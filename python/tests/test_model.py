"""L2 correctness: TinyLM prefill/decode agreement and shape contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    TinyLmConfig,
    decode_step,
    init_params,
    prefill,
    prefill_ref,
)

jax.config.update("jax_platform_name", "cpu")

CFG = TinyLmConfig(max_seq=64)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def test_prefill_matches_reference(params):
    tokens = jnp.arange(32, dtype=jnp.int32).reshape(1, 32) % CFG.vocab
    logits, k, v = prefill(params, CFG, tokens)
    want = prefill_ref(params, CFG, tokens)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), atol=3e-4, rtol=3e-4)
    assert k.shape == (CFG.n_layers, 1, CFG.n_heads, 32, CFG.head_dim)
    assert v.shape == k.shape


def test_decode_continues_prefill(params):
    """Greedy decode after prefill must reproduce prefill logits when fed
    the same tokens — the KV cache handoff is exact."""
    seq = jnp.array([[5, 17, 250, 3, 42, 7, 99, 410]], dtype=jnp.int32)
    s = seq.shape[1]
    full_logits, _, _ = prefill(params, CFG, seq)

    # Prefill the first half, then decode the second half token by token.
    half = s // 2
    _, k, v = prefill(params, CFG, seq[:, :half])
    t = CFG.max_seq
    k_cache = jnp.zeros((CFG.n_layers, 1, CFG.n_heads, t, CFG.head_dim), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    k_cache = k_cache.at[:, :, :, :half, :].set(k)
    v_cache = v_cache.at[:, :, :, :half, :].set(v)

    for i in range(half, s):
        tok = seq[:, i]
        pos = jnp.array([i], jnp.int32)
        logits, k_cache, v_cache = decode_step(params, CFG, tok, pos, k_cache, v_cache)
        np.testing.assert_allclose(
            np.asarray(logits[0]),
            np.asarray(full_logits[0, i]),
            atol=5e-4,
            rtol=5e-4,
            err_msg=f"divergence at position {i}",
        )


def test_batched_decode_matches_individual(params):
    """Decoding two sequences in one batch must equal decoding them
    separately — the isolation property continuous batching relies on."""
    t = CFG.max_seq
    seqs = [
        jnp.array([[1, 2, 3, 4]], dtype=jnp.int32),
        jnp.array([[100, 200, 300, 400, 500, 60]], dtype=jnp.int32),
    ]
    singles = []
    caches = []
    for seq in seqs:
        _, k, v = prefill(params, CFG, seq)
        kc = jnp.zeros((CFG.n_layers, 1, CFG.n_heads, t, CFG.head_dim), jnp.float32)
        vc = jnp.zeros_like(kc)
        kc = kc.at[:, :, :, : seq.shape[1], :].set(k)
        vc = vc.at[:, :, :, : seq.shape[1], :].set(v)
        tok = jnp.array([7], jnp.int32)
        pos = jnp.array([seq.shape[1]], jnp.int32)
        logits, _, _ = decode_step(params, CFG, tok, pos, kc, vc)
        singles.append(np.asarray(logits[0]))
        caches.append((kc, vc))

    kb = jnp.concatenate([caches[0][0], caches[1][0]], axis=1)
    vb = jnp.concatenate([caches[0][1], caches[1][1]], axis=1)
    toks = jnp.array([7, 7], jnp.int32)
    poss = jnp.array([seqs[0].shape[1], seqs[1].shape[1]], jnp.int32)
    logits, _, _ = decode_step(params, CFG, toks, poss, kb, vb)
    np.testing.assert_allclose(np.asarray(logits[0]), singles[0], atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(logits[1]), singles[1], atol=3e-4, rtol=3e-4)


def test_right_padding_does_not_change_last_logits(params):
    """The engine pads prompts to the bucket size on the right; logits at
    the true last position must be unaffected (causality)."""
    seq = jnp.array([[9, 8, 7, 6, 5]], dtype=jnp.int32)
    padded = jnp.zeros((1, 16), jnp.int32).at[:, :5].set(seq)
    l1, _, _ = prefill(params, CFG, seq)
    l2, _, _ = prefill(params, CFG, padded)
    np.testing.assert_allclose(
        np.asarray(l1[0, 4]), np.asarray(l2[0, 4]), atol=3e-4, rtol=3e-4
    )


def test_deterministic_init(params):
    p2 = init_params(CFG, seed=0)
    np.testing.assert_array_equal(np.asarray(params["embed"]), np.asarray(p2["embed"]))
    p3 = init_params(CFG, seed=1)
    assert not np.allclose(np.asarray(params["embed"]), np.asarray(p3["embed"]))


def test_param_count_is_tiny():
    cfg = TinyLmConfig()
    params = init_params(cfg, seed=0)
    n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    assert 0.5e6 < n < 3e6, f"param count {n}"
