"""MoPE training pipeline: router accuracy and expert error on held-out
data must reproduce §6/Fig 7's qualitative results — an in-domain MoPE
decisively beats the out-of-domain single proxy."""

import numpy as np
import pytest

from compile import corpus, mope_train


@pytest.fixture(scope="module")
def weights():
    return mope_train.train(8000, seed=0)


@pytest.fixture(scope="module")
def single():
    return mope_train.train_single(8000, seed=7)


def test_corpus_quantiles_are_plausible():
    rows = corpus.generate(20000, seed=3)
    stats = corpus.summary_stats(rows)
    # Marginals in the neighbourhood of the LMSYS boundaries (the corpus
    # is template-driven, so the band is loose).
    assert 15 <= stats["p33"] <= 120, stats
    assert 60 <= stats["p66"] <= 400, stats


def test_legacy_style_differs():
    arena = corpus.summary_stats(corpus.generate(10000, seed=4))
    legacy = corpus.summary_stats(corpus.generate(10000, seed=4, style="legacy"))
    # The legacy model's length distribution is compressed toward the
    # middle (Fig 4a's domain mismatch).
    assert legacy["p66"] < arena["p66"], (legacy, arena)


def test_features_are_deterministic():
    f1 = corpus.extract_features("Explain rust lifetimes in detail", 42)
    f2 = corpus.extract_features("Explain rust lifetimes in detail", 42)
    assert f1 == f2
    assert len(f1) == corpus.N_FEATURES
    assert f1[0] == 1.0  # bias term


def test_mope_beats_single_proxy(weights, single):
    acc, single_mae, mope_mae = mope_train.evaluate(weights, single, 5000, seed=11)
    assert mope_mae < 0.8 * single_mae, (single_mae, mope_mae)
    assert acc > 0.7, acc


def test_router_accuracy_band(weights, single):
    acc, _, _ = mope_train.evaluate(weights, single, 5000, seed=12)
    # Paper: ≈80% at full training size; our feature router does a bit
    # better on the synthetic corpus.
    assert 0.7 <= acc <= 1.0, acc


def test_weights_shape_and_finite(weights):
    assert weights.shape == (1 + len(mope_train.BOUNDARIES) + 1, corpus.N_FEATURES)
    assert np.isfinite(weights).all()


def test_regime_of_matches_boundaries():
    assert mope_train.regime_of(52) == 0
    assert mope_train.regime_of(53) == 1
    assert mope_train.regime_of(209) == 1
    assert mope_train.regime_of(210) == 2


def test_predict_mope_bounded(weights):
    x = np.array([corpus.extract_features("what is x?", n) for n in (1, 10, 1000)], np.float32)
    preds = mope_train.predict_mope(weights, x)
    assert ((preds >= 1) & (preds <= 1024)).all()
