"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is
the core correctness signal for everything the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import causal_attention, decode_attention
from compile.kernels.ref import causal_attention_ref, decode_attention_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOLS = {jnp.float32.dtype: 2e-5, jnp.bfloat16.dtype: 2e-2}


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_causal_attention_matches_ref(b, h, s_blocks, d, dtype, seed):
    s = 16 * s_blocks
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = rand(keys[0], (b, h, s, d), dtype)
    k = rand(keys[1], (b, h, s, d), dtype)
    v = rand(keys[2], (b, h, s, d), dtype)
    got = causal_attention(q, k, v, block_q=16, block_kv=16)
    want = causal_attention_ref(q, k, v)
    tol = TOLS[jnp.dtype(dtype)]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    t=st.sampled_from([16, 64, 96]),
    d=st.sampled_from([8, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, t, d, dtype, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = rand(keys[0], (b, h, 1, d), dtype)
    kc = rand(keys[1], (b, h, t, d), dtype)
    vc = rand(keys[2], (b, h, t, d), dtype)
    lengths = jax.random.randint(keys[3], (b,), 1, t + 1)
    got = decode_attention(q, kc, vc, lengths)
    want = decode_attention_ref(q, kc, vc, lengths)
    tol = TOLS[jnp.dtype(dtype)]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_causal_attention_is_actually_causal():
    # Changing a future K/V must not change earlier outputs.
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    b, h, s, d = 1, 2, 32, 16
    q = rand(ks[0], (b, h, s, d), jnp.float32)
    k = rand(ks[1], (b, h, s, d), jnp.float32)
    v = rand(ks[2], (b, h, s, d), jnp.float32)
    out1 = causal_attention(q, k, v, block_q=16, block_kv=16)
    k2 = k.at[:, :, -1, :].set(99.0)
    v2 = v.at[:, :, -1, :].set(-99.0)
    out2 = causal_attention(q, k2, v2, block_q=16, block_kv=16)
    np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1], atol=1e-6)
    assert not np.allclose(out1[:, :, -1], out2[:, :, -1])


def test_decode_attention_masks_beyond_length():
    # Garbage beyond `lengths` must not affect the result.
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    b, h, t, d = 2, 2, 64, 16
    q = rand(ks[0], (b, h, 1, d), jnp.float32)
    kc = rand(ks[1], (b, h, t, d), jnp.float32)
    vc = rand(ks[2], (b, h, t, d), jnp.float32)
    lengths = jnp.array([10, 20], jnp.int32)
    out1 = decode_attention(q, kc, vc, lengths)
    kc2 = kc.at[:, :, 30:, :].set(1e4)
    vc2 = vc.at[:, :, 30:, :].set(-1e4)
    out2 = decode_attention(q, kc2, vc2, lengths)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


def test_block_size_invariance():
    # Same numbers regardless of tiling — the kernel's defining invariant.
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    b, h, s, d = 1, 2, 64, 32
    q = rand(ks[0], (b, h, s, d), jnp.float32)
    k = rand(ks[1], (b, h, s, d), jnp.float32)
    v = rand(ks[2], (b, h, s, d), jnp.float32)
    o1 = causal_attention(q, k, v, block_q=16, block_kv=16)
    o2 = causal_attention(q, k, v, block_q=64, block_kv=32)
    np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("s", [16, 48])
def test_softmax_rows_are_convex_combinations(s):
    # Output of attention must lie within the convex hull of V rows:
    # max |out| <= max |v|.
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (1, 1, s, 8), jnp.float32)
    k = rand(ks[1], (1, 1, s, 8), jnp.float32)
    v = rand(ks[2], (1, 1, s, 8), jnp.float32)
    out = causal_attention(q, k, v, block_q=16, block_kv=16)
    assert np.max(np.abs(out)) <= np.max(np.abs(v)) + 1e-5
