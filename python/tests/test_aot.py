"""AOT pipeline: lowered HLO text artifacts are well-formed and the
manifest describes them accurately. Uses --quick buckets to stay fast."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        cwd=os.path.join(REPO, "python"),
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_lists_all_files(artifacts):
    manifest = json.loads((artifacts / "manifest.json").read_text())
    assert manifest["model"]["name"] == "tinylm"
    assert len(manifest["artifacts"]) >= 3  # prefill + decode + mope
    for a in manifest["artifacts"]:
        path = artifacts / a["path"]
        assert path.exists(), a
        text = path.read_text()
        assert text.startswith("HloModule"), a["path"]
        # Self-contained: parameters lowered as constants — module must be
        # nontrivially large for model artifacts.
        if a["kind"] in ("prefill", "decode"):
            assert len(text) > 100_000, (a["path"], len(text))


def test_mope_artifact_metadata(artifacts):
    manifest = json.loads((artifacts / "manifest.json").read_text())
    mope = [a for a in manifest["artifacts"] if a["kind"] == "mope"]
    assert len(mope) == 1
    m = mope[0]
    assert m["boundaries"] == [53, 210]
    assert m["n_experts"] == 3
    assert 0.5 <= m["router_accuracy"] <= 1.0
    assert m["mope_mae"] < m["single_mae"]


def test_hlo_has_no_custom_calls(artifacts):
    """interpret=True Pallas must lower to plain HLO ops — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    for path in artifacts.glob("*.hlo.txt"):
        text = path.read_text()
        assert "custom-call" not in text, path.name
