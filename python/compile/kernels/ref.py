"""Pure-jnp oracle for the Pallas attention kernels.

The CORE correctness signal: python/tests/test_kernel.py asserts the
Pallas kernels match these references to tight tolerances across a
hypothesis-driven sweep of shapes and dtypes.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def causal_attention_ref(q, k, v):
    """Naive causal attention. Shapes ``[b, h, s, d]``."""
    d = q.shape[-1]
    s = q.shape[2]
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    logits = jnp.where(q_pos >= k_pos, logits, NEG_INF)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Naive single-query attention with a length mask.

    q: ``[b, h, 1, d]``; caches ``[b, h, t, d]``; lengths ``[b]``.
    """
    d = q.shape[-1]
    t = k_cache.shape[2]
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, k_cache.astype(jnp.float32)
    )  # [b,h,1,t]
    pos = jnp.arange(t)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
