"""Layer-1: Pallas fused-attention kernels (TPU-style, interpret mode).

The serving hot-spot of the TinyLM model: causal self-attention for
prefill and single-query attention against a KV cache for decode. Both
are written as Pallas kernels with explicit BlockSpec tiling — VMEM-sized
(block_q x block_kv) tiles with flash-attention online softmax, the TPU
re-think of the paper's GPU kernels (DESIGN.md §Hardware-Adaptation).

Kernels are lowered with ``interpret=True`` everywhere: the PJRT CPU
client cannot execute Mosaic custom-calls, and interpret mode lowers to
plain HLO that round-trips through the AOT text bridge. Correctness is
pinned against the pure-jnp oracle in ``ref.py`` by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes/dtypes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: multiples of the 8x128 TPU vreg layout where the model dims
# allow. TinyLM's head_dim (32) and short sequences keep tiles small; the
# grid logic is identical at A100/TPU scale.
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_KV = 64

NEG_INF = -1e30


def _causal_attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_kv: int, scale: float):
    """One (batch*head, q-block) program instance.

    Iterates over KV blocks with the flash-attention online-softmax
    recurrence, accumulating in f32. The q block and the running
    (acc, m, l) statistics live in VMEM for the whole loop — the HBM↔VMEM
    schedule that a CUDA kernel would express with shared-memory staging.
    """
    q = q_ref[...].astype(jnp.float32) * scale  # [block_q, d]
    block_q, d = q.shape
    kv_len = k_ref.shape[0]
    q_offset = pl.program_id(1) * block_q

    def body(carry, kv_idx):
        acc, m_prev, l_prev = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], kv_idx * block_kv, block_kv, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], kv_idx * block_kv, block_kv, 0)
        s = q @ k.astype(jnp.float32).T  # [block_q, block_kv]
        # Causal mask: query position (global) >= key position (global).
        q_pos = q_offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = kv_idx * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return (acc, m_new, l_new), None

    n_kv_blocks = kv_len // block_kv
    init = (
        jnp.zeros((block_q, d), jnp.float32),
        jnp.full((block_q,), NEG_INF, jnp.float32),
        jnp.zeros((block_q,), jnp.float32),
    )
    (acc, _, l), _ = jax.lax.scan(body, init, jnp.arange(n_kv_blocks))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def causal_attention(q, k, v, *, block_q: int = DEFAULT_BLOCK_Q,
                     block_kv: int = DEFAULT_BLOCK_KV):
    """Causal self-attention via the Pallas kernel.

    Args:
      q, k, v: ``[batch, heads, seq, head_dim]`` with seq % block sizes == 0
        (the model pads to buckets).
    Returns:
      ``[batch, heads, seq, head_dim]`` attention output, q's dtype.
    """
    b, h, s, d = q.shape
    assert k.shape == (b, h, s, d) and v.shape == (b, h, s, d)
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    kernel = functools.partial(_causal_attn_kernel, block_kv=block_kv, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale: float):
    """Single-query attention against a cache prefix, one (batch*head)
    program instance. ``len_ref`` holds the valid cache length; positions
    beyond it are masked. Memory-bound by the K/V streams — exactly the
    decode side of the paper's Fig 3 bifurcation."""
    q = q_ref[...].astype(jnp.float32) * scale  # [1, d]
    k = k_ref[...].astype(jnp.float32)  # [T, d]
    v = v_ref[...].astype(jnp.float32)  # [T, d]
    valid = len_ref[...]  # scalar: block shape (None,) drops the axis
    s = (q @ k.T)[0]  # [T]
    pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    s = jnp.where(pos < valid, s, NEG_INF)
    m = jnp.max(s)
    p = jnp.exp(s - m)
    l = jnp.sum(p)
    o_ref[...] = ((p @ v) / jnp.maximum(l, 1e-30))[None, :].astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths):
    """One decode step of attention.

    Args:
      q: ``[batch, heads, 1, head_dim]`` current-token queries.
      k_cache, v_cache: ``[batch, heads, max_seq, head_dim]``.
      lengths: ``[batch]`` int32 — valid cache length per sequence
        (including the current token, already written to the cache).
    Returns:
      ``[batch, heads, 1, head_dim]``.
    """
    b, h, one, d = q.shape
    assert one == 1
    t = k_cache.shape[2]
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(b * h, 1, d)
    kf = k_cache.reshape(b * h, t, d)
    vf = v_cache.reshape(b * h, t, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), h)  # [b*h]

    kernel = functools.partial(_decode_attn_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((None, 1, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, t, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, t, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((None, 1, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        interpret=True,
    )(qf, kf, vf, lens)
    return out.reshape(b, h, 1, d)
