"""AOT bridge: lower TinyLM + MoPE to HLO *text* artifacts for the rust
runtime (Layer 3).

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Model parameters are closed over, so they lower into the HLO as
constants — each artifact is fully self-contained and the rust binary
needs no weight files.

Usage: python -m compile.aot [--out-dir ../artifacts] [--quick]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import mope_train
from compile.model import TinyLmConfig, decode_step, init_params, prefill

# Shape buckets the rust engine requests. Prefill pads prompts up to the
# next bucket; decode runs the whole resident batch at its bucket size.
PREFILL_SEQ_BUCKETS = (64, 128, 256)
DECODE_BATCH_BUCKETS = (1, 2, 4, 8)
MOPE_BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the model weights are closed over and must
    # survive the text round-trip — default printing elides them as
    # `constant({...})`, which would silently zero the model on the rust
    # side.
    return comp.as_hlo_text(print_large_constants=True)


def lower_prefill(params, cfg, seq):
    def fn(tokens):
        logits, k, v = prefill(params, cfg, tokens)
        return logits, k, v

    spec = jax.ShapeDtypeStruct((1, seq), jnp.int32)
    return jax.jit(fn).lower(spec)


def lower_decode(params, cfg, batch):
    def fn(tokens, positions, k_cache, v_cache):
        return decode_step(params, cfg, tokens, positions, k_cache, v_cache)

    tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim), jnp.float32
    )
    return jax.jit(fn).lower(tok, pos, cache, cache)


def lower_mope(weights):
    w = jnp.asarray(weights)  # [1+E, F]

    def fn(features):
        # [B, 1+E]: column 0 router/generalist estimate, cols 1.. experts.
        ln_pred = features @ w.T
        return (jnp.clip(jnp.exp(ln_pred), 1.0, 1024.0),)

    spec = jax.ShapeDtypeStruct((MOPE_BATCH, weights.shape[1]), jnp.float32)
    return jax.jit(fn).lower(spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--quick", action="store_true", help="smallest buckets only (tests)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    cfg = TinyLmConfig()
    params = init_params(cfg, seed=args.seed)
    seq_buckets = PREFILL_SEQ_BUCKETS[:1] if args.quick else PREFILL_SEQ_BUCKETS
    batch_buckets = DECODE_BATCH_BUCKETS[:1] if args.quick else DECODE_BATCH_BUCKETS

    manifest = {
        "model": {
            "name": "tinylm",
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "max_seq": cfg.max_seq,
            "seed": args.seed,
        },
        "artifacts": [],
    }

    def emit(name, lowered, kind, **meta):
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        manifest["artifacts"].append({"name": name, "path": path, "kind": kind, **meta})
        print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")

    print(f"AOT-lowering TinyLM to {out_dir}")
    for s in seq_buckets:
        emit(f"prefill_b1_s{s}", lower_prefill(params, cfg, s), "prefill", batch=1, seq=s)
    for b in batch_buckets:
        emit(f"decode_b{b}", lower_decode(params, cfg, b), "decode", batch=b, max_seq=cfg.max_seq)

    print("training MoPE experts on the synthetic corpus")
    n_train = 2000 if args.quick else 20000
    weights = mope_train.train(n_train, seed=args.seed)
    w_single = mope_train.train_single(n_train, seed=args.seed + 7)
    acc, single_mae, mope_mae = mope_train.evaluate(weights, w_single, 2000, seed=args.seed + 1)
    print(f"  router accuracy={acc:.3f} single MAE={single_mae:.1f} mope MAE={mope_mae:.1f}")
    emit("mope", lower_mope(weights), "mope",
         batch=MOPE_BATCH,
         n_features=int(weights.shape[1]),
         n_experts=int(weights.shape[0] - 1),
         boundaries=list(mope_train.BOUNDARIES),
         router_accuracy=acc,
         single_mae=single_mae,
         mope_mae=mope_mae)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
