"""Offline MoPE training (Fig 8, left half) — build-time only.

The paper fine-tunes BERT-base regressors per output-length regime; we
substitute closed-form ridge regression over the corpus features (the
scheduler consumes only the resulting error distribution — DESIGN.md
substitution ledger).

Pipeline, mirroring Fig 8:
  1. Router/generalist: log-space ridge on the in-domain ("arena") corpus.
  2. Partition the corpus by the ROUTER'S classification (not the true
     regimes — the experts must correct router-conditional error).
  3. One log-space ridge expert per partition.

The single-proxy baseline reproduces Fig 4a's failure mode: it is trained
on a *mismatched* chat corpus (``style="legacy"`` — proxies in the paper
were trained on Llama-7B/GPT-4/Vicuna outputs and generalise poorly),
giving the regression-to-the-mean error profile the paper measures
(L1 ≈ 80 single vs ≈ 33 MoPE).
"""

import numpy as np

from compile import corpus

BOUNDARIES = (53, 210)


def ridge(x: np.ndarray, y: np.ndarray, lam: float = 1e-3) -> np.ndarray:
    f = x.shape[1]
    a = x.T @ x + lam * np.eye(f, dtype=np.float64)
    return np.linalg.solve(a, x.T @ y).astype(np.float32)


def regime_of(out: int) -> int:
    for i, b in enumerate(BOUNDARIES):
        if out < b:
            return i
    return len(BOUNDARIES)


def regime_edges():
    """[lo, hi) token range per expert regime."""
    edges = [1] + list(BOUNDARIES) + [1024]
    return [(edges[i], edges[i + 1]) for i in range(len(edges) - 1)]


def _xy(rows):
    x = np.array([r[2] for r in rows], dtype=np.float32)
    y = np.array([r[3] for r in rows], dtype=np.float32)
    return x, y


def _route(x, w_router):
    est = np.clip(np.exp(x @ w_router), 1, 1024)
    return np.array([regime_of(int(round(p))) for p in est])


def train(n_samples: int = 20000, seed: int = 0):
    """Train MoPE. Returns weights [1 + n_experts, n_features] in
    ln-token space: row 0 router/generalist, rows 1.. experts."""
    x, y = _xy(corpus.generate(n_samples, seed))
    ln_y = np.log(y)
    n_experts = len(BOUNDARIES) + 1

    weights = np.zeros((1 + n_experts, x.shape[1]), dtype=np.float32)
    weights[0] = ridge(x, ln_y)  # router / generalist
    routed = _route(x, weights[0])
    for e in range(n_experts):
        mask = routed == e
        if mask.sum() >= x.shape[1] + 1:
            weights[1 + e] = ridge(x[mask], ln_y[mask])
        else:  # degenerate partition — fall back to the generalist
            weights[1 + e] = weights[0]
    return weights


def train_single(n_samples: int = 20000, seed: int = 7):
    """The single-proxy baseline: one regressor trained out-of-domain."""
    x, y = _xy(corpus.generate(n_samples, seed, style="legacy"))
    return ridge(x, np.log(y))


def predict_mope(weights: np.ndarray, x: np.ndarray) -> np.ndarray:
    routed = _route(x, weights[0])
    ln_pred = np.take_along_axis(x @ weights[1:].T, routed[:, None], axis=1)[:, 0]
    return np.clip(np.exp(ln_pred), 1, 1024)


def evaluate(weights: np.ndarray, w_single: np.ndarray, n_samples: int = 5000, seed: int = 1):
    """Return (router_accuracy, single_mae, mope_mae) on held-out arena data."""
    x, y = _xy(corpus.generate(n_samples, seed))
    routed = _route(x, weights[0])
    truth_regime = np.array([regime_of(int(o)) for o in y])
    acc = float((routed == truth_regime).mean())
    single_pred = np.clip(np.exp(x @ w_single), 1, 1024)
    mope_pred = predict_mope(weights, x)
    return (
        acc,
        float(np.abs(single_pred - y).mean()),
        float(np.abs(mope_pred - y).mean()),
    )


if __name__ == "__main__":
    w = train()
    ws = train_single()
    acc, single_mae, mope_mae = evaluate(w, ws)
    print(f"router accuracy={acc:.3f} single MAE={single_mae:.1f} mope MAE={mope_mae:.1f}")
