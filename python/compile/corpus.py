"""Synthetic prompt corpus for MoPE training.

Substitute for LMSYS-Chat-1M (not redistributable offline): prompts are
template-generated with features (length, question/code/list/explain
markers) and true output lengths drawn from a feature-conditioned
log-normal whose marginal matches the rust-side ``LmsysLike`` generator
(median ~108, P33 ~53, P66 ~210, capped at 1024). The same feature
extractor runs in rust (`runtime/features.rs`) so the AOT-compiled
experts see identical inputs at serving time.
"""

import math
import random

N_FEATURES = 7

_TOPICS = [
    "the roman empire", "rust lifetimes", "gradient descent", "sourdough",
    "black holes", "tcp congestion control", "haiku", "the krebs cycle",
    "jane austen", "distributed consensus", "guitar chords", "tokyo",
]

_TEMPLATES = [
    # (template, marker flags (question, code, list, explain), base log-len)
    ("what is {t}?", (1, 0, 0, 0), 4.0),
    ("define {t} in one sentence.", (0, 0, 0, 0), 3.1),
    ("yes or no: is {t} real?", (1, 0, 0, 0), 2.2),
    ("explain {t} in detail with background and caveats.", (0, 0, 0, 1), 5.8),
    ("write a python program that models {t} with tests.", (0, 1, 0, 0), 5.9),
    ("list 10 facts about {t}.", (0, 0, 1, 0), 5.1),
    ("give a step by step tutorial on {t} for beginners.", (0, 0, 1, 1), 5.8),
    ("translate the word {t}.", (0, 0, 0, 0), 3.0),
    ("summarize {t}.", (0, 0, 0, 0), 3.3),
    ("write an essay comparing {t} and its alternatives.", (0, 0, 0, 1), 5.9),
]


def extract_features(prompt: str, input_tokens: int):
    """Feature vector [1, ln(1+len), question, code, list, explain, short].

    Mirrored bit-for-bit by rust's ``runtime::features``.
    """
    p = prompt.lower()
    return [
        1.0,
        math.log(1.0 + input_tokens),
        1.0 if ("?" in p or p.startswith(("what", "why", "how", "is ", "yes or no"))) else 0.0,
        1.0 if ("program" in p or "code" in p or "python" in p or "function" in p) else 0.0,
        1.0 if ("list" in p or "step by step" in p or "tutorial" in p) else 0.0,
        1.0 if ("explain" in p or "detail" in p or "essay" in p or "comparing" in p) else 0.0,
        1.0
        if ("define" in p or "translate" in p or "one sentence" in p or "yes or no" in p or "summarize" in p)
        else 0.0,
    ]


def generate(n: int, seed: int = 0, style: str = "arena"):
    """Yield (prompt, input_tokens, features, true_output_tokens).

    ``style`` selects the serving model whose response lengths are being
    modelled (Fig 4a: proxies trained on one chat model generalise poorly
    to another):
      * ``arena``  — the deployment's traffic (MoPE trains on this).
      * ``legacy`` — an older model with compressed, noisier length
        behaviour (what the single proxy baseline was trained on).
    """
    rng = random.Random(seed)
    rows = []
    for _ in range(n):
        template, _flags, base = rng.choice(_TEMPLATES)
        topic = rng.choice(_TOPICS)
        prompt = template.format(t=topic)
        # Pad some prompts with context to vary input length (lognormal-ish).
        extra = int(math.exp(rng.gauss(3.2, 1.0)))
        input_tokens = max(1, min(4096, len(prompt.split()) + extra))
        feats = extract_features(prompt, input_tokens)
        # True length: template base + weak input-length effect + noise.
        if style == "legacy":
            mu = 0.68 * base + 1.45 + 0.08 * math.log(1.0 + input_tokens)
            sigma = 0.5
        else:
            mu = base + 0.08 * math.log(1.0 + input_tokens)
            sigma = 0.25
        out = int(round(math.exp(rng.gauss(mu, sigma))))
        out = max(1, min(1024, out))
        rows.append((prompt, input_tokens, feats, out))
    return rows


def summary_stats(rows):
    outs = sorted(r[3] for r in rows)
    n = len(outs)
    return {
        "p33": outs[int(0.33 * (n - 1))],
        "p50": outs[int(0.50 * (n - 1))],
        "p66": outs[int(0.66 * (n - 1))],
        "mean": sum(outs) / n,
    }
