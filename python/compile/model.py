"""Layer-2: TinyLM — the small real transformer the rust runtime serves.

A ~1.1M-parameter decoder-only LM (4 layers, d=128, 4 heads, vocab 512)
with deterministic initialisation. Prefill and decode-step functions call
the Layer-1 Pallas attention kernels so both lower into the same HLO that
``aot.py`` exports. The paper's testbed LLMs (Llama-2-7b/70b) are
substituted at figure scale by the simulator's roofline model; TinyLM is
what proves the three-layer stack composes end to end on a real model
(DESIGN.md substitution ledger).

Functional KV cache: caches are explicit inputs/outputs so the lowered
HLO is pure and the rust engine owns cache state between calls.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.attention import causal_attention, decode_attention


@dataclass(frozen=True)
class TinyLmConfig:
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    max_seq: int = 384

    @property
    def kv_bytes_per_token(self) -> int:
        # f32 K+V across layers.
        return 2 * self.n_layers * self.n_heads * self.head_dim * 4


def init_params(cfg: TinyLmConfig, seed: int = 0):
    """Deterministic parameter pytree (dict of arrays)."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 4 + cfg.n_layers * 8))

    def dense(k, shape, scale=None):
        scale = scale if scale is not None else (1.0 / shape[0]) ** 0.5
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(jnp.float32)

    params = {
        "embed": dense(next(keys), (cfg.vocab, cfg.d_model), scale=0.02),
        "pos": dense(next(keys), (cfg.max_seq, cfg.d_model), scale=0.02),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "head": dense(next(keys), (cfg.d_model, cfg.vocab)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "wq": dense(next(keys), (cfg.d_model, cfg.n_heads * cfg.head_dim)),
            "wk": dense(next(keys), (cfg.d_model, cfg.n_heads * cfg.head_dim)),
            "wv": dense(next(keys), (cfg.d_model, cfg.n_heads * cfg.head_dim)),
            "wo": dense(next(keys), (cfg.n_heads * cfg.head_dim, cfg.d_model)),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "w1": dense(next(keys), (cfg.d_model, cfg.d_ff)),
            "w2": dense(next(keys), (cfg.d_ff, cfg.d_model)),
        }
        params["layers"].append(layer)
    return params


def _rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _split_heads(x, cfg):
    b, s, _ = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def prefill(params, cfg: TinyLmConfig, tokens):
    """Process a padded prompt batch.

    Args:
      tokens: ``[b, s]`` int32, padded with 0s (padding positions attend
        causally like real tokens; the engine reads logits at the true
        last position, so padding never affects sampled output — padding
        is always on the RIGHT).
    Returns:
      logits ``[b, s, vocab]``, k_cache, v_cache ``[n_layers, b, h, s, d]``.
    """
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos"][:s][None, :, :]
    ks, vs = [], []
    for layer in params["layers"]:
        h = _rmsnorm(x, layer["ln1"])
        q = _split_heads(h @ layer["wq"], cfg)
        k = _split_heads(h @ layer["wk"], cfg)
        v = _split_heads(h @ layer["wv"], cfg)
        attn = causal_attention(q, k, v)
        x = x + _merge_heads(attn) @ layer["wo"]
        h2 = _rmsnorm(x, layer["ln2"])
        x = x + jax.nn.gelu(h2 @ layer["w1"]) @ layer["w2"]
        ks.append(k)
        vs.append(v)
    logits = _rmsnorm(x, params["ln_f"]) @ params["head"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(params, cfg: TinyLmConfig, tokens, positions, k_cache, v_cache):
    """One decode step for a batch of sequences.

    Args:
      tokens: ``[b]`` int32 current tokens.
      positions: ``[b]`` int32 — position of the current token (0-based);
        the new K/V is written at this index and attention covers
        ``[0, position]``.
      k_cache, v_cache: ``[n_layers, b, h, max_seq, d]``.
    Returns:
      logits ``[b, vocab]``, updated caches.
    """
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None, :]  # [b, 1, dm]
    pos_emb = params["pos"][positions][:, None, :]
    x = x + pos_emb
    new_k, new_v = [], []
    lengths = positions + 1
    for li, layer in enumerate(params["layers"]):
        h = _rmsnorm(x, layer["ln1"])
        q = _split_heads(h @ layer["wq"], cfg)  # [b, h, 1, d]
        k = _split_heads(h @ layer["wk"], cfg)
        v = _split_heads(h @ layer["wv"], cfg)
        # Scatter the new K/V at each sequence's position.
        kc = jax.vmap(
            lambda cache, upd, p: jax.lax.dynamic_update_slice_in_dim(cache, upd, p, axis=1)
        )(k_cache[li], k[:, :, 0:1, :].transpose(0, 1, 2, 3), positions)
        vc = jax.vmap(
            lambda cache, upd, p: jax.lax.dynamic_update_slice_in_dim(cache, upd, p, axis=1)
        )(v_cache[li], v[:, :, 0:1, :], positions)
        attn = decode_attention(q, kc, vc, lengths)
        x = x + _merge_heads(attn) @ layer["wo"]
        h2 = _rmsnorm(x, layer["ln2"])
        x = x + jax.nn.gelu(h2 @ layer["w1"]) @ layer["w2"]
        new_k.append(kc)
        new_v.append(vc)
    logits = _rmsnorm(x[:, 0, :], params["ln_f"]) @ params["head"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def prefill_ref(params, cfg: TinyLmConfig, tokens):
    """Prefill using the jnp reference attention (oracle for tests)."""
    from compile.kernels.ref import causal_attention_ref

    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos"][:s][None, :, :]
    for layer in params["layers"]:
        h = _rmsnorm(x, layer["ln1"])
        q = _split_heads(h @ layer["wq"], cfg)
        k = _split_heads(h @ layer["wk"], cfg)
        v = _split_heads(h @ layer["wv"], cfg)
        attn = causal_attention_ref(q, k, v)
        x = x + _merge_heads(attn) @ layer["wo"]
        h2 = _rmsnorm(x, layer["ln2"])
        x = x + jax.nn.gelu(h2 @ layer["w1"]) @ layer["w2"]
    return _rmsnorm(x, params["ln_f"]) @ params["head"]
