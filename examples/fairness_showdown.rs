//! Fairness showdown across the adversarial scenario library: hostile
//! traffic shapes (overload, heavy hitter, flash crowd, prefill/decode
//! duel) on the simulated A100, under FCFS vs RPM vs VTC vs Equinox.
//! Prints the per-scheduler fairness/latency/throughput summary per
//! scenario — the library's one-screen pitch.
//!
//! Run: `cargo run --release --example fairness_showdown`

use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::sim::{HostProfile, SimConfig};
use equinox::workload::adversarial;

fn main() {
    let cfg = SimConfig::a100_7b_vllm().with_host(HostProfile::SLORA);
    for name in ["constant_overload", "heavy_hitter", "flash_crowd", "prefill_decode_duel"] {
        let sc = adversarial::find(name).expect("registry scenario");
        let trace = sc.trace(false, 42);
        println!(
            "=== {} — {} requests / {:.0}s across {} tenants ===",
            sc.name,
            trace.len(),
            trace.horizon,
            trace.num_clients()
        );
        println!(
            "{:<10} {:>10} {:>10} {:>10} {:>12} {:>12} {:>12}",
            "scheduler", "TTFT-avg", "TTFT-p90", "GPU-util", "wtok/s", "max-diff", "preemptions"
        );
        for kind in [SchedKind::Fcfs, SchedKind::Rpm, SchedKind::Vtc, SchedKind::Equinox] {
            let pred = if kind == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
            let res = run_sim(&cfg, kind, pred, &trace, 42);
            // Worst co-backlogged service gap across ALL tenant pairs —
            // the multi-tenant generalisation of the paper's accumulated
            // service difference.
            let max_diff = res.max_co_backlogged_diff();
            println!(
                "{:<10} {:>9.1}s {:>9.1}s {:>10.2} {:>12.0} {:>12.0} {:>12}",
                kind.label(),
                res.latency.ttft_mean(),
                res.latency.ttft_p(0.9),
                res.gpu_util,
                res.weighted_tps,
                max_diff,
                res.preemptions,
            );
        }
        println!();
    }
    println!("FCFS lets heavy tenants monopolise; RPM throttles but wastes capacity; VTC bounds");
    println!("the service gap; Equinox bounds it at higher delivered throughput and lower TTFT");
    println!("(prediction-driven stall-free admission). The same matrix, machine-checked, runs");
    println!("as `equinox conformance` — see EXPERIMENTS.md §Conformance matrix.");
}
