//! Fairness showdown across the adversarial scenario library: hostile
//! traffic shapes (overload, heavy hitter, flash crowd, prefill/decode
//! duel) on the simulated A100, under FCFS vs RPM vs VTC vs Equinox.
//! Prints the per-scheduler fairness/latency/throughput summary per
//! scenario — the library's one-screen pitch.
//!
//! Run: `cargo run --release --example fairness_showdown`
//!
//! With `--fleet hetero` it instead contrasts routing policies over the
//! heterogeneous 80GB+2×40GB fleet on the same cluster-scale trace,
//! printing the global co-backlogged discrepancy delta vs a
//! FairShare-routed fleet — the cluster subsystem's one-screen pitch.
//! Run: `cargo run --release --example fairness_showdown -- --fleet hetero`

use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::sim::{HostProfile, SimConfig};
use equinox::workload::adversarial;

fn showdown_fleet(fleet: equinox::cluster::Fleet) {
    use equinox::cluster::{run_cluster, ClusterOpts, RouterKind};
    use equinox::harness::cluster::cluster_trace;

    println!(
        "=== fleet showdown — {} ({} replicas), Equinox+MoPE per replica ===",
        fleet.name,
        fleet.len()
    );
    for name in ["heavy_hitter", "flash_crowd", "constant_overload"] {
        let trace = cluster_trace(name, fleet.len(), false, 42);
        println!(
            "--- {} — {} requests at {}x single-engine load ---",
            name,
            trace.len(),
            2 * fleet.len()
        );
        println!(
            "{:<16} {:>10} {:>10} {:>12} {:>12} {:>10} {:>8}",
            "router", "TTFT-avg", "TTFT-p90", "wtok/s", "max-disc", "vs-fair", "syncs"
        );
        let opts = ClusterOpts::new(42);
        let fair = run_cluster(
            fleet.clone(),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &opts,
        );
        let fair_disc = fair.max_co_backlogged_diff();
        for kind in [
            RouterKind::RoundRobin,
            RouterKind::JoinShortestQueue,
            RouterKind::PredictedCost,
            RouterKind::FairShare,
        ] {
            let computed;
            let res = if kind == RouterKind::FairShare {
                // Reuse the reference run rather than recomputing.
                &fair
            } else {
                computed = run_cluster(
                    fleet.clone(),
                    kind.make(),
                    SchedKind::Equinox,
                    PredKind::Mope,
                    &trace,
                    &opts,
                );
                &computed
            };
            let lat = res.merged_latency();
            let disc = res.max_co_backlogged_diff();
            println!(
                "{:<16} {:>9.1}s {:>9.1}s {:>12.0} {:>12.0} {:>+9.0} {:>8}",
                kind.label(),
                lat.ttft_mean(),
                lat.ttft_p(0.9),
                res.weighted_tps(),
                disc,
                disc - fair_disc,
                res.syncs
            );
        }
        println!();
    }
    println!("Count-blind routing lets the slower 40GB replicas build asymmetric backlogs —");
    println!("the global discrepancy delta (vs-fair) is the price of ignoring the dual-counter");
    println!("plane. FairShare balances predicted backlog seconds and keeps it bounded; the");
    println!("same matrix, machine-checked, runs as `equinox cluster --matrix`.");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Some(i) = args.iter().position(|a| a == "--fleet") {
        let name = args.get(i + 1).map(|s| s.as_str()).unwrap_or("hetero");
        let Some(fleet) = equinox::cluster::Fleet::by_name(name) else {
            eprintln!("unknown fleet '{name}' (solo|homo4|hetero|skewed3)");
            std::process::exit(2);
        };
        showdown_fleet(fleet);
        return;
    }
    let cfg = SimConfig::a100_7b_vllm().with_host(HostProfile::SLORA);
    for name in ["constant_overload", "heavy_hitter", "flash_crowd", "prefill_decode_duel"] {
        let sc = adversarial::find(name).expect("registry scenario");
        let trace = sc.trace(false, 42);
        println!(
            "=== {} — {} requests / {:.0}s across {} tenants ===",
            sc.name,
            trace.len(),
            trace.horizon,
            trace.num_clients()
        );
        println!(
            "{:<10} {:>10} {:>10} {:>10} {:>12} {:>12} {:>12}",
            "scheduler", "TTFT-avg", "TTFT-p90", "GPU-util", "wtok/s", "max-diff", "preemptions"
        );
        for kind in [SchedKind::Fcfs, SchedKind::Rpm, SchedKind::Vtc, SchedKind::Equinox] {
            let pred = if kind == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
            let res = run_sim(&cfg, kind, pred, &trace, 42);
            // Worst co-backlogged service gap across ALL tenant pairs —
            // the multi-tenant generalisation of the paper's accumulated
            // service difference.
            let max_diff = res.max_co_backlogged_diff();
            println!(
                "{:<10} {:>9.1}s {:>9.1}s {:>10.2} {:>12.0} {:>12.0} {:>12}",
                kind.label(),
                res.latency.ttft_mean(),
                res.latency.ttft_p(0.9),
                res.gpu_util,
                res.weighted_tps,
                max_diff,
                res.preemptions,
            );
        }
        println!();
    }
    println!("FCFS lets heavy tenants monopolise; RPM throttles but wastes capacity; VTC bounds");
    println!("the service gap; Equinox bounds it at higher delivered throughput and lower TTFT");
    println!("(prediction-driven stall-free admission). The same matrix, machine-checked, runs");
    println!("as `equinox conformance` — see EXPERIMENTS.md §Conformance matrix.");
}
