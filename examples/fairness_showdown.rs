//! Fairness showdown: two tenants with very different request shapes on
//! the simulated A100, under FCFS vs RPM vs VTC vs Equinox. Prints the
//! per-scheduler fairness/latency/throughput summary — the library's
//! one-screen pitch.
//!
//! Run: `cargo run --release --example fairness_showdown`

use equinox::core::ClientId;
use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::metrics::fairness::summarize_diffs;
use equinox::sim::{HostProfile, SimConfig};
use equinox::workload::{generate, Scenario};

fn main() {
    let duration = 120.0;
    let trace = generate(&Scenario::constant_overload(duration), 42);
    println!(
        "workload: {} requests / {:.0}s — C1: 20 rps of (20 in, 180 out); C2: 2 rps of (200 in, 1800 out)\n",
        trace.len(),
        duration
    );
    let cfg = SimConfig::a100_7b_vllm().with_host(HostProfile::SLORA);
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>12} {:>12} {:>12}",
        "scheduler", "TTFT-avg", "TTFT-p90", "GPU-util", "wtok/s", "max-diff", "preemptions"
    );
    for kind in [SchedKind::Fcfs, SchedKind::Rpm, SchedKind::Vtc, SchedKind::Equinox] {
        let pred = if kind == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
        let res = run_sim(&cfg, kind, pred, &trace, 42);
        let diffs = res.backlogged_diff_series(ClientId(0), ClientId(1));
        let s = summarize_diffs(&diffs);
        println!(
            "{:<10} {:>9.1}s {:>9.1}s {:>10.2} {:>12.0} {:>12.0} {:>12}",
            kind.label(),
            res.latency.ttft_mean(),
            res.latency.ttft_p(0.9),
            res.gpu_util,
            res.weighted_tps,
            s.max,
            res.preemptions,
        );
    }
    println!("\nFCFS lets the heavy tenant monopolise; VTC bounds the gap; Equinox bounds it at");
    println!("higher delivered throughput and lower TTFT (prediction-driven stall-free admission).");
}
