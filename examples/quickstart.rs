//! Quickstart: load the AOT-compiled TinyLM artifacts and serve a few
//! prompts through the full coordinator (frontend → MoPE → Equinox
//! scheduler → PJRT engine). Requires `make artifacts`.
//!
//! Run: `cargo run --release --example quickstart`

use equinox::core::ClientId;
use equinox::server::service::{ServeService, ServiceConfig};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("loading TinyLM artifacts from {artifacts}/ ...");
    let service = ServeService::start(ServiceConfig::new(&artifacts))?;

    let prompts = [
        (0u32, "what is rust?", 16u32),
        (1, "explain tcp congestion control in detail", 24),
        (0, "list 10 facts about tokyo", 16),
        (2, "define sourdough in one sentence.", 8),
    ];
    for (client, prompt, max_new) in prompts {
        let done = service.generate(ClientId(client), prompt, max_new)?;
        println!(
            "client {} | ttft {:>6.3}s | e2e {:>6.3}s | {:>2} tokens | {}",
            done.client, done.ttft, done.e2e, done.output_tokens, done.text
        );
    }
    println!("\nstats: {}", service.stats.snapshot_json().to_string());
    Ok(())
}
