//! Trace replay: run a ShareGPT-like multi-tenant trace through the
//! simulator with the Equinox scheduler and print per-client statistics —
//! the workflow an operator would use to evaluate a fairness policy
//! against their own traffic.
//!
//! Run: `cargo run --release --example trace_replay [rps] [prompts]`

use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::sim::{HostProfile, SimConfig};
use equinox::workload::tracegen::sharegpt_trace;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let rps: f64 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(8.0);
    let prompts: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(640);

    let trace = sharegpt_trace(16, rps, prompts, 7);
    println!(
        "replaying {} ShareGPT-like prompts across {} clients at {:.1} rps (simulated A100 · Llama-2-7b)\n",
        trace.len(),
        trace.num_clients(),
        rps
    );
    let cfg = SimConfig::a100_7b_vllm().with_host(HostProfile::VLLM);
    let res = run_sim(&cfg, SchedKind::Equinox, PredKind::Mope, &trace, 7);

    println!(
        "{:<8} {:>8} {:>12} {:>12} {:>14}",
        "client", "requests", "TTFT-p50", "e2e-p50", "service(wtok)"
    );
    for c in res.service.clients() {
        let lat = &res.per_client_latency[&c];
        println!(
            "{:<8} {:>8} {:>11.2}s {:>11.2}s {:>14.0}",
            c.to_string(),
            lat.count(),
            lat.ttft_p(0.5),
            lat.e2e_p(0.5),
            res.service.total(c),
        );
    }
    println!(
        "\ntotals: {:.0} output tok/s · GPU util {:.2} · Jain(HF) {:.3} · {} preemptions",
        res.output_tps,
        res.gpu_util,
        res.jain_over_hf(),
        res.preemptions
    );
}
