//! End-to-end driver (EXPERIMENTS.md §E2E): start the HTTP frontend over
//! the real TinyLM engine, fire concurrent multi-tenant load from client
//! threads, and report latency/throughput — proving all three layers
//! (Pallas kernel → JAX HLO → rust PJRT coordinator) compose on a real
//! served workload. Requires `make artifacts`.
//!
//! Run: `cargo run --release --example serve_http [requests_per_client]`

use equinox::server::http::{http_get, http_post, HttpResponse, HttpServer};
use equinox::server::service::{ServeService, ServiceConfig};
use equinox::util::json::Json;
use equinox::util::stats::percentile;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let per_client: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(6);
    let artifacts = "artifacts";
    println!("starting equinox HTTP server over TinyLM ({artifacts}/)...");
    let service = Arc::new(ServeService::start(ServiceConfig::new(artifacts))?);

    let svc = service.clone();
    let server = HttpServer::start("127.0.0.1:0", move |req| {
        match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/generate") => {
                let Ok(body) = Json::parse(&req.body) else {
                    return HttpResponse::error(400, r#"{"error":"bad json"}"#);
                };
                let client = body.get("client").and_then(|v| v.as_u64()).unwrap_or(0) as u32;
                let prompt = body.get("prompt").and_then(|v| v.as_str()).unwrap_or("");
                let max = body.get("max_tokens").and_then(|v| v.as_u64()).unwrap_or(16) as u32;
                match svc.submit(equinox::core::ClientId(client), prompt, max) {
                    Ok(rx) => match rx.recv() {
                        Ok(d) => HttpResponse::ok(
                            Json::obj()
                                .set("ttft_s", d.ttft)
                                .set("e2e_s", d.e2e)
                                .set("output_tokens", d.output_tokens as u64)
                                .to_string(),
                        ),
                        Err(_) => HttpResponse::error(503, "{}"),
                    },
                    Err(e) => HttpResponse::error(429, Json::obj().set("error", format!("{e}")).to_string()),
                }
            }
            ("GET", "/v1/stats") => HttpResponse::ok(svc.stats.snapshot_json().to_string()),
            _ => HttpResponse::error(404, "{}"),
        }
    })?;
    let addr = server.addr();
    println!("listening on http://{addr} — firing 3 tenants × {per_client} requests\n");

    let prompts = [
        "what is rust?",
        "explain tcp congestion control in detail",
        "list 10 facts about tokyo",
        "define sourdough in one sentence.",
        "write a python program that models gradient descent",
        "summarize the roman empire",
    ];
    let t0 = Instant::now();
    let handles: Vec<_> = (0..3u32)
        .map(|c| {
            std::thread::spawn(move || {
                let mut lat = Vec::new();
                let mut toks = 0u64;
                for i in 0..per_client {
                    let body = Json::obj()
                        .set("client", c as u64)
                        .set("prompt", prompts[(c as usize + i) % prompts.len()])
                        .set("max_tokens", 12u64)
                        .to_string();
                    let t = Instant::now();
                    let (status, resp) = http_post(&addr, "/v1/generate", &body).unwrap();
                    assert_eq!(status, 200, "{resp}");
                    lat.push(t.elapsed().as_secs_f64());
                    toks += Json::parse(&resp)
                        .ok()
                        .and_then(|j| j.get("output_tokens").and_then(|v| v.as_u64()))
                        .unwrap_or(0);
                }
                (c, lat, toks)
            })
        })
        .collect();

    let mut all_lat = Vec::new();
    let mut total_tokens = 0u64;
    for h in handles {
        let (c, lat, toks) = h.join().unwrap();
        println!(
            "client {c}: {} requests, p50 latency {:.3}s, p90 {:.3}s, {toks} tokens",
            lat.len(),
            percentile(&lat, 0.5),
            percentile(&lat, 0.9)
        );
        total_tokens += toks;
        all_lat.extend(lat);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\ne2e: {} requests in {wall:.2}s → {:.1} req/s, {:.1} output tok/s, p50 {:.3}s p99 {:.3}s",
        all_lat.len(),
        all_lat.len() as f64 / wall,
        total_tokens as f64 / wall,
        percentile(&all_lat, 0.5),
        percentile(&all_lat, 0.99),
    );
    let (_, stats) = http_get(&addr, "/v1/stats")?;
    println!("server stats: {stats}");
    Ok(())
}
