//! Flight-recorder conformance cells: run a cluster cell with tracing
//! enabled and check the trace-level determinism contract.
//!
//! The trace digest is a STRONGER cross-drive check than the cluster
//! fingerprint: the fingerprint hashes end-of-run aggregates, while the
//! digest folds every recorded event — time bits, track, sequence
//! number, event code, payload words. A drive mode that fires a barrier
//! at a different time, drains rings in another order, or perturbs one
//! scheduler decision mid-run produces a different digest even when the
//! final aggregates happen to agree. CI runs the same cell under
//! `Serial` and `Parallel{2}` and diffs both digests.
//!
//! Cells reuse the cluster matrix's seed derivation and trace generator
//! verbatim, so tracing is provably an observer: the traced run's
//! cluster digest must equal the untraced run's.

use super::cluster::cluster_trace;
use super::derive_seed;
use crate::cluster::{run_cluster, ClusterOpts, DriveMode, Fleet, RouterKind};
use crate::exp::{PredKind, SchedKind};
use crate::obs::{TraceCfg, TraceLog};

/// One traced cluster run, ready for digest comparison or export.
#[derive(Debug)]
pub struct TracedCell {
    pub scenario: String,
    pub seed: u64,
    /// The merged flight-recorder log (meta filled in, events in final
    /// `(t, track, seq)` order).
    pub log: TraceLog,
    /// Aggregate cluster digest — must match the untraced run's.
    pub cluster_digest: u64,
    pub finished: usize,
    pub total: usize,
}

impl TracedCell {
    /// Event-stream digest — the cross-drive determinism key.
    pub fn trace_digest(&self) -> u64 {
        self.log.digest()
    }
}

/// Run one traced cluster cell. Scheduler and predictor are pinned to
/// the paper configuration (Equinox + MoPE), matching the cluster
/// matrix; seed and workload are identical to the untraced cell.
pub fn run_traced_cell(
    scenario: &str,
    fleet: Fleet,
    router: RouterKind,
    drive: DriveMode,
    quick: bool,
    base_seed: u64,
) -> TracedCell {
    let label = format!("{}@{}", router.label(), fleet.name);
    let seed = derive_seed(base_seed, scenario, &label);
    let trace = cluster_trace(scenario, fleet.len(), quick, seed);
    let copts = ClusterOpts::new(seed).with_drive(drive).with_trace(TraceCfg::default());
    let res =
        run_cluster(fleet, router.make(), SchedKind::Equinox, PredKind::Mope, &trace, &copts);
    let cluster_digest = res.digest();
    let finished = res.finished();
    let total = res.total_requests();
    let mut log = res.trace.expect("tracing was enabled for this run");
    // The driver cannot know the scenario name; the harness does.
    log.meta.scenario = scenario.to_string();
    TracedCell {
        scenario: scenario.to_string(),
        seed,
        log,
        cluster_digest,
        finished,
        total,
    }
}

/// Digests of the same cell under serial and parallel drives — the pair
/// `tests/trace.rs` and CI assert bit-equal.
pub fn serial_parallel_trace_digests(
    scenario: &str,
    fleet: Fleet,
    router: RouterKind,
    threads: usize,
    quick: bool,
    base_seed: u64,
) -> (u64, u64) {
    let s = run_traced_cell(scenario, fleet.clone(), router, DriveMode::Serial, quick, base_seed);
    let p = run_traced_cell(
        scenario,
        fleet,
        router,
        DriveMode::Parallel { threads },
        quick,
        base_seed,
    );
    (s.trace_digest(), p.trace_digest())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::EventKind;

    #[test]
    fn traced_cell_is_a_pure_observer() {
        // Same cell with and without the recorder: identical cluster
        // digest (recording must not perturb scheduling).
        let cell = run_traced_cell(
            "heavy_hitter",
            Fleet::hetero(),
            RouterKind::FairShare,
            DriveMode::Serial,
            true,
            42,
        );
        let seed = derive_seed(42, "heavy_hitter", "fair_share@hetero-80+2x40");
        assert_eq!(cell.seed, seed);
        let trace = cluster_trace("heavy_hitter", Fleet::hetero().len(), true, seed);
        let bare = run_cluster(
            Fleet::hetero(),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &ClusterOpts::new(seed),
        );
        assert!(bare.trace.is_none());
        assert_eq!(cell.cluster_digest, bare.digest(), "recorder perturbed the run");
        assert_eq!(cell.finished, cell.total);
        assert!(!cell.log.events.is_empty());
        assert_eq!(cell.log.meta.scenario, "heavy_hitter");
    }

    #[test]
    fn traced_cell_covers_the_lifecycle_kinds() {
        let cell = run_traced_cell(
            "flash_crowd",
            Fleet::homogeneous(4),
            RouterKind::RoundRobin,
            DriveMode::Serial,
            true,
            42,
        );
        let mut codes = [false; 16];
        for ev in &cell.log.events {
            codes[ev.kind.code() as usize] = true;
        }
        for kind in [
            EventKind::Arrive { client: crate::core::ClientId(0), req: crate::core::RequestId(0) },
            EventKind::Route {
                client: crate::core::ClientId(0),
                req: crate::core::RequestId(0),
                to: 0,
            },
            EventKind::Finish {
                client: crate::core::ClientId(0),
                req: crate::core::RequestId(0),
                e2e: 0.0,
                predicted: 0,
                actual: 0,
            },
            EventKind::Sync { syncs: 0 },
        ] {
            assert!(codes[kind.code() as usize], "missing {}", kind.label());
        }
    }

    #[test]
    fn serial_and_parallel_traces_are_bit_identical() {
        let (s, p) = serial_parallel_trace_digests(
            "tenant_churn",
            Fleet::homogeneous(4),
            RouterKind::FairShare,
            2,
            true,
            42,
        );
        assert_eq!(s, p, "trace digest diverged across drive modes");
    }
}
