//! Chaos conformance cells: adversarial scenario × fault plan, both
//! drive modes, machine-checked fault-plane invariants.
//!
//! The cluster matrix (`harness::cluster`) pins the healthy-fleet
//! contract; this matrix pins what must *survive* deliberate damage.
//! Every cell fixes the paper configuration (FairShare router over
//! Equinox + MoPE on the heterogeneous fleet, `MigrationPolicy::Migrate`)
//! and varies only the scenario and the fault plan. Per cell:
//!
//! - **drive equivalence** — the digest is bit-identical between
//!   `DriveMode::Serial` and `DriveMode::Parallel`, for every fault
//!   plan. Faults materialize only at barrier boundaries, so this is
//!   the fault plane's headline determinism claim.
//! - **deterministic replay** — re-running the primary drive reproduces
//!   the fingerprint exactly.
//! - **conservation modulo shed** — nothing is silently lost:
//!   finished + shed ≡ trace, Σ routed + shed ≡ trace, and per client
//!   delivered service ≡ offered demand − shed demand. A crash that
//!   drops orphans (see `broken::run_lossy_failover_fixture`) breaks
//!   this check by construction.
//! - **survivor no-starvation** — a client continuously backlogged
//!   beyond the window receives global service inside the interval even
//!   while part of the fleet is down or degraded.
//! - **bounded post-recovery discrepancy** — after the last crash
//!   recovery, the merged co-backlogged pairwise service gap stays
//!   under the cluster tripwire: migration plus fairness-aware routing
//!   must re-converge, not merely limp to drain.

use super::cluster::{cluster_disc_bound, cluster_scenario, cluster_trace};
use super::{derive_seed, other_drive, ConformanceOpts};
use crate::cluster::{
    run_cluster, ClusterOpts, ClusterResult, DriveMode, FaultPlan, Fleet, MigrationPolicy,
    RouterKind,
};
use crate::core::ClientId;
use crate::exp::{PredKind, SchedKind};
use crate::util::json::Json;
use crate::workload::Trace;
use std::collections::BTreeMap;

/// Scenario axis — the two shapes that stress a damaged fleet hardest:
/// a persistent aggressor (does shedding/migration stay weight-fair?)
/// and a synchronized burst (does a crash mid-burst lose anything?).
pub const CHAOS_SCENARIOS: [&str; 2] = ["heavy_hitter", "flash_crowd"];

/// Fault-plan axis. `none` is the control cell: it must behave exactly
/// like the plain cluster matrix and keeps the chaos checks honest.
pub const CHAOS_PLANS: [&str; 4] = ["none", "crash_recover", "brownout", "kv_squeeze"];

/// The scenario horizon at the given depth — fault times are placed as
/// fractions of it so quick and full runs exercise the same phases.
pub fn chaos_horizon(scenario: &str, quick: bool) -> f64 {
    cluster_scenario(scenario, quick)
        .unwrap_or_else(|| panic!("unknown chaos scenario {scenario}"))
        .duration
}

/// Build the named fault plan against a fleet. Times are fractions of
/// the trace horizon: damage lands after queues form and lifts with
/// enough trace left to observe re-convergence.
pub fn chaos_plan(name: &str, fleet: &Fleet, opts: &ClusterOpts, horizon: f64) -> Option<FaultPlan> {
    match name {
        "none" => Some(FaultPlan::none()),
        // Replica 0 — the big A100-80GB on hetero, the worst possible
        // loss — crashes at 25% and returns at 60% of the horizon.
        "crash_recover" => Some(FaultPlan::crash_recover(0, 0.25 * horizon, 0.6 * horizon)),
        // Same replica at half speed for the middle half of the run.
        "brownout" => Some(FaultPlan::brownout(0, 2.0, 0.2 * horizon, 0.7 * horizon)),
        // Reserve half the KV pool of the *smallest* replica (the last
        // spec on every built-in fleet), forcing preemption churn where
        // headroom is scarcest.
        "kv_squeeze" => {
            let r = fleet.len() - 1;
            let cfg = fleet.replicas[r].sim_config(&opts.base);
            let pool =
                (cfg.gpu.kv_token_capacity() as f64 * cfg.host.kv_fraction) as u64 / 16;
            Some(FaultPlan::kv_squeeze(r, (pool / 2) as u32, 0.2 * horizon, 0.7 * horizon))
        }
        _ => None,
    }
}

/// One chaos cell's verdict.
#[derive(Debug)]
pub struct ChaosCellVerdict {
    pub scenario: String,
    pub plan: String,
    pub fleet: String,
    pub router: String,
    pub migration: String,
    /// Primary drive label; the cell internally cross-checks the other
    /// drive, and CI additionally diffs digests across whole-matrix
    /// runs under each drive.
    pub drive: String,
    pub seed: u64,
    pub finished: usize,
    pub total: usize,
    pub shed: u64,
    pub migrated: u64,
    pub fault_transitions: u64,
    /// Max co-backlogged discrepancy measured from the last crash
    /// recovery onward (whole run when the plan has no crash).
    pub max_disc_post: f64,
    pub disc_bound: f64,
    pub digest: u64,
    pub violations: Vec<String>,
    pub notes: Vec<String>,
}

impl ChaosCellVerdict {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn key(&self) -> String {
        format!("{}/{}", self.scenario, self.plan)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("plan", self.plan.as_str())
            .set("fleet", self.fleet.as_str())
            .set("router", self.router.as_str())
            .set("migration", self.migration.as_str())
            .set("drive", self.drive.as_str())
            .set("seed", format!("0x{:016x}", self.seed))
            .set("finished", self.finished)
            .set("total", self.total)
            .set("shed", self.shed)
            .set("migrated", self.migrated)
            .set("fault_transitions", self.fault_transitions)
            .set("max_disc_post", self.max_disc_post)
            .set("disc_bound", self.disc_bound)
            .set("digest", format!("0x{:016x}", self.digest))
            .set("passed", self.passed())
            .set(
                "violations",
                Json::Arr(self.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            )
            .set("notes", Json::Arr(self.notes.iter().map(|v| Json::Str(v.clone())).collect()))
    }
}

/// Fault-plane invariant checks. Returns (violations, notes,
/// post-recovery max discrepancy).
pub fn check_chaos_run(
    trace: &Trace,
    res: &ClusterResult,
    plan: &FaultPlan,
) -> (Vec<String>, Vec<String>, f64) {
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    // Conservation modulo shed, request counts: every trace request is
    // either routed (and, after drain, finished) or shed at the gate —
    // never both, never neither.
    let shed = res.shed_count() as usize;
    if res.finished() + shed != trace.len() {
        violations.push(format!(
            "conservation: finished {} + shed {} != trace {}",
            res.finished(),
            shed,
            trace.len()
        ));
    }
    let routed_total: u64 = res.routed.iter().sum();
    if routed_total as usize + shed != trace.len() {
        violations.push(format!(
            "conservation: routed {} + shed {} != trace {}",
            routed_total,
            shed,
            trace.len()
        ));
    }

    // Conservation modulo shed, weighted service: per client, delivered
    // service equals offered demand minus the demand shed at the gate.
    // Rework (re-prefill after migration/preemption) is excluded from
    // service by the watermark, so this holds exactly.
    let mut demand: BTreeMap<ClientId, f64> = BTreeMap::new();
    for r in trace.requests.iter() {
        *demand.entry(r.client).or_insert(0.0) += r.weighted_tokens();
    }
    for (&c, &d) in &demand {
        let expect = d - res.shed_weighted_for(c);
        let s = res.service_total(c);
        if (s - expect).abs() > 1e-6 * expect.max(1.0) {
            violations.push(format!(
                "conservation: service[{c}] {s} != demand {d} - shed {} ",
                res.shed_weighted_for(c)
            ));
        }
    }

    // Survivor no-starvation: the standard cluster starvation check
    // (global service inside every over-window backlogged interval),
    // which the crash/brownout windows must not break.
    let window = super::cluster::cluster_starvation_window(trace);
    for c in res.ever_backlogged_clients() {
        for (s, e) in res.backlogged_intervals(c) {
            if e - s < window {
                continue;
            }
            if res.service_at(c, e) - res.service_at(c, s) <= 1e-9 {
                violations.push(format!(
                    "survivor starvation: {c} backlogged {:.1}s (≥{window:.1}s) with zero global service",
                    e - s
                ));
                break;
            }
        }
    }

    // Bounded post-recovery discrepancy: measured from the last crash
    // recovery so the (legitimately lopsided) downtime window doesn't
    // dominate the statistic.
    let max_disc_post = res.max_co_backlogged_diff_after(plan.last_recovery_at());
    let bound = cluster_disc_bound(trace);
    if max_disc_post > bound {
        violations.push(format!(
            "post-recovery discrepancy: max co-backlogged gap {max_disc_post:.0} > bound {bound:.0}"
        ));
    }

    // Migration × prediction-mode audit: after a fully drained run every
    // predicted-token admit receipt must have been settled — refunded on
    // the crash source (preempt/drain) and re-charged then corrected on
    // the destination. A receipt left outstanding is an admission charge
    // that was refunded never or twice.
    for (i, r) in res.outstanding_receipts.iter().enumerate() {
        if let Some(n) = r {
            if *n > 0 {
                violations.push(format!(
                    "receipts: replica {i} holds {n} unsettled admit receipts after drain"
                ));
            }
        }
    }

    if res.fault_transitions == 0 && !plan.is_empty() {
        violations.push("fault plane: plan is non-empty but no transition materialized".into());
    }
    if shed > 0 {
        notes.push(format!("shed {shed} requests at the admission gate"));
    }
    let migrated: u64 = res.migrated.iter().sum();
    if migrated > 0 {
        notes.push(format!("migrated {migrated} orphans"));
    }

    (violations, notes, max_disc_post)
}

/// Run one chaos cell under an explicit migration policy (the
/// negative-control fixture in `broken` passes `Drop` here). The cell
/// runs the primary drive twice (replay check) and the opposite drive
/// once (bit-exactness check) before applying the invariant suite.
pub fn run_chaos_cell_with(
    scenario_name: &str,
    plan_name: &str,
    migration: MigrationPolicy,
    opts: &ConformanceOpts,
) -> ChaosCellVerdict {
    let fleet = Fleet::hetero();
    let router = RouterKind::FairShare;
    let label = format!("chaos-{plan_name}@{}", fleet.name);
    let seed = derive_seed(opts.base_seed, scenario_name, &label);
    let trace = cluster_trace(scenario_name, fleet.len(), opts.quick, seed);
    let horizon = chaos_horizon(scenario_name, opts.quick);

    let base_opts = ClusterOpts::new(seed);
    let plan = chaos_plan(plan_name, &fleet, &base_opts, horizon)
        .unwrap_or_else(|| panic!("unknown chaos plan {plan_name}"));

    let run = |drive: DriveMode| {
        let copts = base_opts
            .clone()
            .with_drive(drive)
            .with_faults(plan.clone())
            .with_migration(migration);
        run_cluster(
            fleet.clone(),
            router.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &copts,
        )
    };
    let res = run(opts.drive);
    let replay = run(opts.drive);
    let cross = run(other_drive(opts.drive));

    let (mut violations, notes, max_disc_post) = check_chaos_run(&trace, &res, &plan);
    if res.fingerprint() != replay.fingerprint() {
        violations.push("determinism: chaos replay fingerprint diverged".to_string());
    }
    if res.digest() != cross.digest() {
        violations.push(format!(
            "drive equivalence: {} digest 0x{:016x} != {} digest 0x{:016x}",
            opts.drive.label(),
            res.digest(),
            other_drive(opts.drive).label(),
            cross.digest()
        ));
    }

    ChaosCellVerdict {
        scenario: scenario_name.to_string(),
        plan: plan_name.to_string(),
        fleet: res.fleet.clone(),
        router: res.router.clone(),
        migration: migration.label().to_string(),
        drive: opts.drive.label(),
        seed,
        finished: res.finished(),
        total: res.total_requests(),
        shed: res.shed_count(),
        migrated: res.migrated.iter().sum(),
        fault_transitions: res.fault_transitions,
        max_disc_post,
        disc_bound: cluster_disc_bound(&trace),
        digest: res.digest(),
        violations,
        notes,
    }
}

/// Run one chaos cell under the default (migrating) failover policy.
pub fn run_chaos_cell(
    scenario_name: &str,
    plan_name: &str,
    opts: &ConformanceOpts,
) -> ChaosCellVerdict {
    run_chaos_cell_with(scenario_name, plan_name, MigrationPolicy::Migrate, opts)
}

/// The full chaos matrix: scenarios × fault plans.
pub fn run_chaos_matrix(opts: &ConformanceOpts) -> Vec<ChaosCellVerdict> {
    let mut out = Vec::new();
    for scenario in CHAOS_SCENARIOS {
        for plan in CHAOS_PLANS {
            out.push(run_chaos_cell(scenario, plan, opts));
        }
    }
    out
}

/// Verdicts as one JSON document (the CI artifact).
pub fn chaos_matrix_to_json(opts: &ConformanceOpts, cells: &[ChaosCellVerdict]) -> Json {
    let failed = cells.iter().filter(|c| !c.passed()).count();
    Json::obj()
        .set("quick", opts.quick)
        .set("base_seed", opts.base_seed)
        .set("drive", opts.drive.label())
        .set("cells_total", cells.len())
        .set("cells_failed", failed)
        .set("cells", Json::Arr(cells.iter().map(|c| c.to_json()).collect()))
}

// `check_cluster_run` is intentionally NOT applied to faulted cells —
// its completeness clause (finished ≡ trace) is exactly what shedding
// relaxes. The control plan re-asserts it below to keep both harnesses
// aligned on healthy fleets.
#[cfg(test)]
mod tests {
    use super::super::cluster::check_cluster_run;
    use super::*;

    fn opts() -> ConformanceOpts {
        ConformanceOpts { quick: true, base_seed: 42, drive: DriveMode::Serial }
    }

    #[test]
    fn control_plan_matches_the_plain_cluster_contract() {
        let o = opts();
        let cell = run_chaos_cell("heavy_hitter", "none", &o);
        assert!(cell.passed(), "control cell failed: {:?}", cell.violations);
        assert_eq!(cell.fault_transitions, 0);
        assert_eq!(cell.shed, 0);
        assert_eq!(cell.migrated, 0);
        assert_eq!(cell.finished, cell.total);

        // The healthy cell must also satisfy the stricter plain-cluster
        // invariant suite verbatim.
        let fleet = Fleet::hetero();
        let seed = derive_seed(o.base_seed, "heavy_hitter", "chaos-none@hetero-80+2x40");
        let trace = cluster_trace("heavy_hitter", fleet.len(), true, seed);
        let res = run_cluster(
            fleet,
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &ClusterOpts::new(seed),
        );
        let (violations, _, _) = check_cluster_run(&trace, &res, true);
        assert!(violations.is_empty(), "{violations:?}");
    }

    #[test]
    fn crash_cell_migrates_and_converges() {
        let cell = run_chaos_cell("heavy_hitter", "crash_recover", &opts());
        assert!(cell.passed(), "crash cell failed: {:?}", cell.violations);
        assert!(cell.fault_transitions > 0, "crash plan never materialized");
        assert!(cell.migrated > 0, "crash with queued work must migrate orphans");
    }

    #[test]
    fn every_plan_builds_for_every_builtin_fleet() {
        let o = ClusterOpts::new(1);
        for fleet in [Fleet::solo(), Fleet::homogeneous(4), Fleet::hetero(), Fleet::skewed(3)] {
            for plan in CHAOS_PLANS {
                let p = chaos_plan(plan, &fleet, &o, 20.0).unwrap();
                // crash plans need a survivor; solo fleets only accept
                // non-crash plans.
                if fleet.len() > 1 || plan != "crash_recover" {
                    p.validate(fleet.len()).unwrap();
                }
            }
        }
        assert!(chaos_plan("no_such_plan", &Fleet::hetero(), &o, 20.0).is_none());
    }

    #[test]
    fn kv_squeeze_reserves_a_nontrivial_share_of_the_pool() {
        let o = ClusterOpts::new(1);
        let fleet = Fleet::hetero();
        let plan = chaos_plan("kv_squeeze", &fleet, &o, 20.0).unwrap();
        match plan.events[0] {
            crate::cluster::FaultEvent::KvShrink { pages, replica, .. } => {
                assert_eq!(replica, fleet.len() - 1);
                assert!(pages > 100, "squeeze of {pages} pages is a no-op");
            }
            ref e => panic!("expected KvShrink, got {e:?}"),
        }
    }
}
