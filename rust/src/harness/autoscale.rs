//! Autoscale conformance cells: adversarial scenario × scale policy,
//! both drive modes, machine-checked elasticity invariants.
//!
//! The chaos matrix (`harness::chaos`) pins what survives deliberate
//! damage; this matrix pins what survives deliberate *elasticity*. Every
//! cell fixes the paper configuration (FairShare router over Equinox +
//! MoPE) on the minimal two-replica fleet and varies only the scenario
//! and the autoscale policy. Per cell:
//!
//! - **drive equivalence** — the digest is bit-identical between
//!   `DriveMode::Serial` and `DriveMode::Parallel` under every policy.
//!   Scale transitions materialize only at barrier boundaries, so this
//!   is the autoscaler's headline determinism claim.
//! - **deterministic replay** — re-running the primary drive reproduces
//!   the fingerprint exactly (reactive decisions included: the backlog
//!   signal is a pure function of barrier-time state).
//! - **conservation across drains** — scale-in retires replicas through
//!   the orphan-migration path, so nothing is lost: finished ≡ trace,
//!   Σ routed ≡ trace, and per client delivered service ≡ offered
//!   demand, exactly, across every grow/drain the policy performs.
//! - **epoch ledger** — `fleet_epochs` opens at t=0 with the construction
//!   fleet, advances monotonically, and every consecutive pair differs
//!   in composition; `scale_transitions` counts at least one action per
//!   recorded epoch change. `off` cells record exactly one epoch.

use super::cluster::{cluster_scenario, cluster_trace};
use super::{derive_seed, ConformanceOpts};
use crate::cluster::{
    run_cluster, AutoscalePolicy, ClusterOpts, ClusterResult, DriveMode, Fleet, ReactivePolicy,
    ReplicaSpec, RouterKind, ScaleEvent,
};
use crate::core::ClientId;
use crate::exp::{PredKind, SchedKind};
use crate::util::json::Json;
use crate::workload::Trace;
use std::collections::BTreeMap;

/// Scenario axis — the shapes that stress an autoscaler hardest: a
/// synchronized burst (does scale-out race the spike deterministically?)
/// and a persistent aggressor (does scale-in drain fairly under
/// sustained pressure?).
pub const AUTOSCALE_SCENARIOS: [&str; 2] = ["flash_crowd", "heavy_hitter"];

/// Policy axis. `off` is the control cell: it must behave exactly like
/// the plain cluster matrix on the same fleet and keeps the elasticity
/// checks honest.
pub const AUTOSCALE_POLICIES: [&str; 3] = ["off", "scheduled", "reactive"];

/// The scenario horizon at the given depth — scale times and controller
/// periods are placed as fractions of it so quick and full runs exercise
/// the same phases.
pub fn autoscale_horizon(scenario: &str, quick: bool) -> f64 {
    cluster_scenario(scenario, quick)
        .unwrap_or_else(|| panic!("unknown autoscale scenario {scenario}"))
        .duration
}

/// Build the named policy against the horizon. The scheduled plan grows
/// an A100-80GB at 30% and drains it at 80% — damage-free elasticity
/// with enough trace left to observe re-convergence. The reactive
/// controller evaluates on a 5%-of-horizon grid with hysteresis wide
/// enough that the flash-crowd spike forces a grow.
pub fn autoscale_policy(name: &str, horizon: f64) -> Option<AutoscalePolicy> {
    match name {
        "off" => Some(AutoscalePolicy::Off),
        "scheduled" => Some(AutoscalePolicy::Schedule(vec![
            ScaleEvent::grow(0.3 * horizon, ReplicaSpec::a100_80g()),
            ScaleEvent::shrink(0.8 * horizon),
        ])),
        "reactive" => Some(AutoscalePolicy::Reactive(
            ReactivePolicy::new(4.0, 1.0, ReplicaSpec::a100_80g())
                .with_bounds(2, 6)
                .with_eval_period(0.05 * horizon)
                .with_cooldown(0.1 * horizon),
        )),
        _ => None,
    }
}

/// One autoscale cell's verdict.
#[derive(Debug)]
pub struct AutoscaleCellVerdict {
    pub scenario: String,
    pub policy: String,
    pub fleet: String,
    pub router: String,
    /// Primary drive label; the cell internally cross-checks the other
    /// drive, and CI additionally diffs digests across whole-matrix
    /// runs under each drive.
    pub drive: String,
    pub seed: u64,
    pub finished: usize,
    pub total: usize,
    pub migrated: u64,
    pub scale_transitions: u64,
    pub epochs: usize,
    /// Final fleet size (non-retired replicas) after the run.
    pub final_replicas: usize,
    pub mean_gpu_util: f64,
    pub digest: u64,
    pub violations: Vec<String>,
    pub notes: Vec<String>,
}

impl AutoscaleCellVerdict {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn key(&self) -> String {
        format!("{}/{}", self.scenario, self.policy)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("policy", self.policy.as_str())
            .set("fleet", self.fleet.as_str())
            .set("router", self.router.as_str())
            .set("drive", self.drive.as_str())
            .set("seed", format!("0x{:016x}", self.seed))
            .set("finished", self.finished)
            .set("total", self.total)
            .set("migrated", self.migrated)
            .set("scale_transitions", self.scale_transitions)
            .set("epochs", self.epochs)
            .set("final_replicas", self.final_replicas)
            .set("mean_gpu_util", self.mean_gpu_util)
            .set("digest", format!("0x{:016x}", self.digest))
            .set("passed", self.passed())
            .set(
                "violations",
                Json::Arr(self.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            )
            .set("notes", Json::Arr(self.notes.iter().map(|v| Json::Str(v.clone())).collect()))
    }
}

/// Elasticity invariant checks. Returns (violations, notes).
pub fn check_autoscale_run(
    trace: &Trace,
    res: &ClusterResult,
    policy: &AutoscalePolicy,
) -> (Vec<String>, Vec<String>) {
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    // Conservation across drains, request counts: no admission gate and
    // no faults here, so EVERY trace request must finish — a drain that
    // loses an orphan shows up as a shortfall.
    if res.finished() != trace.len() {
        violations.push(format!(
            "conservation: finished {} != trace {}",
            res.finished(),
            trace.len()
        ));
    }
    let routed_total: u64 = res.routed.iter().sum();
    if routed_total as usize != trace.len() {
        violations
            .push(format!("conservation: routed {} != trace {}", routed_total, trace.len()));
    }
    if res.shed_count() != 0 {
        violations.push(format!("conservation: {} requests shed without a gate", res.shed_count()));
    }

    // Conservation across drains, weighted service: per client,
    // delivered service equals offered demand exactly. Rework
    // (re-prefill after a drain migration) is excluded by the watermark.
    let mut demand: BTreeMap<ClientId, f64> = BTreeMap::new();
    for r in trace.requests.iter() {
        *demand.entry(r.client).or_insert(0.0) += r.weighted_tokens();
    }
    for (&c, &d) in &demand {
        let s = res.service_total(c);
        if (s - d).abs() > 1e-6 * d.max(1.0) {
            violations.push(format!("conservation: service[{c}] {s} != demand {d}"));
        }
    }

    // Epoch ledger: opens at t=0, monotone, consecutive compositions
    // differ, and the action counter covers every recorded change.
    if res.fleet_epochs.is_empty() {
        violations.push("epochs: ledger is empty (construction epoch missing)".into());
    } else {
        if res.fleet_epochs[0].0 != 0.0 {
            violations
                .push(format!("epochs: first epoch at t={}, not 0", res.fleet_epochs[0].0));
        }
        for w in res.fleet_epochs.windows(2) {
            if w[1].0 < w[0].0 {
                violations.push(format!("epochs: time went backwards ({} -> {})", w[0].0, w[1].0));
            }
            let a: Vec<&str> = w[0].1.iter().map(|s| s.name).collect();
            let b: Vec<&str> = w[1].1.iter().map(|s| s.name).collect();
            if a == b {
                violations.push(format!("epochs: no-op epoch recorded at t={}", w[1].0));
            }
        }
    }
    let changes = res.fleet_epochs.len().saturating_sub(1) as u64;
    if res.scale_transitions < changes {
        violations.push(format!(
            "epochs: {} composition changes but only {} scale transitions",
            changes, res.scale_transitions
        ));
    }
    if policy.is_off() && res.scale_transitions != 0 {
        violations.push(format!(
            "policy off but {} scale transitions materialized",
            res.scale_transitions
        ));
    }

    if res.scale_transitions > 0 {
        notes.push(format!(
            "{} scale transitions over {} epochs",
            res.scale_transitions,
            res.fleet_epochs.len()
        ));
    }
    let migrated: u64 = res.migrated.iter().sum();
    if migrated > 0 {
        notes.push(format!("drains migrated {migrated} orphans"));
    }

    (violations, notes)
}

/// The drive to cross-check a cell against.
fn other_drive(d: DriveMode) -> DriveMode {
    match d {
        DriveMode::Serial => DriveMode::Parallel { threads: 2 },
        DriveMode::Parallel { .. } => DriveMode::Serial,
    }
}

/// Run one autoscale cell. The cell runs the primary drive twice
/// (replay check) and the opposite drive once (bit-exactness check)
/// before applying the invariant suite.
pub fn run_autoscale_cell(
    scenario_name: &str,
    policy_name: &str,
    opts: &ConformanceOpts,
) -> AutoscaleCellVerdict {
    let fleet = Fleet::minimal();
    let router = RouterKind::FairShare;
    let label = format!("autoscale-{policy_name}@{}", fleet.name);
    let seed = derive_seed(opts.base_seed, scenario_name, &label);
    let trace = cluster_trace(scenario_name, fleet.len(), opts.quick, seed);
    let horizon = autoscale_horizon(scenario_name, opts.quick);

    let policy = autoscale_policy(policy_name, horizon)
        .unwrap_or_else(|| panic!("unknown autoscale policy {policy_name}"));

    let run = |drive: DriveMode| {
        let copts = ClusterOpts::new(seed).with_drive(drive).with_autoscale(policy.clone());
        run_cluster(
            fleet.clone(),
            router.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &copts,
        )
    };
    let res = run(opts.drive);
    let replay = run(opts.drive);
    let cross = run(other_drive(opts.drive));

    let (mut violations, notes) = check_autoscale_run(&trace, &res, &policy);
    if res.fingerprint() != replay.fingerprint() {
        violations.push("determinism: autoscale replay fingerprint diverged".to_string());
    }
    if res.digest() != cross.digest() {
        violations.push(format!(
            "drive equivalence: {} digest 0x{:016x} != {} digest 0x{:016x}",
            opts.drive.label(),
            res.digest(),
            other_drive(opts.drive).label(),
            cross.digest()
        ));
    }

    let final_replicas =
        res.fleet_epochs.last().map(|(_, specs)| specs.len()).unwrap_or(fleet.len());
    AutoscaleCellVerdict {
        scenario: scenario_name.to_string(),
        policy: policy_name.to_string(),
        fleet: res.fleet.clone(),
        router: res.router.clone(),
        drive: opts.drive.label(),
        seed,
        finished: res.finished(),
        total: res.total_requests(),
        migrated: res.migrated.iter().sum(),
        scale_transitions: res.scale_transitions,
        epochs: res.fleet_epochs.len(),
        final_replicas,
        mean_gpu_util: res.mean_gpu_util(),
        digest: res.digest(),
        violations,
        notes,
    }
}

/// The full autoscale matrix: scenarios × policies.
pub fn run_autoscale_matrix(opts: &ConformanceOpts) -> Vec<AutoscaleCellVerdict> {
    let mut out = Vec::new();
    for scenario in AUTOSCALE_SCENARIOS {
        for policy in AUTOSCALE_POLICIES {
            out.push(run_autoscale_cell(scenario, policy, opts));
        }
    }
    out
}

/// Verdicts as one JSON document (the CI artifact).
pub fn autoscale_matrix_to_json(opts: &ConformanceOpts, cells: &[AutoscaleCellVerdict]) -> Json {
    let failed = cells.iter().filter(|c| !c.passed()).count();
    Json::obj()
        .set("quick", opts.quick)
        .set("base_seed", opts.base_seed)
        .set("drive", opts.drive.label())
        .set("cells_total", cells.len())
        .set("cells_failed", failed)
        .set("cells", Json::Arr(cells.iter().map(|c| c.to_json()).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> ConformanceOpts {
        ConformanceOpts { quick: true, base_seed: 42, drive: DriveMode::Serial }
    }

    #[test]
    fn off_cell_is_a_static_fleet() {
        let cell = run_autoscale_cell("heavy_hitter", "off", &opts());
        assert!(cell.passed(), "control cell failed: {:?}", cell.violations);
        assert_eq!(cell.scale_transitions, 0);
        assert_eq!(cell.epochs, 1);
        assert_eq!(cell.final_replicas, 2);
        assert_eq!(cell.finished, cell.total);
    }

    #[test]
    fn scheduled_cell_grows_then_drains() {
        let cell = run_autoscale_cell("flash_crowd", "scheduled", &opts());
        assert!(cell.passed(), "scheduled cell failed: {:?}", cell.violations);
        assert_eq!(cell.scale_transitions, 2, "grow + shrink must both apply");
        assert_eq!(cell.epochs, 3);
        assert_eq!(cell.final_replicas, 2, "the drained replica leaves the composition");
    }

    #[test]
    fn reactive_cell_scales_out_under_the_spike() {
        let cell = run_autoscale_cell("flash_crowd", "reactive", &opts());
        assert!(cell.passed(), "reactive cell failed: {:?}", cell.violations);
        assert!(
            cell.scale_transitions > 0,
            "an overloaded minimal fleet must trip the backlog controller"
        );
    }

    #[test]
    fn every_policy_builds_and_validates() {
        for name in AUTOSCALE_POLICIES {
            let p = autoscale_policy(name, 40.0).unwrap();
            p.validate().unwrap();
        }
        assert!(autoscale_policy("no_such_policy", 40.0).is_none());
    }
}
