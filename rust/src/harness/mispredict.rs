//! Misprediction-resilience conformance cells: adversarial scenario ×
//! prediction-fault plan × guard mitigation, both drive modes,
//! machine-checked calibration-guard invariants.
//!
//! `harness::chaos` pins what survives *infrastructure* damage; this
//! matrix pins what survives *information* damage — biased, drifting,
//! heavy-tailed, or blacked-out predictions feeding the proactive
//! fairness layer. Every cell fixes the fleet (homogeneous pair,
//! FairShare router, MoPE predictions) and varies three axes:
//!
//! - **scenario** — persistent aggressor, synchronized burst, and the
//!   LMSYS/ShareGPT trace mix;
//! - **plan** — a [`PredFaultPlan`] degradation (or the clean control);
//! - **mitigation** — raw Equinox, always-debiased Equinox, or the full
//!   hysteresis ladder.
//!
//! Per cell the harness checks deterministic replay, serial ≡ parallel
//! cluster digests *and* trace digests (degradation is keyed per
//! `(seed, request)`, so drive mode must not matter), conservation, a
//! bounded-discrepancy tripwire (degraded cells get a relaxed bound —
//! graceful degradation, not immunity), and drained admit receipts.
//! At matrix level: under the 2× bias plan the debiased scheduler must
//! achieve *strictly lower* `max_co_backlogged_diff` than raw wherever
//! bias measurably hurts raw, and the blackout × ladder cell must step
//! down to `ActualOnly` during the blackout and climb back to
//! `Predictive` once calibration returns (checked against
//! `GuardTransition` trace events and final guard health).

use super::cluster::{cluster_disc_bound, cluster_scenario, cluster_trace};
use super::{derive_seed, other_drive, ConformanceOpts};
use crate::cluster::{run_cluster, ClusterOpts, ClusterResult, DriveMode, Fleet, RouterKind};
use crate::core::ClientId;
use crate::exp::{PredKind, SchedKind};
use crate::obs::{EventKind, TraceCfg};
use crate::predictor::PredFaultPlan;
use crate::sched::{GuardMode, GuardPolicy};
use crate::util::json::Json;
use crate::workload::{tracegen, Trace};
use std::collections::BTreeMap;

/// Scenario axis: the two cluster stress shapes plus the real-trace mix
/// (predictions matter most when request shapes are heterogeneous).
pub const MISPREDICT_SCENARIOS: [&str; 3] = ["heavy_hitter", "flash_crowd", "trace_mix"];

/// Prediction-fault axis. `clean` is the control cell: it must behave
/// exactly like the plain cluster matrix and keeps the checks honest.
pub const MISPREDICT_PLANS: [&str; 5] = ["clean", "bias", "drift", "blackout", "heavy_tail"];

/// Mitigation axis: what stands between bad predictions and the
/// fairness counters.
pub const MISPREDICT_MITIGATIONS: [&str; 3] = ["raw", "debiased", "ladder"];

/// Fleet-wide finishes after the last fault segment lifts before the
/// strict recovered-to-`Predictive` check applies; a thinner tail can
/// only support the weaker left-`ActualOnly` check (recovery needs
/// completions to observe — the guard cannot recalibrate on silence).
const RECOVERY_MIN_FINISHES: usize = 120;

/// Fraction of the discrepancy bound below which a raw bias cell is
/// considered unhurt, making "debiased strictly beats raw" vacuous for
/// that scenario.
const BIAS_NOISE_FLOOR: f64 = 0.02;

/// The scenario horizon at the given depth — fault segments are placed
/// as fractions of it so quick and full runs exercise the same phases.
pub fn mispredict_horizon(scenario: &str, quick: bool) -> f64 {
    match scenario {
        // Mirrors the adversarial registry's trace_mix durations.
        "trace_mix" => {
            if quick {
                14.0
            } else {
                90.0
            }
        }
        _ => {
            cluster_scenario(scenario, quick)
                .unwrap_or_else(|| panic!("unknown mispredict scenario {scenario}"))
                .duration
        }
    }
}

/// Build the scenario trace. heavy_hitter/flash_crowd reuse the cluster
/// matrix generator verbatim; trace_mix has no `Scenario` entry, so it
/// applies the same `2.0 × fleet_len` rate scaling to the mixed
/// LMSYS/ShareGPT generator directly.
pub fn mispredict_trace(scenario: &str, fleet_len: usize, quick: bool, seed: u64) -> Trace {
    if scenario == "trace_mix" {
        let d = mispredict_horizon("trace_mix", quick);
        return tracegen::trace_mix(3, 0.8 * 2.0 * fleet_len as f64, d, seed);
    }
    cluster_trace(scenario, fleet_len, quick, seed)
}

/// Build the named prediction-fault plan. Times are fractions of the
/// trace horizon; the blackout lifts at 40% so well over half the run
/// remains for the ladder to observe clean completions and recover.
pub fn mispredict_plan(name: &str, horizon: f64, seed: u64) -> Option<PredFaultPlan> {
    let h = horizon;
    let plan = match name {
        "clean" => PredFaultPlan::none(),
        // Sustained 2× over-prediction for the whole run — the
        // debiased-strictly-beats-raw acceptance plan.
        "bias" => PredFaultPlan::bias_storm(2.0, 0.0, h),
        // Error grows with cluster time: ~2.8× by the end of the run.
        "drift" => PredFaultPlan::drift_ramp(2.0 / h, 0.1 * h, h),
        // MoPE regime 0 (short predictions) returns centroid garbage for
        // the window [10%, 40%] of the horizon.
        "blackout" => PredFaultPlan::regime_blackout(0, 0.1 * h, 0.4 * h),
        // 10% of requests mispredicted by 8× either way.
        "heavy_tail" => PredFaultPlan::heavy_tail(0.1, 8.0, 0.0, h),
        _ => return None,
    };
    Some(plan.with_seed(seed))
}

/// Map a mitigation label to its scheduler.
pub fn mitigation_sched(name: &str) -> Option<SchedKind> {
    match name {
        "raw" => Some(SchedKind::Equinox),
        "debiased" => Some(SchedKind::EquinoxGuarded(GuardPolicy::Debias)),
        "ladder" => Some(SchedKind::EquinoxGuarded(GuardPolicy::Ladder)),
        _ => None,
    }
}

/// One mispredict cell's verdict.
#[derive(Debug)]
pub struct MispredictCellVerdict {
    pub scenario: String,
    pub plan: String,
    pub mitigation: String,
    pub fleet: String,
    pub drive: String,
    pub seed: u64,
    pub finished: usize,
    pub total: usize,
    /// Whole-run max co-backlogged discrepancy.
    pub max_disc: f64,
    /// The bound applied to this cell (relaxed 2× for degraded plans).
    pub disc_bound: f64,
    /// `GuardTransition` events recorded across the fleet.
    pub guard_transitions: u64,
    /// A transition *to* `ActualOnly` appeared in the trace.
    pub engaged_actual_only: bool,
    /// Final per-replica guard modes (`None` for unguarded schedulers).
    pub final_modes: Vec<Option<u32>>,
    /// Fleet-wide finishes after the last fault segment lifted.
    pub post_fault_finishes: usize,
    pub digest: u64,
    pub trace_digest: u64,
    pub violations: Vec<String>,
    pub notes: Vec<String>,
}

impl MispredictCellVerdict {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn key(&self) -> String {
        format!("{}/{}/{}", self.scenario, self.plan, self.mitigation)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("plan", self.plan.as_str())
            .set("mitigation", self.mitigation.as_str())
            .set("fleet", self.fleet.as_str())
            .set("drive", self.drive.as_str())
            .set("seed", format!("0x{:016x}", self.seed))
            .set("finished", self.finished)
            .set("total", self.total)
            .set("max_disc", self.max_disc)
            .set("disc_bound", self.disc_bound)
            .set("guard_transitions", self.guard_transitions)
            .set("engaged_actual_only", self.engaged_actual_only)
            .set(
                "final_modes",
                Json::Arr(
                    self.final_modes
                        .iter()
                        .map(|m| match m {
                            Some(c) => Json::Str(GuardMode::from_code(*c).label().into()),
                            None => Json::Str("unguarded".into()),
                        })
                        .collect(),
                ),
            )
            .set("post_fault_finishes", self.post_fault_finishes)
            .set("digest", format!("0x{:016x}", self.digest))
            .set("trace_digest", format!("0x{:016x}", self.trace_digest))
            .set("passed", self.passed())
            .set(
                "violations",
                Json::Arr(self.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            )
            .set("notes", Json::Arr(self.notes.iter().map(|v| Json::Str(v.clone())).collect()))
    }
}

/// Cell-local invariant checks shared by the matrix and the chaos-audit
/// hook: conservation modulo shed, bounded discrepancy (degraded cells
/// get 2× slack — graceful degradation), and drained admit receipts.
/// Returns (violations, notes, max_disc).
pub fn check_mispredict_run(
    trace: &Trace,
    res: &ClusterResult,
    degraded: bool,
) -> (Vec<String>, Vec<String>, f64) {
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    // Conservation modulo shed (same clauses as the chaos matrix):
    // miscalibrated charges may distort *ordering*, never *existence*.
    let shed = res.shed_count() as usize;
    if res.finished() + shed != trace.len() {
        violations.push(format!(
            "conservation: finished {} + shed {} != trace {}",
            res.finished(),
            shed,
            trace.len()
        ));
    }
    let routed_total: u64 = res.routed.iter().sum();
    if routed_total as usize + shed != trace.len() {
        violations.push(format!(
            "conservation: routed {routed_total} + shed {shed} != trace {}",
            trace.len()
        ));
    }
    let mut demand: BTreeMap<ClientId, f64> = BTreeMap::new();
    for r in trace.requests.iter() {
        *demand.entry(r.client).or_insert(0.0) += r.weighted_tokens();
    }
    for (&c, &d) in &demand {
        let expect = d - res.shed_weighted_for(c);
        let s = res.service_total(c);
        if (s - expect).abs() > 1e-6 * expect.max(1.0) {
            violations.push(format!(
                "conservation: service[{c}] {s} != demand {d} - shed {}",
                res.shed_weighted_for(c)
            ));
        }
    }

    // Bounded discrepancy degradation: a degraded predictor may cost
    // fairness, but boundedly — the completion correction keeps counter
    // error transient, so the gap must stay under a relaxed tripwire.
    let max_disc = res.max_co_backlogged_diff();
    let bound = cluster_disc_bound(trace) * if degraded { 2.0 } else { 1.0 };
    if max_disc > bound {
        violations.push(format!(
            "discrepancy: max co-backlogged gap {max_disc:.0} > bound {bound:.0}"
        ));
    }

    // Receipt exactness (migration × prediction-mode audit): after a
    // fully drained run every predicted-token admit receipt must have
    // been consumed by its completion correction — an outstanding
    // receipt is a charge that was never settled.
    for (i, r) in res.outstanding_receipts.iter().enumerate() {
        if let Some(n) = r {
            if *n > 0 {
                violations.push(format!(
                    "receipts: replica {i} holds {n} unsettled admit receipts after drain"
                ));
            }
        }
    }

    if shed > 0 {
        notes.push(format!("shed {shed} requests at the admission gate"));
    }
    (violations, notes, max_disc)
}

/// Run one mispredict cell: primary drive twice (replay check), the
/// opposite drive once (cluster digest AND trace digest bit-exactness),
/// then the invariant suite plus the plan×mitigation-specific guard
/// checks.
pub fn run_mispredict_cell(
    scenario_name: &str,
    plan_name: &str,
    mitigation: &str,
    opts: &ConformanceOpts,
) -> MispredictCellVerdict {
    let fleet = Fleet::homogeneous(2);
    let router = RouterKind::FairShare;
    let label = format!("mispredict-{plan_name}+{mitigation}@{}", fleet.name);
    let seed = derive_seed(opts.base_seed, scenario_name, &label);
    let trace = mispredict_trace(scenario_name, fleet.len(), opts.quick, seed);
    let horizon = mispredict_horizon(scenario_name, opts.quick);
    let plan = mispredict_plan(plan_name, horizon, seed)
        .unwrap_or_else(|| panic!("unknown mispredict plan {plan_name}"));
    let sched = mitigation_sched(mitigation)
        .unwrap_or_else(|| panic!("unknown mitigation {mitigation}"));

    let run = |drive: DriveMode| {
        let copts = ClusterOpts::new(seed)
            .with_drive(drive)
            .with_pred_faults(plan.clone())
            .with_trace(TraceCfg::default());
        run_cluster(fleet.clone(), router.make(), sched, PredKind::Mope, &trace, &copts)
    };
    let res = run(opts.drive);
    let replay = run(opts.drive);
    let cross = run(other_drive(opts.drive));

    let degraded = !plan.is_empty();
    let (mut violations, mut notes, max_disc) = check_mispredict_run(&trace, &res, degraded);

    if res.fingerprint() != replay.fingerprint() {
        violations.push("determinism: mispredict replay fingerprint diverged".to_string());
    }
    if res.digest() != cross.digest() {
        violations.push(format!(
            "drive equivalence: {} digest 0x{:016x} != {} digest 0x{:016x}",
            opts.drive.label(),
            res.digest(),
            other_drive(opts.drive).label(),
            cross.digest()
        ));
    }
    let log = res.trace.as_ref().expect("tracing was enabled for this cell");
    let cross_log = cross.trace.as_ref().expect("tracing was enabled for this cell");
    let trace_digest = log.digest();
    if trace_digest != cross_log.digest() {
        violations.push(format!(
            "drive equivalence: trace digest 0x{trace_digest:016x} != 0x{:016x} \
             under {} — degradation is not drive-invariant",
            cross_log.digest(),
            other_drive(opts.drive).label()
        ));
    }

    // Guard telemetry from the trace + final health.
    let fault_end = plan.last_recovery_at();
    let mut guard_transitions = 0u64;
    let mut engaged_actual_only = false;
    let mut post_fault_finishes = 0usize;
    for ev in &log.events {
        match ev.kind {
            EventKind::GuardTransition { to, .. } => {
                guard_transitions += 1;
                if to == GuardMode::ActualOnly.code() {
                    engaged_actual_only = true;
                }
            }
            EventKind::Finish { .. } if ev.t >= fault_end => post_fault_finishes += 1,
            _ => {}
        }
    }
    let final_modes: Vec<Option<u32>> =
        res.guard_health.iter().map(|h| h.as_ref().map(|h| h.mode.code())).collect();

    // Guarded cells must expose guard health; raw cells must not.
    let guarded = mitigation != "raw";
    if guarded && final_modes.iter().any(|m| m.is_none()) {
        violations.push("guard: guarded scheduler reported no guard health".into());
    }
    if !guarded && guard_transitions > 0 {
        violations.push("guard: unguarded scheduler recorded guard transitions".into());
    }

    // The acceptance pair: blackout × ladder must engage ActualOnly and
    // recover. The strict recovered-to-Predictive clause applies when
    // the post-blackout tail carries enough completions to recalibrate;
    // a thin tail still must have left ActualOnly.
    if mitigation == "ladder" && plan_name == "blackout" {
        if !engaged_actual_only {
            violations.push(
                "ladder: blackout never drove the guard to ActualOnly (no GuardTransition to \
                 code 2 in trace)"
                    .into(),
            );
        }
        let strict = post_fault_finishes >= RECOVERY_MIN_FINISHES;
        if !strict {
            notes.push(format!(
                "thin post-blackout tail ({post_fault_finishes} finishes): recovery check \
                 relaxed to left-ActualOnly"
            ));
        }
        for (i, m) in final_modes.iter().enumerate() {
            let Some(code) = m else { continue };
            let mode = GuardMode::from_code(*code);
            if strict && mode != GuardMode::Predictive {
                violations.push(format!(
                    "ladder: replica {i} ended in {} after the blackout lifted \
                     ({post_fault_finishes} post-blackout finishes)",
                    mode.label()
                ));
            } else if !strict && mode == GuardMode::ActualOnly {
                violations.push(format!(
                    "ladder: replica {i} stuck in ActualOnly after the blackout lifted"
                ));
            }
        }
    }
    if guard_transitions > 0 {
        notes.push(format!("{guard_transitions} guard transitions in trace"));
    }

    MispredictCellVerdict {
        scenario: scenario_name.to_string(),
        plan: plan_name.to_string(),
        mitigation: mitigation.to_string(),
        fleet: res.fleet.clone(),
        drive: opts.drive.label(),
        seed,
        finished: res.finished(),
        total: res.total_requests(),
        max_disc,
        disc_bound: cluster_disc_bound(&trace) * if degraded { 2.0 } else { 1.0 },
        guard_transitions,
        engaged_actual_only,
        final_modes,
        post_fault_finishes,
        digest: res.digest(),
        trace_digest,
        violations,
        notes,
    }
}

/// "Debiased strictly beats raw under bias" for one scenario pair, or
/// `None` when it holds (or is vacuous because bias never measurably
/// hurt the raw scheduler).
pub fn bias_beat_violation(
    raw: &MispredictCellVerdict,
    debiased: &MispredictCellVerdict,
) -> Option<String> {
    let floor = BIAS_NOISE_FLOOR * raw.disc_bound;
    if raw.max_disc <= floor {
        return None;
    }
    if debiased.max_disc < raw.max_disc {
        return None;
    }
    Some(format!(
        "bias mitigation: {} debiased max_disc {:.0} !< raw {:.0}",
        raw.scenario, debiased.max_disc, raw.max_disc
    ))
}

/// Matrix-level checks that need cells from different mitigations.
pub fn check_mispredict_matrix(cells: &[MispredictCellVerdict]) -> Vec<String> {
    let mut violations = Vec::new();
    let find = |scenario: &str, plan: &str, mitigation: &str| {
        cells.iter().find(|c| {
            c.scenario == scenario && c.plan == plan && c.mitigation == mitigation
        })
    };
    for scenario in MISPREDICT_SCENARIOS {
        if let (Some(raw), Some(deb)) =
            (find(scenario, "bias", "raw"), find(scenario, "bias", "debiased"))
        {
            if let Some(v) = bias_beat_violation(raw, deb) {
                violations.push(v);
            }
        }
    }
    violations
}

/// The full mispredict matrix: scenarios × plans × mitigations.
pub fn run_mispredict_matrix(opts: &ConformanceOpts) -> Vec<MispredictCellVerdict> {
    let mut out = Vec::new();
    for scenario in MISPREDICT_SCENARIOS {
        for plan in MISPREDICT_PLANS {
            for mitigation in MISPREDICT_MITIGATIONS {
                out.push(run_mispredict_cell(scenario, plan, mitigation, opts));
            }
        }
    }
    out
}

/// Verdicts + matrix-level checks as one JSON document (the CI
/// artifact).
pub fn mispredict_matrix_to_json(
    opts: &ConformanceOpts,
    cells: &[MispredictCellVerdict],
) -> Json {
    let matrix_violations = check_mispredict_matrix(cells);
    let failed = cells.iter().filter(|c| !c.passed()).count();
    Json::obj()
        .set("quick", opts.quick)
        .set("base_seed", opts.base_seed)
        .set("drive", opts.drive.label())
        .set("cells_total", cells.len())
        .set("cells_failed", failed)
        .set("matrix_passed", matrix_violations.is_empty())
        .set(
            "matrix_violations",
            Json::Arr(matrix_violations.into_iter().map(Json::Str).collect()),
        )
        .set("cells", Json::Arr(cells.iter().map(|c| c.to_json()).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> ConformanceOpts {
        ConformanceOpts { quick: true, base_seed: 42, drive: DriveMode::Serial }
    }

    #[test]
    fn every_plan_builds_and_validates() {
        for plan in MISPREDICT_PLANS {
            let p = mispredict_plan(plan, 20.0, 7).unwrap();
            p.validate(3).unwrap();
            assert_eq!(p.is_empty(), plan == "clean");
        }
        assert!(mispredict_plan("no_such_plan", 20.0, 7).is_none());
        for m in MISPREDICT_MITIGATIONS {
            assert!(mitigation_sched(m).is_some());
        }
        assert!(mitigation_sched("no_such_mitigation").is_none());
    }

    #[test]
    fn trace_mix_scenario_materializes() {
        let t = mispredict_trace("trace_mix", 2, true, 42);
        assert!(!t.requests.is_empty());
        let horizon = mispredict_horizon("trace_mix", true);
        assert!(t.requests.iter().all(|r| r.arrival <= horizon));
    }

    #[test]
    fn control_cell_passes_with_silent_guard() {
        let cell = run_mispredict_cell("heavy_hitter", "clean", "raw", &opts());
        assert!(cell.passed(), "control cell failed: {:?}", cell.violations);
        assert_eq!(cell.finished, cell.total);
        assert_eq!(cell.guard_transitions, 0);
        assert!(cell.final_modes.iter().all(|m| m.is_none()));
    }

    #[test]
    fn blackout_ladder_engages_and_recovers() {
        let cell = run_mispredict_cell("heavy_hitter", "blackout", "ladder", &opts());
        assert!(cell.passed(), "blackout/ladder cell failed: {:?}", cell.violations);
        assert!(cell.engaged_actual_only, "ladder never reached ActualOnly");
        assert!(cell.guard_transitions >= 2, "engage + recover need ≥2 transitions");
        assert!(cell.final_modes.iter().all(|m| m.is_some()));
    }

    #[test]
    fn debiased_strictly_beats_raw_under_bias() {
        let o = opts();
        let raw = run_mispredict_cell("heavy_hitter", "bias", "raw", &o);
        let deb = run_mispredict_cell("heavy_hitter", "bias", "debiased", &o);
        assert!(raw.passed(), "raw bias cell failed: {:?}", raw.violations);
        assert!(deb.passed(), "debiased bias cell failed: {:?}", deb.violations);
        assert!(
            bias_beat_violation(&raw, &deb).is_none(),
            "debiased {:.0} must strictly beat raw {:.0}",
            deb.max_disc,
            raw.max_disc
        );
    }
}
