//! Cluster conformance cells: router × fleet × adversarial-scenario
//! matrix with machine-checked cluster-level invariants.
//!
//! Per cell (local scheduler fixed to Equinox + MoPE, the paper's
//! configuration):
//!
//! - **completeness** — every routed request finishes (drain mode), and
//!   Σ per-replica totals equals the trace size (no request lost or
//!   duplicated by routing).
//! - **global service conservation** — per client, the cross-replica
//!   *sum* of delivered service equals the client's offered demand
//!   (Σ replica service ≡ cluster service ≡ demand).
//! - **cluster no-starvation** — a client continuously backlogged on
//!   ANY replica beyond the starvation window must have received global
//!   service inside the interval (hard for `FairShare`).
//! - **cross-replica bounded co-backlogged discrepancy** — the merged
//!   (union-backlog, summed-service) pairwise gap stays under a loose
//!   3× tripwire over the single-engine bound (see
//!   [`cluster_disc_bound`]). Hard for `FairShare` (it claims
//!   fairness-aware placement); recorded as a note for
//!   `RoundRobin`/`JSQ`, which make no such claim — on a heterogeneous
//!   fleet RoundRobin may legitimately blow it, and that gap is exactly
//!   the cluster subsystem's motivating measurement.
//! - **deterministic replay** — the full cluster run (routing decisions,
//!   sync rounds, every replica engine) is bit-identical when re-run.
//!
//! The matrix axes follow the issue spec: {RoundRobin, JSQ, FairShare} ×
//! {homogeneous 4×A100-40GB, heterogeneous 80GB+2×40GB} ×
//! {heavy_hitter, flash_crowd, tenant_churn}.

use super::{derive_seed, disc_bound, ConformanceOpts};
use crate::cluster::{run_cluster, ClusterOpts, ClusterResult, Fleet, RouterKind};
use crate::core::ClientId;
use crate::exp::{PredKind, SchedKind};
use crate::util::json::Json;
use crate::workload::{generate, Scenario, Trace};
use std::collections::BTreeMap;

/// Router axis of the cluster matrix.
pub const ROUTERS: [RouterKind; 3] =
    [RouterKind::RoundRobin, RouterKind::JoinShortestQueue, RouterKind::FairShare];

/// Scenario axis.
pub const SCENARIOS: [&str; 3] = ["heavy_hitter", "flash_crowd", "tenant_churn"];

/// The named single-engine scenario at cluster-cell durations (mirroring
/// the adversarial registry's quick/full depths).
pub fn cluster_scenario(name: &str, quick: bool) -> Option<Scenario> {
    let d = |q: f64, f: f64| if quick { q } else { f };
    match name {
        "heavy_hitter" => Some(Scenario::heavy_hitter(4, d(14.0, 60.0))),
        "flash_crowd" => Some(Scenario::flash_crowd(d(16.0, 80.0))),
        "tenant_churn" => Some(Scenario::tenant_churn(6, d(16.0, 90.0))),
        "constant_overload" => Some(Scenario::constant_overload(d(10.0, 40.0))),
        "balanced_load" => Some(Scenario::balanced_load(d(12.0, 60.0))),
        _ => None,
    }
}

/// Cluster-scale trace: the scenario's arrival intensity multiplied by
/// 2× the fleet size, so per-replica offered load is comparable to (and
/// transiently above) what the single-engine matrix runs — an N-replica
/// fleet tested at 1-replica load would leave every router unbacklogged
/// and every invariant vacuous.
pub fn cluster_trace(name: &str, fleet_len: usize, quick: bool, seed: u64) -> Trace {
    let sc = cluster_scenario(name, quick)
        .unwrap_or_else(|| panic!("unknown cluster scenario {name}"));
    generate(&sc.scale_rates(2.0 * fleet_len.max(1) as f64), seed)
}

/// Cluster discrepancy tripwire: the single-engine bound with 3×
/// routing slack. Deliberately generous — co-backlog is measured as the
/// cross-replica UNION (windows persist while the client queues on any
/// replica) and service as the global sum, and the cells run at 2×-per-
/// replica overload, all of which widen transients without implying
/// unfair placement. A router that genuinely starves a tenant
/// accumulates a gap near the whole co-backlogged service (≈ 0.85× the
/// trace demand on heavy_hitter), far above this bound; the sharp
/// fairness signal is the hard no-starvation check plus the strict
/// FairShare-below-RoundRobin comparison in `tests/cluster.rs`.
pub fn cluster_disc_bound(trace: &Trace) -> f64 {
    3.0 * disc_bound(trace)
}

/// Cluster no-starvation window — same as the single-engine harness.
pub fn cluster_starvation_window(trace: &Trace) -> f64 {
    super::starvation_window(trace)
}

/// Fleet axis.
pub fn fleets() -> Vec<Fleet> {
    vec![Fleet::homogeneous(4), Fleet::hetero()]
}

/// Which routers claim the cross-replica fairness contract (hard
/// discrepancy bound). The others get notes.
pub fn expects_cluster_fairness(kind: RouterKind) -> bool {
    matches!(kind, RouterKind::FairShare | RouterKind::PredictedCost)
}

/// One cluster cell's verdict.
#[derive(Debug)]
pub struct ClusterCellVerdict {
    pub scenario: String,
    pub fleet: String,
    pub router: String,
    /// Driver mode label the cell ran under. The digest must be
    /// identical across modes (serial ≡ parallel) — CI diffs it.
    pub drive: String,
    pub seed: u64,
    pub replicas: usize,
    pub finished: usize,
    pub total: usize,
    pub preemptions: u64,
    pub wall: f64,
    pub grand_service: f64,
    pub jain_service: f64,
    pub max_disc: f64,
    pub disc_bound: f64,
    pub syncs: u64,
    pub routed: Vec<u64>,
    pub digest: u64,
    pub violations: Vec<String>,
    pub notes: Vec<String>,
}

impl ClusterCellVerdict {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn key(&self) -> String {
        format!("{}/{}/{}", self.scenario, self.fleet, self.router)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("fleet", self.fleet.as_str())
            .set("router", self.router.as_str())
            .set("drive", self.drive.as_str())
            .set("seed", format!("0x{:016x}", self.seed))
            .set("replicas", self.replicas)
            .set("finished", self.finished)
            .set("total", self.total)
            .set("preemptions", self.preemptions)
            .set("wall", self.wall)
            .set("grand_service", self.grand_service)
            .set("jain_service", self.jain_service)
            .set("max_disc", self.max_disc)
            .set("disc_bound", self.disc_bound)
            .set("syncs", self.syncs)
            .set(
                "routed",
                Json::Arr(self.routed.iter().map(|&n| Json::Num(n as f64)).collect()),
            )
            .set("digest", format!("0x{:016x}", self.digest))
            .set("passed", self.passed())
            .set(
                "violations",
                Json::Arr(self.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            )
            .set("notes", Json::Arr(self.notes.iter().map(|v| Json::Str(v.clone())).collect()))
    }
}

/// Cluster-level invariant checks shared by every cell.
pub fn check_cluster_run(
    trace: &Trace,
    res: &ClusterResult,
    expect_fair: bool,
) -> (Vec<String>, Vec<String>, f64) {
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    // Completeness: nothing lost or duplicated by routing.
    if res.total_requests() != trace.len() {
        violations.push(format!(
            "routing: {} requests injected vs {} in trace",
            res.total_requests(),
            trace.len()
        ));
    }
    if res.finished() != res.total_requests() {
        violations.push(format!(
            "completeness: finished {}/{}",
            res.finished(),
            res.total_requests()
        ));
    }
    let routed_total: u64 = res.routed.iter().sum();
    if routed_total as usize != trace.len() {
        violations.push(format!("routing: routed {} of {} requests", routed_total, trace.len()));
    }

    // Global service conservation: Σ replica service ≡ cluster service ≡
    // per-client demand.
    let mut demand: BTreeMap<ClientId, f64> = BTreeMap::new();
    for r in trace.requests.iter() {
        *demand.entry(r.client).or_insert(0.0) += r.weighted_tokens();
    }
    let drained = res.finished() == res.total_requests();
    for (&c, &d) in &demand {
        let s = res.service_total(c);
        if s > d * (1.0 + 1e-9) + 1e-6 {
            violations.push(format!("conservation: service[{c}] {s} exceeds demand {d}"));
        } else if drained && (s - d).abs() > 1e-6 * d.max(1.0) {
            violations.push(format!("conservation: service[{c}] {s} != demand {d} after drain"));
        }
    }
    let total: f64 = demand.values().sum();
    let grand = res.grand_service();
    if drained && (grand - total).abs() > 1e-6 * total.max(1.0) {
        violations.push(format!("conservation: grand service {grand} != total demand {total}"));
    }

    // No starvation, cluster-wide: a client continuously backlogged
    // (anywhere) for longer than the window must have received some
    // GLOBAL service inside the interval. Hard for fairness-claiming
    // routers over fair local schedulers; note otherwise.
    let window = cluster_starvation_window(trace);
    for c in res.ever_backlogged_clients() {
        for (s, e) in res.backlogged_intervals(c) {
            if e - s < window {
                continue;
            }
            let gain = res.service_at(c, e) - res.service_at(c, s);
            if gain <= 1e-9 {
                let msg = format!(
                    "cluster starvation: {c} backlogged {:.1}s (≥{window:.1}s) with zero global service",
                    e - s
                );
                if expect_fair {
                    violations.push(msg);
                } else {
                    notes.push(msg);
                }
                break;
            }
        }
    }

    // Cross-replica bounded co-backlogged discrepancy.
    let max_disc = res.max_co_backlogged_diff();
    let bound = cluster_disc_bound(trace);
    if max_disc > bound {
        let msg = format!(
            "cluster discrepancy: max co-backlogged gap {max_disc:.0} > bound {bound:.0}"
        );
        if expect_fair {
            violations.push(msg);
        } else {
            notes.push(msg);
        }
    }

    (violations, notes, max_disc)
}

/// Run one cluster cell (with deterministic-replay verification).
pub fn run_cluster_cell(
    scenario_name: &str,
    fleet: Fleet,
    router: RouterKind,
    opts: &ConformanceOpts,
) -> ClusterCellVerdict {
    let label = format!("{}@{}", router.label(), fleet.name);
    let seed = derive_seed(opts.base_seed, scenario_name, &label);
    let trace = cluster_trace(scenario_name, fleet.len(), opts.quick, seed);
    // The drive mode never enters the seed or the trace: a cell's digest
    // is mode-independent by construction, which is what lets CI diff
    // serial vs parallel artifacts.
    let copts = ClusterOpts::new(seed).with_drive(opts.drive);

    let run = || {
        run_cluster(
            fleet.clone(),
            router.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &copts,
        )
    };
    let res = run();
    let replay = run();

    let expect_fair = expects_cluster_fairness(router);
    let (mut violations, notes, max_disc) = check_cluster_run(&trace, &res, expect_fair);
    if res.fingerprint() != replay.fingerprint() {
        violations.push("determinism: cluster replay fingerprint diverged".to_string());
    }

    ClusterCellVerdict {
        scenario: scenario_name.to_string(),
        fleet: res.fleet.clone(),
        router: res.router.clone(),
        drive: opts.drive.label(),
        seed,
        replicas: res.replicas.len(),
        finished: res.finished(),
        total: res.total_requests(),
        preemptions: res.preemptions(),
        wall: res.wall(),
        grand_service: res.grand_service(),
        jain_service: res.jain_over_service(),
        max_disc,
        disc_bound: cluster_disc_bound(&trace),
        syncs: res.syncs,
        routed: res.routed.clone(),
        digest: res.digest(),
        violations,
        notes,
    }
}

/// The full cluster matrix: scenarios × fleets × routers.
pub fn run_cluster_matrix(opts: &ConformanceOpts) -> Vec<ClusterCellVerdict> {
    let mut out = Vec::new();
    for scenario in SCENARIOS {
        for fleet in fleets() {
            for router in ROUTERS {
                out.push(run_cluster_cell(scenario, fleet.clone(), router, opts));
            }
        }
    }
    out
}

/// Verdicts as one JSON document (the CI artifact).
pub fn cluster_matrix_to_json(opts: &ConformanceOpts, cells: &[ClusterCellVerdict]) -> Json {
    let failed = cells.iter().filter(|c| !c.passed()).count();
    Json::obj()
        .set("quick", opts.quick)
        .set("base_seed", opts.base_seed)
        .set("drive", opts.drive.label())
        .set("meta", super::run_meta_json(opts, "cluster_matrix"))
        .set("cells_total", cells.len())
        .set("cells_failed", failed)
        .set("cells", Json::Arr(cells.iter().map(|c| c.to_json()).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axes_match_the_issue_spec() {
        assert_eq!(ROUTERS.len(), 3);
        assert_eq!(SCENARIOS.len(), 3);
        let fl = fleets();
        assert_eq!(fl.len(), 2);
        assert_eq!(fl[0].len(), 4, "homogeneous 4×A100-40GB");
        assert_eq!(fl[1].len(), 3, "hetero 80GB+2×40GB");
    }

    #[test]
    fn one_cluster_cell_runs_clean() {
        let opts = ConformanceOpts::default();
        let cell = run_cluster_cell("heavy_hitter", Fleet::hetero(), RouterKind::FairShare, &opts);
        assert!(cell.passed(), "{}: {:?}", cell.key(), cell.violations);
        assert_eq!(cell.finished, cell.total);
        assert!(cell.syncs > 0, "the global plane must have synced");
    }

    #[test]
    fn cluster_verdict_json_is_parseable() {
        let opts = ConformanceOpts::default();
        let cell =
            run_cluster_cell("flash_crowd", Fleet::homogeneous(4), RouterKind::JoinShortestQueue, &opts);
        let doc = cluster_matrix_to_json(&opts, &[cell]);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("cells_total").and_then(|v| v.as_u64()), Some(1));
        let arr = parsed.get("cells").and_then(|v| v.as_arr()).unwrap();
        assert!(arr[0].get("digest").is_some());
        assert!(arr[0].get("routed").is_some());
    }
}
