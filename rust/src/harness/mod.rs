//! The scheduler × workload conformance harness.
//!
//! Runs the full scheduler × adversarial-scenario × step-mode matrix and
//! machine-checks invariants after every run, emitting one compact JSON
//! verdict per cell:
//!
//! - **completeness / service conservation** — every request finishes
//!   (drain mode) and per-client delivered service equals the client's
//!   offered weighted-token demand; no client is credited more service
//!   than it asked for.
//! - **bounded discrepancy** (VTC, Sheng et al. OSDI'24 Thm 1; Equinox
//!   §3) — the max service gap between co-backlogged clients stays under
//!   a loose order-of-magnitude bound. The bound is deliberately generous
//!   (a regression tripwire, not the paper constant): a fair scheduler
//!   sits far below it, a broken one blows through it.
//! - **no starvation** — a client continuously backlogged longer than the
//!   starvation window must receive some service inside the interval.
//!   Hard for fairness-claiming schedulers; recorded as a note for
//!   FCFS/RPM (RPM's quota waits legitimately starve within a window —
//!   that waste is the paper's §1 critique, not a harness bug).
//! - **receipt accounting** — admission receipts ([`AdmitReceipt`]) must
//!   all be consumed by `on_complete`/`requeue`; a drained run with
//!   outstanding receipts means preemption refunds can double-bill.
//! - **macro ≡ micro** — the event-horizon macro-stepping engine must be
//!   a pure performance transformation of the per-token reference
//!   (tolerances from `tests/macro_stepping.rs`).
//! - **deterministic replay** — the same (scenario, scheduler, seed) cell
//!   re-run must be bit-identical (float fields compared by `to_bits`).
//!
//! Matrix cells use per-(scenario, scheduler) derived seeds
//! ([`derive_seed`]) so cells are independent: changing one scenario's
//! generator cannot shift the RNG stream of another cell.
//!
//! [`AdmitReceipt`]: crate::sched::AdmitReceipt

pub mod autoscale;
pub mod broken;
pub mod chaos;
pub mod cluster;
pub mod mispredict;
pub mod trace;

use crate::core::ClientId;
use crate::exp::{make_pred, make_sched, PredKind, SchedKind};
use crate::predictor::Predictor;
use crate::sched::Scheduler;
use crate::sim::{SimConfig, SimResult, Simulation, StepMode};
use crate::util::json::Json;
use crate::workload::adversarial::{self, AdvScenario};
use crate::workload::Trace;
use std::collections::BTreeMap;

/// Harness options.
#[derive(Debug, Clone)]
pub struct ConformanceOpts {
    /// Short traces (tier-1 tests, CI); full durations otherwise.
    pub quick: bool,
    /// Base seed; every cell derives its own from this plus its name.
    pub base_seed: u64,
    /// Cluster-cell driver execution mode (single-engine cells ignore
    /// it). Results are bit-exact across modes — CI runs the cluster
    /// matrix under both `Serial` and `Parallel{2}` and diffs digests.
    pub drive: crate::cluster::DriveMode,
}

impl Default for ConformanceOpts {
    fn default() -> Self {
        ConformanceOpts { quick: true, base_seed: 42, drive: crate::cluster::DriveMode::Serial }
    }
}

/// Run metadata shared with flight-recorder trace headers
/// ([`crate::obs::RunMeta`]): harness verdicts and trace files produced
/// by different CI jobs join on the same key set (schema, seed, drive,
/// threads).
pub fn run_meta_json(opts: &ConformanceOpts, scenario: &str) -> Json {
    let mut meta = crate::obs::RunMeta::new(opts.base_seed, scenario);
    match opts.drive {
        crate::cluster::DriveMode::Serial => {}
        crate::cluster::DriveMode::Parallel { threads } => {
            meta.drive = "parallel".into();
            meta.threads = threads;
        }
    }
    meta.to_json()
}

/// The scheduler axis of the matrix.
pub const SCHEDULERS: [SchedKind; 5] =
    [SchedKind::Fcfs, SchedKind::Rpm, SchedKind::Vtc, SchedKind::VtcPred, SchedKind::Equinox];

/// Both step modes — the full matrix.
pub const MODES: [StepMode; 2] = [StepMode::Micro, StepMode::Macro];

/// Which policies claim the bounded-discrepancy / no-starvation fairness
/// contract (hard invariants). FCFS and RPM make no such claim — their
/// fairness numbers are recorded as notes.
pub fn expects_bounded_fairness(kind: SchedKind) -> bool {
    matches!(
        kind,
        SchedKind::Vtc | SchedKind::VtcPred | SchedKind::Equinox | SchedKind::EquinoxAlpha(_)
    )
}

fn pred_for(kind: SchedKind) -> PredKind {
    if kind == SchedKind::Equinox {
        PredKind::Mope
    } else {
        PredKind::Oracle
    }
}

pub fn mode_label(mode: StepMode) -> &'static str {
    match mode {
        StepMode::Micro => "micro",
        StepMode::Macro => "macro",
    }
}

/// Per-(scenario, scheduler) seed derivation: FNV-1a over the cell name
/// with a splitmix64 finaliser. Both step modes of a cell share the seed
/// (they must see the identical trace); different cells get independent
/// streams.
pub fn derive_seed(base: u64, scenario: &str, scheduler: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ base.wrapping_mul(0x1000_0000_01b3);
    for b in scenario.bytes().chain([b'/']).chain(scheduler.bytes()) {
        h = (h ^ b as u64).wrapping_mul(0x1000_0000_01b3);
    }
    // splitmix64 finaliser for avalanche.
    let mut z = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The drive mode to cross-check a cluster cell against (chaos and
/// mispredict cells run the primary drive twice and this one once).
pub fn other_drive(d: crate::cluster::DriveMode) -> crate::cluster::DriveMode {
    match d {
        crate::cluster::DriveMode::Serial => crate::cluster::DriveMode::Parallel { threads: 2 },
        crate::cluster::DriveMode::Parallel { .. } => crate::cluster::DriveMode::Serial,
    }
}

/// Discrepancy bound for a trace: deliberately loose (fair schedulers sit
/// ~an order of magnitude below; a starving scheduler accumulates a gap
/// proportional to the whole co-backlogged service, far above). See the
/// module docs — this is a tripwire, not the paper's theorem constant.
pub fn disc_bound(trace: &Trace) -> f64 {
    (0.25 * trace.total_weighted_tokens()).max(80_000.0)
}

/// No-starvation window: generous — half the trace horizon, at least 8 s.
pub fn starvation_window(trace: &Trace) -> f64 {
    (0.5 * trace.horizon).max(8.0)
}

/// One cell's machine-checked verdict.
#[derive(Debug)]
pub struct CellVerdict {
    pub scenario: String,
    pub scheduler: String,
    pub mode: &'static str,
    pub seed: u64,
    pub finished: usize,
    pub total: usize,
    pub preemptions: u64,
    pub iterations: u64,
    pub macro_steps: u64,
    pub wall: f64,
    pub grand_service: f64,
    pub jain_service: f64,
    /// Max co-backlogged pairwise service gap and the bound it was
    /// checked against (hard only for fairness-claiming schedulers).
    pub max_disc: f64,
    pub disc_bound: f64,
    /// Spread (max − min) of the scheduler's internal fairness scores
    /// over served clients, when the policy exposes one.
    pub score_spread: Option<f64>,
    /// Outstanding admission receipts after the run, when tracked.
    pub receipts: Option<usize>,
    /// Bit-exact run digest (deterministic-replay and golden keys).
    pub digest: u64,
    /// Hard invariant failures — a non-empty list fails the cell.
    pub violations: Vec<String>,
    /// Report-only observations (e.g. FCFS/RPM fairness numbers).
    pub notes: Vec<String>,
}

impl CellVerdict {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn key(&self) -> String {
        format!("{}/{}/{}", self.scenario, self.scheduler, self.mode)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("scheduler", self.scheduler.as_str())
            .set("mode", self.mode)
            .set("seed", format!("0x{:016x}", self.seed))
            .set("finished", self.finished)
            .set("total", self.total)
            .set("preemptions", self.preemptions)
            .set("iterations", self.iterations)
            .set("macro_steps", self.macro_steps)
            .set("wall", self.wall)
            .set("grand_service", self.grand_service)
            .set("jain_service", self.jain_service)
            .set("max_disc", self.max_disc)
            .set("disc_bound", self.disc_bound)
            .set("digest", format!("0x{:016x}", self.digest))
            .set("passed", self.passed())
            .set(
                "violations",
                Json::Arr(self.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            )
            .set("notes", Json::Arr(self.notes.iter().map(|v| Json::Str(v.clone())).collect()));
        if let Some(s) = self.score_spread {
            j = j.set("score_spread", s);
        }
        if let Some(r) = self.receipts {
            j = j.set("receipts_outstanding", r);
        }
        j
    }
}

/// Bit-exact fingerprint of a run: integer outcomes plus the raw bits of
/// every float aggregate. Two runs of the same cell must produce the
/// identical vector — the deterministic-replay invariant.
pub fn fingerprint(res: &SimResult) -> Vec<u64> {
    let mut v = vec![
        res.finished as u64,
        res.total_requests as u64,
        res.preemptions,
        res.iterations,
        res.iter_equiv,
        res.macro_steps,
        res.rework_live as u64,
        res.wall.to_bits(),
        res.output_tps.to_bits(),
        res.weighted_tps.to_bits(),
        res.gpu_util.to_bits(),
        res.latency.ttft_mean().to_bits(),
        res.latency.e2e_mean().to_bits(),
    ];
    for c in res.service.clients() {
        v.push(c.0 as u64);
        v.push(res.service.total(c).to_bits());
    }
    v
}

/// FNV-1a digest of a fingerprint — one u64 per run for golden files.
pub fn digest(res: &SimResult) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for word in fingerprint(res) {
        for byte in word.to_le_bytes() {
            h = (h ^ byte as u64).wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

/// Macro ≡ micro agreement: identical integer outcomes, float aggregates
/// within 1e-9 relative, windowed fairness within the one-token
/// ramp-vs-staircase band (the contract proven in
/// `tests/macro_stepping.rs`). Returns violation messages, empty on
/// agreement.
pub fn compare_modes(micro: &SimResult, mac: &SimResult) -> Vec<String> {
    let mut v = Vec::new();
    let mut fail = |msg: String| v.push(format!("macro≡micro: {msg}"));
    if micro.finished != mac.finished {
        fail(format!("finished {} vs {}", micro.finished, mac.finished));
    }
    if micro.total_requests != mac.total_requests {
        fail(format!("totals {} vs {}", micro.total_requests, mac.total_requests));
    }
    if micro.preemptions != mac.preemptions {
        fail(format!("preemptions {} vs {}", micro.preemptions, mac.preemptions));
    }
    if micro.iter_equiv != mac.iter_equiv {
        fail(format!("iter_equiv {} vs {}", micro.iter_equiv, mac.iter_equiv));
    }
    if !close(micro.wall, mac.wall, 1e-9) {
        fail(format!("wall {} vs {}", micro.wall, mac.wall));
    }
    if !close(micro.latency.ttft_mean(), mac.latency.ttft_mean(), 1e-9) {
        fail(format!("ttft_mean {} vs {}", micro.latency.ttft_mean(), mac.latency.ttft_mean()));
    }
    if !close(micro.latency.e2e_mean(), mac.latency.e2e_mean(), 1e-9) {
        fail(format!("e2e_mean {} vs {}", micro.latency.e2e_mean(), mac.latency.e2e_mean()));
    }
    if !close(micro.latency.e2e_p(0.99), mac.latency.e2e_p(0.99), 1e-9) {
        fail("e2e_p99 diverged".to_string());
    }
    let clients = micro.service.clients();
    if clients != mac.service.clients() {
        fail("client sets diverged".to_string());
    } else {
        for c in clients {
            let (sm, sa) = (micro.service.total(c), mac.service.total(c));
            if !close(sm, sa, 1e-9) {
                fail(format!("service[{c}] {sm} vs {sa}"));
            }
        }
    }
    if !close(micro.output_tps, mac.output_tps, 1e-9) {
        fail("output_tps diverged".to_string());
    }
    if !close(micro.weighted_tps, mac.weighted_tps, 1e-9) {
        fail("weighted_tps diverged".to_string());
    }
    if !close(micro.gpu_util, mac.gpu_util, 1e-6) {
        fail(format!("gpu_util {} vs {}", micro.gpu_util, mac.gpu_util));
    }
    if !close(micro.jain_over_service(), mac.jain_over_service(), 1e-9) {
        fail("jain(service) diverged".to_string());
    }
    let (jm, ja) = (micro.windowed_jain(10.0), mac.windowed_jain(10.0));
    if (jm - ja).abs() >= 0.05 {
        fail(format!("windowed jain {jm} vs {ja}"));
    }
    if micro.backlog_timeline.len() != mac.backlog_timeline.len() {
        fail("backlog window counts diverged".to_string());
    } else {
        for (i, ((_, bm), (_, ba))) in
            micro.backlog_timeline.iter().zip(mac.backlog_timeline.iter()).enumerate()
        {
            if bm[..] != ba[..] {
                fail(format!("backlog set diverged at window {i}"));
                break;
            }
        }
    }
    v
}

/// Run one (scheduler, mode) leg and capture post-run scheduler
/// introspection (receipts, fairness-score spread) that `SimResult`
/// cannot carry.
fn run_instrumented(
    cfg: &SimConfig,
    kind: SchedKind,
    mode: StepMode,
    trace: &Trace,
    seed: u64,
) -> (SimResult, Option<usize>, Option<f64>) {
    let peak = cfg.gpu.peak_decode_tps(64, 512);
    let mut sched = make_sched(kind, peak);
    let mut pred = make_pred(pred_for(kind), seed);
    let res = {
        let mut sim = Simulation::new(cfg.clone().with_step_mode(mode), sched.as_mut(), pred.as_mut());
        sim.run(trace)
    };
    let receipts = sched.outstanding_receipts();
    let spread = score_spread(sched.as_ref(), &res);
    (res, receipts, spread)
}

fn score_spread(sched: &dyn Scheduler, res: &SimResult) -> Option<f64> {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    let mut any = false;
    for c in res.service.clients() {
        if let Some(s) = sched.fairness_score(c) {
            lo = lo.min(s);
            hi = hi.max(s);
            any = true;
        }
    }
    if any {
        Some(hi - lo)
    } else {
        None
    }
}

/// Per-run invariant checks shared by every cell (and by the
/// broken-scheduler fixture). Returns (violations, notes, max_disc).
fn check_run(
    trace: &Trace,
    res: &SimResult,
    expect_fair: bool,
    receipts: Option<usize>,
) -> (Vec<String>, Vec<String>, f64) {
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    // Completeness: drain mode means every request must finish.
    if res.finished != res.total_requests {
        violations
            .push(format!("completeness: finished {}/{}", res.finished, res.total_requests));
    }
    if res.rework_live != 0 {
        violations.push(format!("rework watermarks leaked: {}", res.rework_live));
    }

    // Service conservation: per-client delivered service never exceeds
    // the client's offered weighted-token demand, and equals it (1e-6
    // relative) once everything finished.
    let mut demand: BTreeMap<ClientId, f64> = BTreeMap::new();
    for r in trace.requests.iter() {
        *demand.entry(r.client).or_insert(0.0) += r.weighted_tokens();
    }
    for (&c, &d) in &demand {
        let s = res.service.total(c);
        if s > d * (1.0 + 1e-9) + 1e-6 {
            violations.push(format!("conservation: service[{c}] {s} exceeds demand {d}"));
        } else if res.finished == res.total_requests && !close(s, d, 1e-6) {
            violations.push(format!("conservation: service[{c}] {s} != demand {d} after drain"));
        }
    }

    // Receipt accounting.
    if let Some(n) = receipts {
        if res.finished == res.total_requests && n != 0 {
            violations.push(format!("receipts: {n} outstanding after a drained run"));
        }
    }

    // No starvation: a continuously-backlogged client must progress
    // within the window. Hard for fairness-claiming schedulers.
    let window = starvation_window(trace);
    for c in res.ever_backlogged_clients() {
        for (s, e) in res.backlogged_intervals(c) {
            if e - s < window {
                continue;
            }
            let gain = res.service.curve(c).map(|cv| cv.at(e) - cv.at(s)).unwrap_or(0.0);
            if gain <= 1e-9 {
                let msg = format!(
                    "starvation: {c} backlogged {:.1}s (≥{window:.1}s) with zero service",
                    e - s
                );
                if expect_fair {
                    violations.push(msg);
                } else {
                    notes.push(msg);
                }
                break;
            }
        }
    }

    // Bounded discrepancy over co-backlogged windows.
    let max_disc = res.max_co_backlogged_diff();
    let bound = disc_bound(trace);
    if max_disc > bound {
        let msg = format!("discrepancy: max co-backlogged gap {max_disc:.0} > bound {bound:.0}");
        if expect_fair {
            violations.push(msg);
        } else {
            notes.push(msg);
        }
    }

    (violations, notes, max_disc)
}

fn build_verdict(
    sc_name: &str,
    sched_label: &str,
    mode: StepMode,
    seed: u64,
    trace: &Trace,
    res: &SimResult,
    expect_fair: bool,
    receipts: Option<usize>,
    spread: Option<f64>,
) -> CellVerdict {
    let (violations, notes, max_disc) = check_run(trace, res, expect_fair, receipts);
    CellVerdict {
        scenario: sc_name.to_string(),
        scheduler: sched_label.to_string(),
        mode: mode_label(mode),
        seed,
        finished: res.finished,
        total: res.total_requests,
        preemptions: res.preemptions,
        iterations: res.iterations,
        macro_steps: res.macro_steps,
        wall: res.wall,
        grand_service: res.service.grand_total(),
        jain_service: res.jain_over_service(),
        max_disc,
        disc_bound: disc_bound(trace),
        score_spread: spread,
        receipts,
        digest: digest(res),
        violations,
        notes,
    }
}

/// Run every scheduler over one scenario for the given step modes.
/// When both modes run, the macro cell additionally carries the
/// macro≡micro agreement verdict; the macro leg is always replayed for
/// the deterministic-replay invariant.
pub fn run_scenario_cells(
    sc: &AdvScenario,
    opts: &ConformanceOpts,
    modes: &[StepMode],
) -> Vec<CellVerdict> {
    let cfg = SimConfig::a100_7b_vllm();
    let mut out = Vec::new();
    for kind in SCHEDULERS {
        let label = kind.label();
        let seed = derive_seed(opts.base_seed, sc.name, &label);
        let trace = sc.trace(opts.quick, seed);
        let expect_fair = expects_bounded_fairness(kind);

        let mut micro_res: Option<SimResult> = None;
        let mut cell_results: Vec<(StepMode, SimResult, Option<usize>, Option<f64>)> = Vec::new();
        for &mode in modes {
            let (res, receipts, spread) = run_instrumented(&cfg, kind, mode, &trace, seed);
            cell_results.push((mode, res, receipts, spread));
        }
        for (mode, res, receipts, spread) in cell_results {
            let mut verdict = build_verdict(
                sc.name,
                &label,
                mode,
                seed,
                &trace,
                &res,
                expect_fair,
                receipts,
                spread,
            );
            match mode {
                StepMode::Micro => micro_res = Some(res),
                StepMode::Macro => {
                    // Deterministic replay: same cell, bit-identical run.
                    let (replay, _, _) = run_instrumented(&cfg, kind, mode, &trace, seed);
                    if fingerprint(&res) != fingerprint(&replay) {
                        verdict
                            .violations
                            .push("determinism: replay fingerprint diverged".to_string());
                    }
                    if let Some(micro) = &micro_res {
                        verdict.violations.extend(compare_modes(micro, &res));
                    }
                }
            }
            out.push(verdict);
        }
    }
    out
}

/// The full matrix: every registered scenario × every scheduler × the
/// given step modes.
pub fn run_matrix(opts: &ConformanceOpts, modes: &[StepMode]) -> Vec<CellVerdict> {
    let mut out = Vec::new();
    for sc in adversarial::registry() {
        out.extend(run_scenario_cells(&sc, opts, modes));
    }
    out
}

/// Verdicts as one JSON document (the CI artifact).
pub fn matrix_to_json(opts: &ConformanceOpts, cells: &[CellVerdict]) -> Json {
    let failed = cells.iter().filter(|c| !c.passed()).count();
    Json::obj()
        .set("quick", opts.quick)
        .set("base_seed", opts.base_seed)
        .set("meta", run_meta_json(opts, "matrix"))
        .set("cells_total", cells.len())
        .set("cells_failed", failed)
        .set("cells", Json::Arr(cells.iter().map(|c| c.to_json()).collect()))
}

/// Golden snapshot of the macro cells: integer outcomes plus the
/// bit-exact digest, keyed by cell. Regenerate with `GOLDEN_REGEN=1`
/// (tests) or `equinox conformance --regen` (CLI).
pub fn golden_from_cells(cells: &[CellVerdict]) -> Json {
    let mut m = BTreeMap::new();
    for c in cells.iter().filter(|c| c.mode == "macro") {
        m.insert(
            c.key(),
            Json::obj()
                .set("digest", format!("0x{:016x}", c.digest))
                .set("finished", c.finished)
                .set("total", c.total)
                .set("preemptions", c.preemptions)
                .set("iterations", c.iterations)
                .set("macro_steps", c.macro_steps),
        );
    }
    Json::obj().set("version", 1u64).set("cells", Json::Obj(m))
}

/// Diff freshly-run macro cells against a committed golden document.
/// Returns human-readable mismatch lines (empty = clean).
pub fn compare_golden(golden: &Json, cells: &[CellVerdict]) -> Vec<String> {
    let mut diffs = Vec::new();
    let Some(Json::Obj(gcells)) = golden.get("cells").cloned() else {
        return vec!["golden: missing 'cells' object".to_string()];
    };
    let mut seen = std::collections::BTreeSet::new();
    for c in cells.iter().filter(|c| c.mode == "macro") {
        let key = c.key();
        seen.insert(key.clone());
        let Some(g) = gcells.get(&key) else {
            diffs.push(format!("{key}: not in golden (new cell)"));
            continue;
        };
        let want_digest = g.get("digest").and_then(|v| v.as_str()).unwrap_or("");
        let got_digest = format!("0x{:016x}", c.digest);
        if want_digest != got_digest {
            diffs.push(format!("{key}: digest {got_digest} != golden {want_digest}"));
        }
        for (field, got) in [
            ("finished", c.finished as u64),
            ("total", c.total as u64),
            ("preemptions", c.preemptions),
            ("iterations", c.iterations),
            ("macro_steps", c.macro_steps),
        ] {
            if let Some(want) = g.get(field).and_then(|v| v.as_u64()) {
                if want != got {
                    diffs.push(format!("{key}: {field} {got} != golden {want}"));
                }
            }
        }
    }
    for key in gcells.keys() {
        if !seen.contains(key) {
            diffs.push(format!("{key}: in golden but not in this run (removed cell)"));
        }
    }
    diffs
}

/// Run one custom scheduler (e.g. a deliberately-broken fixture) through
/// a cell with fairness invariants enforced — the harness self-test path:
/// a policy that starves a tenant MUST fail here.
pub fn run_custom_cell(
    label: &str,
    sched: &mut dyn Scheduler,
    pred: &mut dyn Predictor,
    cfg: &SimConfig,
    sc_name: &str,
    trace: &Trace,
    seed: u64,
    expect_fair: bool,
) -> CellVerdict {
    let res = {
        let mut sim = Simulation::new(cfg.clone(), sched, pred);
        sim.run(trace)
    };
    let receipts = None;
    let spread = None;
    build_verdict(sc_name, label, cfg.step_mode, seed, trace, &res, expect_fair, receipts, spread)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_seeds_are_independent_and_stable() {
        let a = derive_seed(42, "flash_crowd", "VTC");
        let b = derive_seed(42, "flash_crowd", "FCFS");
        let c = derive_seed(42, "heavy_hitter", "VTC");
        let d = derive_seed(43, "flash_crowd", "VTC");
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
        assert_eq!(a, derive_seed(42, "flash_crowd", "VTC"));
        // Concatenation ambiguity is broken by the separator.
        assert_ne!(derive_seed(1, "ab", "c"), derive_seed(1, "a", "bc"));
    }

    #[test]
    fn matrix_axes_meet_the_acceptance_floor() {
        assert!(SCHEDULERS.len() >= 4, "≥4 schedulers required");
        assert!(crate::workload::adversarial::registry().len() >= 12, "≥12 scenarios required");
        assert_eq!(MODES.len(), 2, "both step modes required");
    }

    #[test]
    fn one_cell_runs_clean_end_to_end() {
        // Smoke: the smallest paper scenario through one fair scheduler,
        // both modes — everything downstream (tests/conformance.rs) leans
        // on this path.
        let sc = adversarial::find("balanced_load").unwrap();
        let opts = ConformanceOpts::default();
        let cells = run_scenario_cells(&sc, &opts, &[StepMode::Macro]);
        assert_eq!(cells.len(), SCHEDULERS.len());
        for c in &cells {
            assert!(c.passed(), "{}: {:?}", c.key(), c.violations);
            assert_eq!(c.finished, c.total);
            assert!(c.digest != 0);
        }
    }

    #[test]
    fn golden_roundtrip_detects_drift() {
        let sc = adversarial::find("balanced_load").unwrap();
        let opts = ConformanceOpts::default();
        let cells = run_scenario_cells(&sc, &opts, &[StepMode::Macro]);
        let golden = golden_from_cells(&cells);
        // Serialise → parse → compare: clean.
        let parsed = Json::parse(&golden.to_string()).unwrap();
        assert!(compare_golden(&parsed, &cells).is_empty());
        // Perturb one digest: detected.
        let mut tampered = cells;
        tampered[0].digest ^= 1;
        let diffs = compare_golden(&parsed, &tampered);
        assert_eq!(diffs.len(), 1);
        assert!(diffs[0].contains("digest"), "{diffs:?}");
    }

    #[test]
    fn verdict_json_is_parseable_and_keyed() {
        let sc = adversarial::find("equal_tokens").unwrap();
        let opts = ConformanceOpts::default();
        let cells = run_scenario_cells(&sc, &opts, &[StepMode::Macro]);
        let doc = matrix_to_json(&opts, &cells);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("cells_total").and_then(|v| v.as_u64()), Some(cells.len() as u64));
        let arr = parsed.get("cells").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(arr.len(), cells.len());
        assert!(arr[0].get("digest").is_some());
    }
}
