//! Deliberately-broken scheduler fixtures: harness self-tests. A
//! conformance harness that never fails proves nothing — these policies
//! violate the fairness contract by construction, and
//! `tests/conformance.rs` asserts the harness actually flags them.

use crate::core::{ClientId, Request};
use crate::exp::{make_pred, PredKind};
use crate::sched::{Actuals, ClientQueues, Scheduler};
use crate::sim::{HostProfile, SimConfig};
use crate::workload::{generate, Arrival, ArrivalProcess, ClientSpec, Scenario};

use super::{derive_seed, CellVerdict, ConformanceOpts};

/// Strict priority by client id, non-work-conserving: while the
/// lowest-id client has ANY queued work, nobody else is even considered
/// (and an infeasible head blocks the whole queue). Under sustained
/// overload this starves every other tenant for the full co-backlogged
/// period — the textbook fairness violation both the no-starvation and
/// bounded-discrepancy invariants exist to catch.
#[derive(Debug, Default)]
pub struct StrictPriority {
    queues: ClientQueues,
}

impl StrictPriority {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for StrictPriority {
    fn name(&self) -> &'static str {
        "strict-priority-broken"
    }

    fn enqueue(&mut self, req: Request, _now: f64) {
        self.queues.push_back(req);
    }

    fn pick(&mut self, _now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request> {
        // Only the lowest-id active client is ever considered.
        let mut lowest: Option<ClientId> = None;
        self.queues.for_each_active(&mut |c| {
            if lowest.is_none() {
                lowest = Some(c);
            }
        });
        let client = lowest?;
        let head = self.queues.head(client)?;
        if feasible(head) {
            self.queues.pop(client)
        } else {
            None
        }
    }

    fn requeue(&mut self, req: Request) {
        self.queues.push_front(req);
    }

    fn on_complete(&mut self, _req: &Request, _actual: &Actuals, _now: f64) {}

    fn queue_len(&self) -> usize {
        self.queues.len()
    }

    fn for_each_queued_client(&self, f: &mut dyn FnMut(ClientId)) {
        self.queues.for_each_active(f);
    }

    fn queued_client_count(&self) -> usize {
        self.queues.active_count()
    }
}

/// Run the broken fixture through the harness with fairness invariants
/// enforced, on a dedicated massively-oversubscribed duel: client 0
/// floods at many times the S-LoRA host's capacity, client 1 trickles.
/// Strict priority then serves client 0 exclusively for tens of
/// simulated seconds while client 1 sits backlogged with zero service —
/// an unambiguous starvation AND discrepancy violation. (A fair
/// scheduler on the same trace interleaves the two and passes; the
/// matrix covers that side via `constant_overload`/`heavy_hitter`.)
pub fn run_strict_priority_fixture(opts: &ConformanceOpts) -> CellVerdict {
    let duration = if opts.quick { 8.0 } else { 20.0 };
    let scenario = Scenario {
        name: "priority_flood_duel",
        clients: vec![
            // ~43.5k wtok/s offered — several times S-LoRA capacity.
            ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(40.0), 64, 256),
            ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(1.0), 64, 256),
        ],
        duration,
    };
    let seed = derive_seed(opts.base_seed, scenario.name, "strict-priority-broken");
    let trace = generate(&scenario, seed);
    // The memory-constrained S-LoRA profile guarantees the flood
    // saturates the host, so the co-backlogged period is far longer than
    // the starvation window.
    let cfg = SimConfig::a100_7b_vllm().with_host(HostProfile::SLORA);
    let mut sched = StrictPriority::new();
    let mut pred = make_pred(PredKind::Oracle, seed);
    super::run_custom_cell(
        "strict-priority-broken",
        &mut sched,
        pred.as_mut(),
        &cfg,
        scenario.name,
        &trace,
        seed,
        true, // the fixture CLAIMS fairness — the harness must refute it
    )
}

/// Negative control for the fault plane: a lossy failover. The
/// crash-recover chaos cell re-run with `MigrationPolicy::Drop` —
/// orphans on the downed replica are silently discarded instead of
/// migrated, and nothing is booked as shed. Conservation-modulo-shed
/// must flag it (finished + shed < trace, per-client service short of
/// demand − shed); `tests/chaos.rs` asserts the harness does. A chaos
/// harness that passed this fixture would be checking nothing.
pub fn run_lossy_failover_fixture(
    opts: &ConformanceOpts,
) -> crate::harness::chaos::ChaosCellVerdict {
    use crate::cluster::MigrationPolicy;
    crate::harness::chaos::run_chaos_cell_with(
        "heavy_hitter",
        "crash_recover",
        MigrationPolicy::Drop,
        opts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestId;

    fn req(id: u64, client: u32) -> Request {
        Request::new(RequestId(id), ClientId(client), 10, 10, 0.0)
    }

    #[test]
    fn strict_priority_ignores_other_clients() {
        let mut s = StrictPriority::new();
        s.enqueue(req(1, 1), 0.0);
        s.enqueue(req(2, 0), 0.0);
        s.enqueue(req(3, 0), 0.0);
        // Client 0 exists → client 1 is invisible.
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(0));
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(0));
        // Only once client 0 drains does client 1 run.
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(1));
    }

    #[test]
    fn strict_priority_blocks_on_infeasible_favored_head() {
        let mut s = StrictPriority::new();
        let mut big = req(1, 0);
        big.input_tokens = 10_000;
        s.enqueue(big, 0.0);
        s.enqueue(req(2, 1), 0.0);
        // Head-of-line blocking across clients: nothing runs.
        assert!(s.pick(0.0, &mut |r| r.input_tokens < 100).is_none());
    }
}
