//! Prompt feature extraction for the AOT-compiled MoPE experts —
//! mirrors `python/compile/corpus.py::extract_features` bit-for-bit so
//! the experts see at serving time exactly what they were trained on.

pub const N_FEATURES: usize = 7;

/// [1, ln(1+tokens), question, code, list, explain, short-answer].
pub fn extract(prompt: &str, input_tokens: u32) -> [f32; N_FEATURES] {
    let p = prompt.to_lowercase();
    let starts = |s: &str| p.starts_with(s);
    [
        1.0,
        (1.0 + input_tokens as f64).ln() as f32,
        if p.contains('?') || starts("what") || starts("why") || starts("how") || starts("is ") || starts("yes or no") {
            1.0
        } else {
            0.0
        },
        if p.contains("program") || p.contains("code") || p.contains("python") || p.contains("function") {
            1.0
        } else {
            0.0
        },
        if p.contains("list") || p.contains("step by step") || p.contains("tutorial") {
            1.0
        } else {
            0.0
        },
        if p.contains("explain") || p.contains("detail") || p.contains("essay") || p.contains("comparing") {
            1.0
        } else {
            0.0
        },
        if p.contains("define")
            || p.contains("translate")
            || p.contains("one sentence")
            || p.contains("yes or no")
            || p.contains("summarize")
        {
            1.0
        } else {
            0.0
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_and_length_terms() {
        let f = extract("hello world", 10);
        assert_eq!(f[0], 1.0);
        assert!((f[1] - (11.0f64).ln() as f32).abs() < 1e-6);
    }

    #[test]
    fn marker_detection_matches_python_rules() {
        assert_eq!(extract("what is rust?", 5)[2], 1.0);
        assert_eq!(extract("define rust.", 5)[2], 0.0);
        assert_eq!(extract("write a python program", 5)[3], 1.0);
        assert_eq!(extract("list 10 facts", 5)[4], 1.0);
        assert_eq!(extract("explain tcp in detail", 5)[5], 1.0);
        assert_eq!(extract("summarize tokyo", 5)[6], 1.0);
        assert_eq!(extract("summarize tokyo", 5)[2..6], [0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn case_insensitive() {
        assert_eq!(extract("EXPLAIN THIS", 5)[5], 1.0);
    }
}
