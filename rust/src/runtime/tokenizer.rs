//! Byte-pair-free tokenizer for TinyLM: hashed word-piece tokenization
//! into the model's 512-token vocabulary. Deterministic, reversible
//! enough for a demo (detokenization returns placeholder word ids).

const VOCAB: u32 = 512;
/// Reserved ids: 0 = pad, 1 = BOS, 2 = EOS.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
const RESERVED: u32 = 3;

/// FNV-1a hash of a word into the non-reserved vocab range.
fn hash_token(word: &str) -> i32 {
    let mut h = 0xcbf29ce484222325u64;
    for b in word.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (RESERVED + (h % (VOCAB as u64 - RESERVED as u64)) as u32) as i32
}

/// Tokenize a prompt: BOS + one token per whitespace-separated word.
pub fn encode(text: &str) -> Vec<i32> {
    let mut toks = vec![BOS];
    toks.extend(text.split_whitespace().map(hash_token));
    toks
}

/// Approximate token count of a prompt (for admission decisions).
pub fn count_tokens(text: &str) -> u32 {
    1 + text.split_whitespace().count() as u32
}

/// Render generated token ids as a placeholder string.
pub fn decode(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| {
            if t == EOS {
                "<eos>".to_string()
            } else {
                format!("w{t}")
            }
        })
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let a = encode("explain rust lifetimes in detail");
        let b = encode("explain rust lifetimes in detail");
        assert_eq!(a, b);
        assert_eq!(a[0], BOS);
        for &t in &a {
            assert!((0..512).contains(&t));
            assert!(t >= BOS);
        }
    }

    #[test]
    fn count_matches_encode() {
        let text = "a b c d";
        assert_eq!(count_tokens(text) as usize, encode(text).len());
    }

    #[test]
    fn different_words_usually_differ() {
        assert_ne!(hash_token("alpha"), hash_token("beta"));
    }

    #[test]
    fn decode_renders_eos() {
        assert!(decode(&[5, EOS]).contains("<eos>"));
    }
}
