//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO text,
//! compile once, execute many times. Adapted from
//! /opt/xla-example/src/bin/load_hlo.rs (see its README for the gotchas —
//! notably that HLO *text* is the interchange format).

use anyhow::{Context, Result};
use std::path::Path;

/// Process-wide PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.file_stem().unwrap().to_string_lossy().into_owned() })
    }
}

/// A compiled computation. All our artifacts are lowered with
/// `return_tuple=True`, so outputs arrive as one tuple literal.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        tuple.decompose_tuple().context("decomposing result tuple")
    }
}

/// Helpers for building literals from rust vectors.
pub fn lit_i32_1d(v: &[i32]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(v))
}

pub fn lit_i32_2d(v: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(v.len() == rows * cols, "shape mismatch");
    xla::Literal::vec1(v)
        .reshape(&[rows as i64, cols as i64])
        .context("reshape i32 2d")
}

pub fn lit_f32(v: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(v.len() == n, "shape mismatch: {} vs {:?}", v.len(), dims);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(v).reshape(&dims_i64).context("reshape f32")
}

pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().context("literal to f32 vec")
}
