//! Artifact manifest written by `python/compile/aot.py`: which HLO files
//! exist, their shape buckets, the model config, and MoPE metadata.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    /// prefill: (batch, seq); decode: (batch, max_seq); mope: (batch, _).
    pub batch: usize,
    pub seq: usize,
}

#[derive(Debug, Clone, Default)]
pub struct MopeInfo {
    pub n_features: usize,
    pub n_experts: usize,
    pub boundaries: Vec<u32>,
    pub router_accuracy: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub artifacts: Vec<ArtifactInfo>,
    pub mope: Option<MopeInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let m = j.get("model").context("manifest missing 'model'")?;
        let num = |o: &Json, k: &str| -> Result<usize> {
            o.get(k)
                .and_then(|v| v.as_u64())
                .map(|v| v as usize)
                .with_context(|| format!("manifest missing numeric '{k}'"))
        };
        let model = ModelInfo {
            name: m.get("name").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            vocab: num(m, "vocab")?,
            d_model: num(m, "d_model")?,
            n_layers: num(m, "n_layers")?,
            n_heads: num(m, "n_heads")?,
            head_dim: num(m, "head_dim")?,
            max_seq: num(m, "max_seq")?,
        };
        let mut artifacts = Vec::new();
        let mut mope = None;
        for a in j.get("artifacts").and_then(|v| v.as_arr()).context("manifest missing 'artifacts'")? {
            let kind = a.get("kind").and_then(|v| v.as_str()).unwrap_or("?").to_string();
            let name = a.get("name").and_then(|v| v.as_str()).unwrap_or("?").to_string();
            let path = dir.join(a.get("path").and_then(|v| v.as_str()).context("artifact missing path")?);
            let batch = a.get("batch").and_then(|v| v.as_u64()).unwrap_or(0) as usize;
            let seq = a
                .get("seq")
                .or_else(|| a.get("max_seq"))
                .and_then(|v| v.as_u64())
                .unwrap_or(0) as usize;
            if kind == "mope" {
                mope = Some(MopeInfo {
                    n_features: a.get("n_features").and_then(|v| v.as_u64()).unwrap_or(0) as usize,
                    n_experts: a.get("n_experts").and_then(|v| v.as_u64()).unwrap_or(0) as usize,
                    boundaries: a
                        .get("boundaries")
                        .and_then(|v| v.as_arr())
                        .map(|xs| xs.iter().filter_map(|x| x.as_u64()).map(|x| x as u32).collect())
                        .unwrap_or_default(),
                    router_accuracy: a.get("router_accuracy").and_then(|v| v.as_f64()).unwrap_or(0.0),
                });
            }
            artifacts.push(ArtifactInfo { name, path, kind, batch, seq });
        }
        Ok(Manifest { dir: dir.to_path_buf(), model, artifacts, mope })
    }

    /// Prefill artifact covering a prompt of `len` tokens (smallest
    /// bucket ≥ len).
    pub fn prefill_for(&self, len: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "prefill" && a.seq >= len)
            .min_by_key(|a| a.seq)
    }

    /// Decode artifact for a batch of `n` sequences.
    pub fn decode_for(&self, n: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "decode" && a.batch >= n)
            .min_by_key(|a| a.batch)
    }

    pub fn mope_artifact(&self) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.kind == "mope")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_manifest(dir: &Path) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        write!(
            f,
            r#"{{"model":{{"name":"tinylm","vocab":512,"d_model":128,"n_layers":4,"n_heads":4,"head_dim":32,"max_seq":384,"seed":0}},
"artifacts":[
 {{"name":"prefill_b1_s64","path":"prefill_b1_s64.hlo.txt","kind":"prefill","batch":1,"seq":64}},
 {{"name":"prefill_b1_s256","path":"prefill_b1_s256.hlo.txt","kind":"prefill","batch":1,"seq":256}},
 {{"name":"decode_b2","path":"decode_b2.hlo.txt","kind":"decode","batch":2,"max_seq":384}},
 {{"name":"decode_b8","path":"decode_b8.hlo.txt","kind":"decode","batch":8,"max_seq":384}},
 {{"name":"mope","path":"mope.hlo.txt","kind":"mope","batch":8,"n_features":6,"n_experts":3,
   "boundaries":[53,210],"router_accuracy":0.8,"single_mae":80.0,"mope_mae":33.0}}
]}}"#
        )
        .unwrap();
    }

    #[test]
    fn parses_and_selects_buckets() {
        let dir = std::env::temp_dir().join(format!("eqx_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.vocab, 512);
        assert_eq!(m.prefill_for(10).unwrap().seq, 64);
        assert_eq!(m.prefill_for(65).unwrap().seq, 256);
        assert!(m.prefill_for(300).is_none());
        assert_eq!(m.decode_for(1).unwrap().batch, 2);
        assert_eq!(m.decode_for(3).unwrap().batch, 8);
        let mope = m.mope.unwrap();
        assert_eq!(mope.boundaries, vec![53, 210]);
        assert_eq!(mope.n_experts, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_friendly_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
