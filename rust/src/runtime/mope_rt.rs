//! Serving-path MoPE: runs the AOT-compiled expert matrix and applies the
//! threshold router (§6's online prediction path). One executable call
//! returns the generalist estimate plus every expert's estimate; the
//! router picks the expert whose regime contains the generalist estimate.

use super::manifest::{Manifest, MopeInfo};
use super::pjrt::{lit_f32, to_vec_f32, Executable, Runtime};
use anyhow::{Context, Result};

pub struct MopePredictor {
    exe: Executable,
    pub info: MopeInfo,
    batch: usize,
}

impl MopePredictor {
    pub fn load(rt: &Runtime, manifest: &Manifest) -> Result<MopePredictor> {
        let art = manifest.mope_artifact().context("manifest has no mope artifact")?;
        let info = manifest.mope.clone().context("manifest has no mope metadata")?;
        let exe = rt.load_hlo_text(&art.path)?;
        Ok(MopePredictor { exe, info, batch: art.batch })
    }

    /// Regime index for an estimated output length.
    pub fn regime_of(&self, est: f64) -> usize {
        self.info
            .boundaries
            .iter()
            .position(|&b| (est as u32) < b)
            .unwrap_or(self.info.boundaries.len())
    }

    /// Predict output tokens for up to `batch` feature vectors.
    pub fn predict(&self, features: &[[f32; super::features::N_FEATURES]]) -> Result<Vec<u32>> {
        anyhow::ensure!(!features.is_empty(), "empty feature batch");
        let f = self.info.n_features;
        anyhow::ensure!(f == super::features::N_FEATURES, "feature arity mismatch");
        let mut out = Vec::with_capacity(features.len());
        for chunk in features.chunks(self.batch) {
            // Pad the batch to the compiled bucket.
            let mut flat = vec![0f32; self.batch * f];
            for (i, feat) in chunk.iter().enumerate() {
                flat[i * f..(i + 1) * f].copy_from_slice(feat);
            }
            flat.iter_mut().skip(chunk.len() * f).step_by(f).for_each(|x| *x = 1.0);
            let lit = lit_f32(&flat, &[self.batch, f])?;
            let res = self.exe.run(&[lit])?;
            let preds = to_vec_f32(&res[0])?; // [batch, 1+E]
            let cols = 1 + self.info.n_experts;
            for i in 0..chunk.len() {
                let row = &preds[i * cols..(i + 1) * cols];
                let router_est = row[0] as f64;
                let expert = self.regime_of(router_est).min(self.info.n_experts - 1);
                out.push((row[1 + expert].round() as u32).clamp(1, 1024));
            }
        }
        Ok(out)
    }
}
