//! Real-model runtime: loads the AOT-compiled HLO text artifacts
//! (`make artifacts`) and serves TinyLM through the PJRT CPU client.
//! Python never runs on this path — the artifacts are self-contained
//! (weights lowered as constants).

pub mod engine;
pub mod features;
pub mod manifest;
pub mod mope_rt;
pub mod pjrt;
pub mod tokenizer;

pub use engine::{EngineConfig, ServeEngine};
pub use manifest::Manifest;
pub use pjrt::{Executable, Runtime};
