//! TinyLM serving engine: slot-based continuous batching over the
//! AOT-compiled prefill/decode executables. This is the "GPU" the real
//! coordinator path drives — per-request prefill into a KV slot, then one
//! batched decode step per engine iteration, mirroring the simulator's
//! iteration structure on real numerics.

use super::manifest::Manifest;
use super::pjrt::{lit_f32, lit_i32_1d, lit_i32_2d, to_vec_f32, Executable, Runtime};
use super::tokenizer;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifact_dir: std::path::PathBuf,
    /// Stop decoding a sequence when it emits EOS.
    pub stop_on_eos: bool,
}

impl EngineConfig {
    pub fn new(dir: impl Into<std::path::PathBuf>) -> Self {
        EngineConfig { artifact_dir: dir.into(), stop_on_eos: false }
    }
}

/// One resident sequence.
#[derive(Debug, Clone)]
struct Slot {
    /// Tokens in the KV cache (prompt + generated so far).
    context_len: usize,
    generated: Vec<i32>,
    max_new: usize,
    last_token: i32,
    done: bool,
}

/// Step outcome for one sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepEvent {
    pub slot: usize,
    pub token: i32,
    pub finished: bool,
}

pub struct ServeEngine {
    pub manifest: Manifest,
    prefills: BTreeMap<usize, Executable>, // seq bucket → exe
    decode: Executable,
    batch: usize,
    max_seq: usize,
    /// Flattened caches [L, B, H, T, D].
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
    slots: Vec<Option<Slot>>,
    dims: (usize, usize, usize, usize), // (L, H, T, D)
}

impl ServeEngine {
    pub fn new(rt: &Runtime, cfg: &EngineConfig) -> Result<ServeEngine> {
        let manifest = Manifest::load(&cfg.artifact_dir)?;
        let mut prefills = BTreeMap::new();
        for a in manifest.artifacts.iter().filter(|a| a.kind == "prefill") {
            prefills.insert(a.seq, rt.load_hlo_text(&a.path)?);
        }
        anyhow::ensure!(!prefills.is_empty(), "no prefill artifacts");
        let decode_art = manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == "decode")
            .max_by_key(|a| a.batch)
            .context("no decode artifacts")?;
        let decode = rt.load_hlo_text(&decode_art.path)?;
        let batch = decode_art.batch;
        let m = &manifest.model;
        let (l, h, t, d) = (m.n_layers, m.n_heads, m.max_seq, m.head_dim);
        let cache_len = l * batch * h * t * d;
        Ok(ServeEngine {
            max_seq: t,
            k_cache: vec![0.0; cache_len],
            v_cache: vec![0.0; cache_len],
            slots: (0..batch).map(|_| None).collect(),
            dims: (l, h, t, d),
            prefills,
            decode,
            batch,
            manifest,
        })
    }

    pub fn capacity(&self) -> usize {
        self.batch
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    pub fn occupied(&self) -> usize {
        self.batch - self.free_slots()
    }

    /// Whether a prompt of `len` tokens can currently be admitted.
    pub fn can_admit(&self, len: usize, max_new: usize) -> bool {
        self.free_slots() > 0
            && self.prefills.keys().any(|&b| b >= len)
            && len + max_new <= self.max_seq
    }

    #[inline]
    fn cache_index(&self, l: usize, b: usize, h: usize, t: usize) -> usize {
        let (_, nh, nt, nd) = self.dims;
        (((l * self.batch + b) * nh + h) * nt + t) * nd
    }

    /// Prefill a prompt into a free slot; returns (slot, first_token).
    /// The first output token is sampled greedily from the last prompt
    /// position's logits — this is the TTFT moment.
    pub fn add_request(&mut self, prompt_tokens: &[i32], max_new: usize) -> Result<(usize, i32)> {
        let len = prompt_tokens.len();
        anyhow::ensure!(len > 0, "empty prompt");
        anyhow::ensure!(len + max_new <= self.max_seq, "prompt + output exceeds max_seq");
        let slot_id = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .context("no free slot")?;
        let (&bucket, exe) = self
            .prefills
            .range(len..)
            .next()
            .with_context(|| format!("prompt of {len} tokens exceeds largest prefill bucket"))?;

        // Right-pad to the bucket.
        let mut padded = prompt_tokens.to_vec();
        padded.resize(bucket, tokenizer::PAD);
        let tokens = lit_i32_2d(&padded, 1, bucket)?;
        let outs = exe.run(&[tokens])?;
        // Outputs: logits [1, bucket, vocab], k [L,1,H,bucket,D], v same.
        let vocab = self.manifest.model.vocab;
        let logits = to_vec_f32(&outs[0])?;
        let last = &logits[(len - 1) * vocab..len * vocab];
        let first_token = argmax(last);

        let k = to_vec_f32(&outs[1])?;
        let v = to_vec_f32(&outs[2])?;
        let (nl, nh, _, nd) = self.dims;
        for l in 0..nl {
            for h in 0..nh {
                for t in 0..len {
                    let src = ((l * nh + h) * bucket + t) * nd;
                    let dst = self.cache_index(l, slot_id, h, t);
                    self.k_cache[dst..dst + nd].copy_from_slice(&k[src..src + nd]);
                    self.v_cache[dst..dst + nd].copy_from_slice(&v[src..src + nd]);
                }
            }
        }
        self.slots[slot_id] = Some(Slot {
            context_len: len,
            generated: vec![first_token],
            max_new,
            last_token: first_token,
            done: max_new <= 1,
        });
        Ok((slot_id, first_token))
    }

    /// One batched decode step for all live sequences. Returns the events
    /// (newly sampled tokens; `finished` sequences are freed).
    pub fn step(&mut self) -> Result<Vec<StepEvent>> {
        let live: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.as_ref().map(|x| !x.done).unwrap_or(false))
            .map(|(i, _)| i)
            .collect();
        if live.is_empty() {
            // Free any lingering done slots.
            self.reap();
            return Ok(Vec::new());
        }
        let mut tokens = vec![0i32; self.batch];
        let mut positions = vec![0i32; self.batch];
        for &i in &live {
            let s = self.slots[i].as_ref().unwrap();
            tokens[i] = s.last_token;
            positions[i] = s.context_len as i32; // write position of the new token
        }
        let (nl, nh, nt, nd) = self.dims;
        let cache_dims = [nl, self.batch, nh, nt, nd];
        let outs = self.decode.run(&[
            lit_i32_1d(&tokens)?,
            lit_i32_1d(&positions)?,
            lit_f32(&self.k_cache, &cache_dims)?,
            lit_f32(&self.v_cache, &cache_dims)?,
        ])?;
        let vocab = self.manifest.model.vocab;
        let logits = to_vec_f32(&outs[0])?; // [B, vocab]
        self.k_cache = to_vec_f32(&outs[1])?;
        self.v_cache = to_vec_f32(&outs[2])?;

        let mut events = Vec::with_capacity(live.len());
        for &i in &live {
            let tok = argmax(&logits[i * vocab..(i + 1) * vocab]);
            let s = self.slots[i].as_mut().unwrap();
            s.context_len += 1;
            s.generated.push(tok);
            s.last_token = tok;
            let eos = tok == tokenizer::EOS;
            if s.generated.len() >= s.max_new
                || s.context_len + 1 > nt
                || (eos && s.max_new > 0 && eos_enabled())
            {
                s.done = true;
            }
            events.push(StepEvent { slot: i, token: tok, finished: s.done });
        }
        self.reap();
        Ok(events)
    }

    /// Collected output tokens of a slot (valid until the slot is reaped).
    pub fn output_of(&self, slot: usize) -> Option<&[i32]> {
        self.slots.get(slot).and_then(|s| s.as_ref()).map(|s| s.generated.as_slice())
    }

    /// Free finished slots (zeroing their cache region is unnecessary —
    /// the decode kernel masks by length).
    fn reap(&mut self) {
        for s in self.slots.iter_mut() {
            if s.as_ref().map(|x| x.done).unwrap_or(false) {
                *s = None;
            }
        }
    }

    /// Run a single prompt to completion (convenience for examples).
    pub fn generate(&mut self, prompt_tokens: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let (slot, first) = self.add_request(prompt_tokens, max_new)?;
        let mut out = vec![first];
        while self.slots[slot].as_ref().map(|s| !s.done).unwrap_or(false) {
            for ev in self.step()? {
                if ev.slot == slot {
                    out.push(ev.token);
                }
            }
        }
        Ok(out)
    }
}

fn eos_enabled() -> bool {
    // EOS stopping is config-level; TinyLM's hashed tokenizer rarely emits
    // id 2, so default off keeps generation lengths deterministic for the
    // serving experiments.
    false
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
