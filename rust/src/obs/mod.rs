//! Deterministic flight recorder: typed request-lifecycle events captured
//! per replica, merged in (time, replica-id, seq) order at cluster
//! barriers, and digested with the same FNV-1a fold the rest of the
//! determinism machinery uses. The trace is bit-identical under
//! `DriveMode::Serial` and `DriveMode::Parallel` — `trace_digest` is a
//! strictly stronger cross-drive check than `ClusterResult::fingerprint()`
//! because it pins *every intermediate decision*, not just end-of-run
//! aggregates.
//!
//! Recording is opt-in: the engine holds a `Box<dyn Recorder>` that
//! defaults to [`NullRecorder`], whose methods are empty bodies — tracing
//! off means no hot-path allocations and no payload construction beyond
//! register work, preserving the `tests/scale.rs` allocation budget.
//! [`TraceRecorder`] is a bounded ring: it never allocates after
//! construction either; overflow overwrites the oldest event and bumps a
//! deterministic `dropped` counter.

pub mod export;

use crate::core::{ClientId, RequestId};
use crate::util::json::Json;

/// Trace schema version, bumped whenever `EventKind` payloads or the
/// digest fold change shape. Embedded in every header so artifacts from
/// different jobs are joinable (or refused) explicitly.
pub const TRACE_SCHEMA_VERSION: u32 = 2;

/// Replica id used for events emitted by the cluster driver itself
/// (routing, shedding, barriers) rather than by any one replica. Sorts
/// after all real replicas at equal timestamps.
pub const DRIVER_TRACK: u32 = u32::MAX;

/// Shared run metadata, embedded in trace headers and in the harness
/// matrix JSON so artifacts produced by different CI jobs join on the
/// same key set.
#[derive(Debug, Clone, PartialEq)]
pub struct RunMeta {
    pub schema: u32,
    pub seed: u64,
    /// Drive label: "serial" or "parallel".
    pub drive: String,
    /// Worker threads (1 under serial drive).
    pub threads: usize,
    /// Global-plane sync period in seconds (0 for single-sim runs).
    pub sync_period: f64,
    pub scenario: String,
    pub scheduler: String,
    pub router: String,
    pub fleet: String,
}

impl RunMeta {
    pub fn new(seed: u64, scenario: &str) -> Self {
        RunMeta {
            schema: TRACE_SCHEMA_VERSION,
            seed,
            drive: "serial".into(),
            threads: 1,
            sync_period: 0.0,
            scenario: scenario.into(),
            scheduler: String::new(),
            router: String::new(),
            fleet: String::new(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("schema", self.schema as u64)
            .set("seed", self.seed)
            .set("drive", self.drive.as_str())
            .set("threads", self.threads)
            .set("sync_period", self.sync_period)
            .set("scenario", self.scenario.as_str())
            .set("scheduler", self.scheduler.as_str())
            .set("router", self.router.as_str())
            .set("fleet", self.fleet.as_str())
    }
}

/// One typed trace event. All payloads are `Copy` — no strings, no heap —
/// so recording is register work and the ring never allocates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Request entered a replica's pending-arrival stream.
    Arrive { client: ClientId, req: RequestId },
    /// Router decision: request dispatched to replica `to`.
    Route { client: ClientId, req: RequestId, to: u32 },
    /// Scheduler admitted the request into the running batch.
    Admit { client: ClientId, req: RequestId, queued: u32 },
    /// Pick decision: chosen client's fairness score plus the best losing
    /// score among still-queued rivals (`rivals` = how many lost).
    Pick { client: ClientId, score: f64, rival: ClientId, rival_score: f64, rivals: u32 },
    /// First output token emitted (TTFT edge).
    FirstToken { client: ClientId, req: RequestId, ttft: f64 },
    /// Macro/micro step delivered `tokens` weighted service to a client.
    Progress { client: ClientId, tokens: f64, running: u32 },
    /// KV pressure evicted the request from the running batch.
    Preempt { client: ClientId, req: RequestId, kv_tokens: u64 },
    /// Preempted request re-entered its client queue.
    Requeue { client: ClientId, req: RequestId },
    /// Request completed; `e2e` is end-to-end latency. `predicted` and
    /// `actual` are output-token counts, so misprediction is auditable
    /// per request straight from the trace.
    Finish { client: ClientId, req: RequestId, e2e: f64, predicted: u32, actual: u32 },
    /// Orphan migrated off a dead replica onto `to`.
    Migrate { client: ClientId, req: RequestId, to: u32 },
    /// Admission control shed the request (weighted service recorded in
    /// the shed ledger).
    Shed { client: ClientId, req: RequestId, weighted: f64 },
    /// Per-sample-window counter snapshot for one backlogged client.
    Window { client: ClientId, score: f64 },
    /// Global-plane sync barrier completed (`syncs` = barrier ordinal).
    Sync { syncs: u64 },
    /// Fault transition materialized at a barrier for `replica`.
    Fault { code: u32, replica: u32 },
    /// Autoscale epoch boundary: fleet composition changed.
    ScaleEpoch { epoch: u32, alive: u32 },
    /// Calibration guard changed mode (codes from `GuardMode::code`);
    /// `err` is the worst seasoned EWMA |log-error| at the transition.
    GuardTransition { from: u32, to: u32, err: f64 },
}

impl EventKind {
    /// Stable discriminant for the digest fold and compact export.
    pub fn code(&self) -> u8 {
        match self {
            EventKind::Arrive { .. } => 0,
            EventKind::Route { .. } => 1,
            EventKind::Admit { .. } => 2,
            EventKind::Pick { .. } => 3,
            EventKind::FirstToken { .. } => 4,
            EventKind::Progress { .. } => 5,
            EventKind::Preempt { .. } => 6,
            EventKind::Requeue { .. } => 7,
            EventKind::Finish { .. } => 8,
            EventKind::Migrate { .. } => 9,
            EventKind::Shed { .. } => 10,
            EventKind::Window { .. } => 11,
            EventKind::Sync { .. } => 12,
            EventKind::Fault { .. } => 13,
            EventKind::ScaleEpoch { .. } => 14,
            EventKind::GuardTransition { .. } => 15,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EventKind::Arrive { .. } => "arrive",
            EventKind::Route { .. } => "route",
            EventKind::Admit { .. } => "admit",
            EventKind::Pick { .. } => "pick",
            EventKind::FirstToken { .. } => "first_token",
            EventKind::Progress { .. } => "progress",
            EventKind::Preempt { .. } => "preempt",
            EventKind::Requeue { .. } => "requeue",
            EventKind::Finish { .. } => "finish",
            EventKind::Migrate { .. } => "migrate",
            EventKind::Shed { .. } => "shed",
            EventKind::Window { .. } => "window",
            EventKind::Sync { .. } => "sync",
            EventKind::Fault { .. } => "fault",
            EventKind::ScaleEpoch { .. } => "scale_epoch",
            EventKind::GuardTransition { .. } => "guard",
        }
    }

    /// Payload words for the digest fold. Every field participates, f64s
    /// via `to_bits`, so two traces digest equal only if they are
    /// bit-identical event for event.
    pub fn payload(&self) -> [u64; 4] {
        match *self {
            EventKind::Arrive { client, req } => [client.0 as u64, req.0, 0, 0],
            EventKind::Route { client, req, to } => [client.0 as u64, req.0, to as u64, 0],
            EventKind::Admit { client, req, queued } => [client.0 as u64, req.0, queued as u64, 0],
            EventKind::Pick { client, score, rival, rival_score, rivals } => [
                (client.0 as u64) | ((rival.0 as u64) << 32),
                score.to_bits(),
                rival_score.to_bits(),
                rivals as u64,
            ],
            EventKind::FirstToken { client, req, ttft } => [client.0 as u64, req.0, ttft.to_bits(), 0],
            EventKind::Progress { client, tokens, running } => {
                [client.0 as u64, tokens.to_bits(), running as u64, 0]
            }
            EventKind::Preempt { client, req, kv_tokens } => [client.0 as u64, req.0, kv_tokens, 0],
            EventKind::Requeue { client, req } => [client.0 as u64, req.0, 0, 0],
            EventKind::Finish { client, req, e2e, predicted, actual } => {
                [client.0 as u64, req.0, e2e.to_bits(), ((predicted as u64) << 32) | actual as u64]
            }
            EventKind::Migrate { client, req, to } => [client.0 as u64, req.0, to as u64, 0],
            EventKind::Shed { client, req, weighted } => [client.0 as u64, req.0, weighted.to_bits(), 0],
            EventKind::Window { client, score } => [client.0 as u64, score.to_bits(), 0, 0],
            EventKind::Sync { syncs } => [syncs, 0, 0, 0],
            EventKind::Fault { code, replica } => [code as u64, replica as u64, 0, 0],
            EventKind::ScaleEpoch { epoch, alive } => [epoch as u64, alive as u64, 0, 0],
            EventKind::GuardTransition { from, to, err } => {
                [from as u64, to as u64, err.to_bits(), 0]
            }
        }
    }

    /// The request this event belongs to, if it is a lifecycle edge.
    pub fn request(&self) -> Option<RequestId> {
        match *self {
            EventKind::Arrive { req, .. }
            | EventKind::Route { req, .. }
            | EventKind::Admit { req, .. }
            | EventKind::FirstToken { req, .. }
            | EventKind::Preempt { req, .. }
            | EventKind::Requeue { req, .. }
            | EventKind::Finish { req, .. }
            | EventKind::Migrate { req, .. }
            | EventKind::Shed { req, .. } => Some(req),
            _ => None,
        }
    }

    pub fn client(&self) -> Option<ClientId> {
        match *self {
            EventKind::Arrive { client, .. }
            | EventKind::Route { client, .. }
            | EventKind::Admit { client, .. }
            | EventKind::Pick { client, .. }
            | EventKind::FirstToken { client, .. }
            | EventKind::Progress { client, .. }
            | EventKind::Preempt { client, .. }
            | EventKind::Requeue { client, .. }
            | EventKind::Finish { client, .. }
            | EventKind::Migrate { client, .. }
            | EventKind::Shed { client, .. }
            | EventKind::Window { client, .. } => Some(client),
            _ => None,
        }
    }
}

/// A recorded event with its merge key: (t, replica, seq) is a total
/// order — seq is per-recorder monotonic, so no two events from the same
/// track ever tie, and replica breaks cross-track ties at equal times.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub t: f64,
    pub replica: u32,
    pub seq: u32,
    pub kind: EventKind,
}

/// Recording interface threaded through `RunState` and the cluster
/// driver. Default methods are empty bodies: a `NullRecorder` call site
/// compiles to a virtual call that immediately returns — no allocation,
/// no payload inspection. Heavier capture (pick-score scans, per-client
/// window snapshots) must be gated on `enabled()` at the call site so the
/// scan itself is skipped when tracing is off. `Send` because recorders
/// live inside `RunState`, which the parallel cluster driver advances on
/// scoped worker threads.
pub trait Recorder: Send {
    /// True when events are actually captured; gates optional scans.
    fn enabled(&self) -> bool {
        false
    }

    fn record(&mut self, t: f64, kind: EventKind) {
        let _ = (t, kind);
    }

    /// Move buffered events (oldest first) into `out`, clearing the
    /// buffer. Called at cluster barriers and end-of-run.
    fn drain_into(&mut self, out: &mut Vec<TraceEvent>) {
        let _ = out;
    }

    /// Events overwritten by ring overflow since construction.
    fn dropped(&self) -> u64 {
        0
    }
}

/// Tracing configuration for a cluster run: per-track ring capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCfg {
    /// Ring capacity per track (one track per replica plus the driver).
    pub capacity: usize,
}

impl Default for TraceCfg {
    fn default() -> Self {
        // ~12 MB per track at 48 B/event — enough for every quick cell
        // without overflow, small enough to preallocate per replica.
        TraceCfg { capacity: 1 << 18 }
    }
}

/// The zero-cost default: every method is the trait's empty body.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullRecorder;

impl Recorder for NullRecorder {}

/// Bounded ring-buffer recorder. Allocates exactly once (at
/// construction); overflow overwrites the oldest event deterministically.
#[derive(Debug)]
pub struct TraceRecorder {
    replica: u32,
    seq: u32,
    cap: usize,
    /// Ring storage; once `buf.len() == cap`, `head` is the oldest slot.
    buf: Vec<TraceEvent>,
    head: usize,
    dropped: u64,
}

impl TraceRecorder {
    pub fn new(replica: u32, capacity: usize) -> Self {
        TraceRecorder {
            replica,
            seq: 0,
            cap: capacity.max(1),
            buf: Vec::with_capacity(capacity.max(1)),
            head: 0,
            dropped: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

impl Recorder for TraceRecorder {
    fn enabled(&self) -> bool {
        true
    }

    fn record(&mut self, t: f64, kind: EventKind) {
        let ev = TraceEvent { t, replica: self.replica, seq: self.seq, kind };
        self.seq = self.seq.wrapping_add(1);
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    fn drain_into(&mut self, out: &mut Vec<TraceEvent>) {
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        self.buf.clear();
        self.head = 0;
    }

    fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// Sort a span of events into canonical (t, replica, seq) order. The key
/// is total (`total_cmp` on t, unique (replica, seq) tiebreak), so
/// `sort_unstable_by` is deterministic.
pub fn merge_events(events: &mut [TraceEvent]) {
    events.sort_unstable_by(|a, b| {
        a.t.total_cmp(&b.t).then(a.replica.cmp(&b.replica)).then(a.seq.cmp(&b.seq))
    });
}

/// FNV-1a over every event's (t bits, replica, seq, code, payload).
/// Same constants as the engine/cluster digests so cross-artifact diffing
/// tooling stays uniform.
pub fn trace_digest(events: &[TraceEvent]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut fold = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for ev in events {
        fold(ev.t.to_bits());
        fold(((ev.replica as u64) << 32) | ev.seq as u64);
        fold(ev.kind.code() as u64);
        for w in ev.kind.payload() {
            fold(w);
        }
    }
    h
}

/// A finished, merged trace: header metadata plus the canonical event
/// stream. Produced by the cluster driver when tracing is enabled.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceLog {
    pub meta: RunMeta,
    pub events: Vec<TraceEvent>,
    /// Total ring-overflow drops across all tracks (deterministic).
    pub dropped: u64,
}

impl TraceLog {
    pub fn new(meta: RunMeta) -> Self {
        TraceLog { meta, events: Vec::new(), dropped: 0 }
    }

    /// Append a drained chunk, keeping it barrier-locally sorted. The
    /// final canonical order is re-established by `finish()`.
    pub fn absorb(&mut self, mut chunk: Vec<TraceEvent>, dropped: u64) {
        merge_events(&mut chunk);
        self.events.extend_from_slice(&chunk);
        self.dropped = dropped;
    }

    /// Global (t, replica, seq) sort — events recorded near a barrier can
    /// straddle the drain on different tracks, so the concatenation of
    /// per-barrier chunks is only approximately ordered until this runs.
    pub fn finish(&mut self) {
        merge_events(&mut self.events);
    }

    pub fn digest(&self) -> u64 {
        trace_digest(&self.events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: f64, replica: u32, seq: u32) -> TraceEvent {
        TraceEvent {
            t,
            replica,
            seq,
            kind: EventKind::Arrive { client: ClientId(1), req: RequestId(seq as u64) },
        }
    }

    #[test]
    fn null_recorder_is_disabled_and_silent() {
        let mut r = NullRecorder;
        assert!(!r.enabled());
        r.record(1.0, EventKind::Sync { syncs: 1 });
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert!(out.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut r = TraceRecorder::new(0, 3);
        for i in 0..5 {
            r.record(i as f64, EventKind::Sync { syncs: i });
        }
        assert_eq!(r.dropped(), 2);
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert_eq!(out.len(), 3);
        // Oldest-first after wrap: events 2, 3, 4 survive.
        assert_eq!(out[0].t, 2.0);
        assert_eq!(out[2].t, 4.0);
        assert_eq!(out[0].seq, 2);
        assert!(r.is_empty());
    }

    #[test]
    fn merge_orders_by_time_then_replica_then_seq() {
        let mut evs = vec![ev(2.0, 0, 5), ev(1.0, 1, 0), ev(1.0, 0, 3), ev(1.0, 0, 1)];
        merge_events(&mut evs);
        assert_eq!(
            evs.iter().map(|e| (e.t, e.replica, e.seq)).collect::<Vec<_>>(),
            vec![(1.0, 0, 1), (1.0, 0, 3), (1.0, 1, 0), (2.0, 0, 5)]
        );
    }

    #[test]
    fn digest_is_order_and_payload_sensitive() {
        let a = vec![ev(1.0, 0, 0), ev(2.0, 0, 1)];
        let b = vec![ev(2.0, 0, 1), ev(1.0, 0, 0)];
        assert_ne!(trace_digest(&a), trace_digest(&b));
        let mut c = a.clone();
        c[0].kind = EventKind::Arrive { client: ClientId(2), req: RequestId(0) };
        assert_ne!(trace_digest(&a), trace_digest(&c));
        assert_eq!(trace_digest(&a), trace_digest(&a.clone()));
    }

    #[test]
    fn every_kind_has_distinct_code() {
        let kinds = [
            EventKind::Arrive { client: ClientId(0), req: RequestId(0) },
            EventKind::Route { client: ClientId(0), req: RequestId(0), to: 0 },
            EventKind::Admit { client: ClientId(0), req: RequestId(0), queued: 0 },
            EventKind::Pick {
                client: ClientId(0),
                score: 0.0,
                rival: ClientId(0),
                rival_score: 0.0,
                rivals: 0,
            },
            EventKind::FirstToken { client: ClientId(0), req: RequestId(0), ttft: 0.0 },
            EventKind::Progress { client: ClientId(0), tokens: 0.0, running: 0 },
            EventKind::Preempt { client: ClientId(0), req: RequestId(0), kv_tokens: 0 },
            EventKind::Requeue { client: ClientId(0), req: RequestId(0) },
            EventKind::Finish {
                client: ClientId(0),
                req: RequestId(0),
                e2e: 0.0,
                predicted: 0,
                actual: 0,
            },
            EventKind::Migrate { client: ClientId(0), req: RequestId(0), to: 0 },
            EventKind::Shed { client: ClientId(0), req: RequestId(0), weighted: 0.0 },
            EventKind::Window { client: ClientId(0), score: 0.0 },
            EventKind::Sync { syncs: 0 },
            EventKind::Fault { code: 0, replica: 0 },
            EventKind::ScaleEpoch { epoch: 0, alive: 0 },
            EventKind::GuardTransition { from: 0, to: 1, err: 0.0 },
        ];
        let mut codes: Vec<u8> = kinds.iter().map(|k| k.code()).collect();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), kinds.len());
    }

    #[test]
    fn run_meta_json_round_trips_fields() {
        let m = RunMeta::new(42, "heavy_hitter");
        let j = m.to_json();
        assert_eq!(j.get("seed").and_then(|v| v.as_u64()), Some(42));
        assert_eq!(j.get("schema").and_then(|v| v.as_u64()), Some(TRACE_SCHEMA_VERSION as u64));
        assert_eq!(j.get("scenario").and_then(|v| v.as_str()), Some("heavy_hitter"));
    }
}
