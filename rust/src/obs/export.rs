//! Trace exporters: Chrome trace-event JSON (loadable in Perfetto — one
//! track per replica plus a driver track, request lifecycles as async
//! spans) and compact JSONL for tooling, plus the `--explain` latency
//! attribution used by `equinox trace`.

use super::{EventKind, TraceEvent, TraceLog, DRIVER_TRACK};
use crate::core::RequestId;
use crate::util::json::Json;

fn track_name(replica: u32) -> String {
    if replica == DRIVER_TRACK {
        "driver".into()
    } else {
        format!("replica {replica}")
    }
}

/// Typed payload fields as a JSON object (shared by both exporters).
fn kind_args(kind: &EventKind) -> Json {
    let mut j = Json::obj();
    if let Some(c) = kind.client() {
        j = j.set("client", c.0 as u64);
    }
    if let Some(r) = kind.request() {
        j = j.set("req", r.0);
    }
    match *kind {
        EventKind::Route { to, .. } => j = j.set("to", to as u64),
        EventKind::Admit { queued, .. } => j = j.set("queued", queued as u64),
        EventKind::Pick { score, rival, rival_score, rivals, .. } => {
            j = j
                .set("score", score)
                .set("rival", rival.0 as u64)
                .set("rival_score", rival_score)
                .set("rivals", rivals as u64);
        }
        EventKind::FirstToken { ttft, .. } => j = j.set("ttft", ttft),
        EventKind::Progress { tokens, running, .. } => {
            j = j.set("tokens", tokens).set("running", running as u64);
        }
        EventKind::Preempt { kv_tokens, .. } => j = j.set("kv_tokens", kv_tokens),
        EventKind::Finish { e2e, predicted, actual, .. } => {
            j = j.set("e2e", e2e).set("predicted", predicted as u64).set("actual", actual as u64);
        }
        EventKind::Migrate { to, .. } => j = j.set("to", to as u64),
        EventKind::Shed { weighted, .. } => j = j.set("weighted", weighted),
        EventKind::Window { score, .. } => j = j.set("score", score),
        EventKind::Sync { syncs } => j = j.set("syncs", syncs),
        EventKind::Fault { code, replica } => {
            j = j.set("code", code as u64).set("replica", replica as u64);
        }
        EventKind::ScaleEpoch { epoch, alive } => {
            j = j.set("epoch", epoch as u64).set("alive", alive as u64);
        }
        EventKind::GuardTransition { from, to, err } => {
            j = j.set("from", from as u64).set("to", to as u64).set("err", err);
        }
        _ => {}
    }
    j
}

/// Compact JSONL: a header line (meta + digest), then one event per line
/// in canonical merge order. Integer-friendly and diffable.
pub fn to_jsonl(log: &TraceLog) -> String {
    let mut out = String::new();
    let header = Json::obj()
        .set("meta", log.meta.to_json())
        .set("digest", format!("0x{:016x}", log.digest()))
        .set("dropped", log.dropped)
        .set("events", log.events.len());
    out.push_str(&header.to_string());
    out.push('\n');
    for ev in &log.events {
        let line = Json::obj()
            .set("t", ev.t)
            .set("track", ev.replica as u64)
            .set("seq", ev.seq as u64)
            .set("ev", ev.kind.label())
            .set("args", kind_args(&ev.kind));
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// Chrome trace-event JSON. Each replica (and the driver) gets a process
/// track of instant events; each request becomes an async span (`b`/`n`/
/// `e` phases keyed by request id) so Perfetto draws arrive→finish bars
/// with admit/first-token/preempt beads on them.
pub fn to_perfetto(log: &TraceLog) -> String {
    let mut events: Vec<Json> = Vec::with_capacity(log.events.len() + 8);
    // Process-name metadata, driver track first (pid sorts are cosmetic).
    let mut tracks: Vec<u32> = log.events.iter().map(|e| e.replica).collect();
    tracks.sort_unstable();
    tracks.dedup();
    for r in &tracks {
        events.push(
            Json::obj()
                .set("ph", "M")
                .set("name", "process_name")
                .set("pid", *r as u64)
                .set("tid", 0u64)
                .set("args", Json::obj().set("name", track_name(*r))),
        );
    }
    for ev in &log.events {
        let ts = ev.t * 1e6; // trace-event ts is in microseconds
        let base = Json::obj()
            .set("pid", ev.replica as u64)
            .set("tid", 0u64)
            .set("ts", ts)
            .set("name", ev.kind.label())
            .set("args", kind_args(&ev.kind));
        match ev.kind {
            // Lifecycle edges become async-span phases keyed by request.
            EventKind::Arrive { req, .. } => {
                events.push(base.set("ph", "b").set("cat", "request").set("id", req.0));
            }
            EventKind::Finish { req, .. } | EventKind::Shed { req, .. } => {
                events.push(base.set("ph", "e").set("cat", "request").set("id", req.0));
            }
            EventKind::Route { req, .. }
            | EventKind::Admit { req, .. }
            | EventKind::FirstToken { req, .. }
            | EventKind::Preempt { req, .. }
            | EventKind::Requeue { req, .. }
            | EventKind::Migrate { req, .. } => {
                events.push(base.set("ph", "n").set("cat", "request").set("id", req.0));
            }
            // Everything else is an instant on its track.
            _ => {
                events.push(base.set("ph", "i").set("s", "t"));
            }
        }
    }
    Json::obj()
        .set("displayTimeUnit", "ms")
        .set("otherData", Json::obj().set("meta", log.meta.to_json()).set(
            "digest",
            format!("0x{:016x}", log.digest()),
        ))
        .set("traceEvents", events)
        .to_string()
}

/// Queue-ahead / preemption attribution for one request's latency: walks
/// the merged stream once and decomposes arrive→finish into queue wait
/// (with the number of other admissions that jumped ahead), execution,
/// and preemption stalls. Deterministic text, suitable for test capture.
pub fn explain(log: &TraceLog, req: RequestId) -> String {
    let mut out = String::new();
    let mut arrive: Option<f64> = None;
    let mut first_admit: Option<f64> = None;
    let mut first_token: Option<f64> = None;
    let mut finish: Option<f64> = None;
    let mut shed_at: Option<f64> = None;
    let mut routed_to: Option<u32> = None;
    let mut queue_ahead: u32 = 0;
    let mut preempts: Vec<f64> = Vec::new();
    let mut stall = 0.0;
    let mut pending_preempt: Option<f64> = None;
    let mut migrations: u32 = 0;
    let mut tokens: Option<(u32, u32)> = None;

    for ev in &log.events {
        let mine = ev.kind.request() == Some(req);
        match ev.kind {
            EventKind::Arrive { .. } if mine => arrive = Some(ev.t),
            EventKind::Route { to, .. } if mine => routed_to = Some(to),
            EventKind::Admit { .. } => {
                if mine {
                    if first_admit.is_none() {
                        first_admit = Some(ev.t);
                    }
                    if let Some(p) = pending_preempt.take() {
                        stall += ev.t - p;
                    }
                } else if arrive.is_some()
                    && first_admit.is_none()
                    && routed_to.unwrap_or(ev.replica) == ev.replica
                {
                    // Another request admitted on our replica while we waited.
                    queue_ahead += 1;
                }
            }
            EventKind::FirstToken { .. } if mine && first_token.is_none() => {
                first_token = Some(ev.t)
            }
            EventKind::Preempt { .. } if mine => {
                preempts.push(ev.t);
                pending_preempt = Some(ev.t);
            }
            EventKind::Migrate { .. } if mine => migrations += 1,
            EventKind::Finish { predicted, actual, .. } if mine => {
                finish = Some(ev.t);
                tokens = Some((predicted, actual));
            }
            EventKind::Shed { .. } if mine => shed_at = Some(ev.t),
            _ => {}
        }
    }

    out.push_str(&format!("request r{}\n", req.0));
    let Some(t0) = arrive else {
        out.push_str("  no arrive event in trace (request unseen or outside ring window)\n");
        return out;
    };
    out.push_str(&format!("  arrive            t={t0:.4}\n"));
    if let Some(r) = routed_to {
        out.push_str(&format!("  routed to         replica {r}\n"));
    }
    if let Some(t) = shed_at {
        out.push_str(&format!("  SHED              t={t:.4} (admission control)\n"));
        return out;
    }
    if let Some(ta) = first_admit {
        out.push_str(&format!(
            "  admit             t={ta:.4}  queue wait {:.4}s ({queue_ahead} admissions ahead)\n",
            ta - t0
        ));
    } else {
        out.push_str("  never admitted within the trace window\n");
        return out;
    }
    if let Some(tf) = first_token {
        out.push_str(&format!("  first token       t={tf:.4}  ttft {:.4}s\n", tf - t0));
    }
    if !preempts.is_empty() {
        out.push_str(&format!(
            "  preempted         {}x, {:.4}s stalled re-queued\n",
            preempts.len(),
            stall
        ));
    }
    if migrations > 0 {
        out.push_str(&format!("  migrated          {migrations}x (replica failure)\n"));
    }
    if let Some(te) = finish {
        let e2e = te - t0;
        let queue = first_admit.map(|ta| ta - t0).unwrap_or(0.0);
        let exec = e2e - queue - stall;
        out.push_str(&format!("  finish            t={te:.4}  e2e {e2e:.4}s\n"));
        if let Some((pred, act)) = tokens {
            let ratio = pred.max(1) as f64 / act.max(1) as f64;
            out.push_str(&format!(
                "  tokens            predicted {pred} vs actual {act} (x{ratio:.2})\n"
            ));
        }
        out.push_str(&format!(
            "  attribution       queue {:.1}% | exec {:.1}% | preemption {:.1}%\n",
            100.0 * queue / e2e.max(1e-12),
            100.0 * exec / e2e.max(1e-12),
            100.0 * stall / e2e.max(1e-12),
        ));
    } else {
        out.push_str("  still in flight at end of trace\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ClientId;
    use crate::obs::RunMeta;

    fn lifecycle_log() -> TraceLog {
        let c = ClientId(1);
        let r = RequestId(7);
        let mk = |t: f64, seq: u32, kind: EventKind| TraceEvent { t, replica: 0, seq, kind };
        let mut log = TraceLog::new(RunMeta::new(1, "unit"));
        log.events = vec![
            mk(0.0, 0, EventKind::Arrive { client: c, req: r }),
            mk(0.1, 1, EventKind::Admit { client: ClientId(2), req: RequestId(8), queued: 1 }),
            mk(0.5, 2, EventKind::Admit { client: c, req: r, queued: 0 }),
            mk(0.7, 3, EventKind::FirstToken { client: c, req: r, ttft: 0.7 }),
            mk(1.0, 4, EventKind::Preempt { client: c, req: r, kv_tokens: 64 }),
            mk(1.4, 5, EventKind::Admit { client: c, req: r, queued: 0 }),
            mk(2.0, 6, EventKind::Finish { client: c, req: r, e2e: 2.0, predicted: 96, actual: 64 }),
        ];
        log
    }

    #[test]
    fn jsonl_has_header_plus_event_lines() {
        let log = lifecycle_log();
        let text = to_jsonl(&log);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1 + log.events.len());
        let header = Json::parse(lines[0]).unwrap();
        assert_eq!(header.get("events").and_then(|v| v.as_u64()), Some(7));
        assert!(header.get("digest").and_then(|v| v.as_str()).unwrap().starts_with("0x"));
        let first = Json::parse(lines[1]).unwrap();
        assert_eq!(first.get("ev").and_then(|v| v.as_str()), Some("arrive"));
    }

    #[test]
    fn perfetto_is_valid_json_with_async_span() {
        let log = lifecycle_log();
        let j = Json::parse(&to_perfetto(&log)).unwrap();
        let evs = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        // 1 process-name metadata + 7 events.
        assert_eq!(evs.len(), 8);
        let begins: Vec<&Json> =
            evs.iter().filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("b")).collect();
        assert_eq!(begins.len(), 1);
        assert_eq!(begins[0].get("id").and_then(|v| v.as_u64()), Some(7));
    }

    #[test]
    fn explain_decomposes_latency() {
        let log = lifecycle_log();
        let text = explain(&log, RequestId(7));
        assert!(text.contains("queue wait 0.5000s (1 admissions ahead)"), "{text}");
        assert!(text.contains("preempted         1x, 0.4000s"), "{text}");
        assert!(text.contains("e2e 2.0000s"), "{text}");
        assert!(text.contains("predicted 96 vs actual 64 (x1.50)"), "{text}");
        let unknown = explain(&log, RequestId(99));
        assert!(unknown.contains("no arrive event"), "{unknown}");
    }
}
