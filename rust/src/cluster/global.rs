//! The global dual-counter plane: cluster-wide UFC/RFC built by merging
//! per-replica counter deltas on a configurable sync period.
//!
//! Each replica's scheduler keeps its own local `HolisticCounters` (or
//! VTC counter) and schedules on them untouched — the plane is a *read
//! plane* for the router and for discrepancy measurement, never a write
//! path back into replica scheduling. That decoupling is what lets the
//! sync period trade freshness for coordination cost: between syncs the
//! router sees counters up to `sync_period` (plus at most one engine
//! iteration of overshoot) stale, and the cluster conformance cells
//! measure the discrepancy bound *under* that staleness.
//!
//! Merge semantics: UFC is additive service, so per-replica deltas of the
//! cumulative export sum into the global counter exactly. RFC is a
//! bounded recent-efficiency EMA — deltas of an EMA are not meaningful
//! across replicas, so the plane keeps the latest per-replica value and
//! aggregates by mean over the replicas that have seen the client.

use crate::core::{ClientId, ClientSlab};
use crate::sched::counters::hf_score;
use crate::sched::{HfParams, Scheduler};

/// Cluster-wide merged dual counters with periodic pull-based sync.
#[derive(Debug)]
pub struct GlobalPlane {
    params: HfParams,
    sync_period: f64,
    next_sync: f64,
    /// Per-replica last-pulled cumulative `(ufc, rfc)` per client —
    /// both the baseline the next pull differences against AND the
    /// latest-RFC store (one structure). A dense slab keeps the
    /// steady-state pull path allocation-free: a pull over an
    /// already-seen client set is pure in-place slot overwrites, and
    /// only a genuinely new max client id ever grows storage. (This
    /// replaces the previous hand-rolled sorted-vec + binary-search
    /// merge with the same `ClientSlab` every per-client hot structure
    /// uses.)
    seen: Vec<ClientSlab<(f64, f64)>>,
    /// Merged cluster-wide UFC (sum of per-replica deltas). Entries are
    /// only created the first time a client is seen anywhere; steady-state
    /// pulls update in place.
    ufc: ClientSlab<f64>,
    /// Fault-plane liveness per replica: dead replicas keep their pull
    /// baseline (UFC deltas must difference correctly across an outage)
    /// but are excluded from the RFC mean — a frozen EMA is not recent
    /// efficiency, and averaging it in would bias the routing band for
    /// the whole outage.
    alive: Vec<bool>,
    /// Completed sync rounds.
    pub syncs: u64,
    /// Cluster time of the last completed sync.
    pub last_sync_at: f64,
    /// Cached (min, max) global HF over known clients, refreshed at each
    /// `finish_sync` — counters only change at sync rounds, and the
    /// FairShare router queries the band once per routing decision, so
    /// recomputing it per query would be O(clients × replicas) on the
    /// routing hot path.
    band: (f64, f64),
}

impl GlobalPlane {
    /// `sync_period <= 0` disables periodic syncing (the plane only
    /// merges once, at the end of the run).
    pub fn new(n_replicas: usize, sync_period: f64, params: HfParams) -> GlobalPlane {
        let effective = if sync_period > 0.0 { sync_period } else { f64::INFINITY };
        GlobalPlane {
            params,
            sync_period: effective,
            next_sync: effective,
            seen: vec![ClientSlab::new(); n_replicas],
            alive: vec![true; n_replicas],
            ufc: ClientSlab::new(),
            syncs: 0,
            last_sync_at: 0.0,
            band: (f64::INFINITY, f64::NEG_INFINITY),
        }
    }

    pub fn sync_period(&self) -> f64 {
        self.sync_period
    }

    /// Is a sync boundary due at `cluster_time` (the min runnable replica
    /// clock — replicas ahead of the boundary contribute slightly stale
    /// state, which is the bounded-staleness model, not a bug)?
    pub fn due(&self, cluster_time: f64) -> bool {
        cluster_time >= self.next_sync
    }

    /// The next sync boundary (cluster time); `INFINITY` when periodic
    /// syncing is disabled. The parallel driver's barrier horizon:
    /// between consecutive boundaries (and routing gates) every replica's
    /// evolution is independent.
    pub fn next_sync_at(&self) -> f64 {
        self.next_sync
    }

    /// Pull one replica's cumulative counters and merge the delta since
    /// the last pull. Called once per replica per sync round. Zero
    /// allocations when the replica's client set is unchanged (the
    /// steady-state path — see `seen`).
    pub fn pull_replica(&mut self, replica: usize, sched: &dyn Scheduler) {
        let seen = &mut self.seen[replica];
        let ufc = &mut self.ufc;
        sched.export_counters(&mut |client, cum_ufc, cum_rfc| {
            // A fresh slot reads Default (0.0, 0.0) — the same zero
            // baseline a first-time client got from the old sorted-vec
            // miss branch.
            let slot = seen.or_default(client);
            let base_ufc = slot.0;
            *slot = (cum_ufc, cum_rfc);
            // Signed delta: preemption refunds and completion corrections
            // propagate too; the merged counter just never goes negative.
            let delta = cum_ufc - base_ufc;
            let e = ufc.or_default(client);
            *e = (*e + delta).max(0.0);
        });
    }

    /// Complete a sync round at `cluster_time`: advances the boundary so
    /// `due` goes false until the next period, and refreshes the cached
    /// HF band. The driver calls `pull_replica` for every replica first.
    pub fn finish_sync(&mut self, cluster_time: f64) {
        self.syncs += 1;
        self.last_sync_at = cluster_time;
        // Skip boundaries the run never observed (long macro-steps can
        // cross several) rather than replaying them back-to-back.
        while self.next_sync <= cluster_time {
            self.next_sync += self.sync_period;
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for (c, _) in self.ufc.iter() {
            let h = self.hf(c);
            lo = lo.min(h);
            hi = hi.max(h);
        }
        self.band = (lo, hi);
    }

    /// Merged cluster-wide UFC for a client (0 if never seen).
    pub fn ufc(&self, client: ClientId) -> f64 {
        self.ufc.get(client).copied().unwrap_or(0.0)
    }

    /// Mark one replica dead or alive for the RFC mean. Driver-thread
    /// barrier code (fault materialization) — mode-invariant.
    pub fn set_alive(&mut self, replica: usize, alive: bool) {
        self.alive[replica] = alive;
    }

    /// Join a scale-out replica to the plane: a fresh zero pull baseline
    /// (its first pull differences against nothing, exactly like a
    /// construction-time replica) under the next replica id. Driver-
    /// thread barrier code (scale materialization) — mode-invariant.
    pub fn add_replica(&mut self) {
        self.seen.push(ClientSlab::new());
        self.alive.push(true);
    }

    /// Mean of the latest per-replica RFC values for a client, over
    /// alive replicas only. Falls back to all replicas when every
    /// holder of this client is dead — a stale estimate beats
    /// pretending the client was never seen.
    pub fn rfc(&self, client: ClientId) -> f64 {
        let mut sum = 0.0;
        let mut n = 0u32;
        let mut dead_sum = 0.0;
        let mut dead_n = 0u32;
        for (r, m) in self.seen.iter().enumerate() {
            if let Some(&(_, rfc)) = m.get(client) {
                if self.alive[r] {
                    sum += rfc;
                    n += 1;
                } else {
                    dead_sum += rfc;
                    dead_n += 1;
                }
            }
        }
        if n > 0 {
            sum / n as f64
        } else if dead_n > 0 {
            dead_sum / dead_n as f64
        } else {
            0.0
        }
    }

    /// Test hook: (len, capacity) of one replica's baseline store — the
    /// allocation-free steady-state contract is "capacity stable across
    /// pulls once the client set stops growing".
    #[cfg(test)]
    fn seen_shape(&self, replica: usize) -> (usize, usize) {
        (self.seen[replica].len(), self.seen[replica].capacity())
    }

    /// Global holistic-fairness score — the same composition the
    /// per-replica schedulers use, over the merged counters.
    pub fn hf(&self, client: ClientId) -> f64 {
        hf_score(&self.params, self.ufc(client), self.rfc(client))
    }

    /// All known clients with their global HF, ascending client id.
    pub fn all_hf(&self) -> Vec<(ClientId, f64)> {
        self.ufc.iter().map(|(c, _)| (c, self.hf(c))).collect()
    }

    /// Max − min global HF over known clients (as of the last sync) —
    /// the cluster-wide spread the FairShare router tries to keep from
    /// growing.
    pub fn hf_spread(&self) -> f64 {
        let (lo, hi) = self.band;
        if lo.is_finite() {
            hi - lo
        } else {
            0.0
        }
    }

    /// Is this client in the most-underserved band (global HF within 5%
    /// of the cluster spread above the minimum, as of the last sync)?
    /// Unknown clients are underserved by definition — they have
    /// received nothing anywhere. O(log C): one counter lookup against
    /// the cached band.
    pub fn is_underserved(&self, client: ClientId) -> bool {
        if !self.ufc.contains(client) {
            return true;
        }
        let (lo, hi) = self.band;
        if !lo.is_finite() {
            return true;
        }
        self.hf(client) <= lo + 0.05 * (hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Request, RequestId};
    use crate::sched::Vtc;

    fn req(id: u64, client: u32, input: u32) -> Request {
        Request::new(RequestId(id), ClientId(client), input, 10, 0.0)
    }

    fn served_vtc(charges: &[(u32, u32)]) -> Vtc {
        let mut s = Vtc::new();
        for (i, &(client, input)) in charges.iter().enumerate() {
            s.enqueue(req(i as u64, client, input), 0.0);
            let _ = s.pick(0.0, &mut |_| true).unwrap();
        }
        s
    }

    #[test]
    fn ufc_deltas_sum_across_replicas() {
        let a = served_vtc(&[(0, 100), (1, 50)]);
        let b = served_vtc(&[(0, 300)]);
        let mut plane = GlobalPlane::new(2, 1.0, HfParams::default());
        plane.pull_replica(0, &a);
        plane.pull_replica(1, &b);
        plane.finish_sync(1.0);
        assert_eq!(plane.ufc(ClientId(0)), 400.0);
        assert_eq!(plane.ufc(ClientId(1)), 50.0);
        assert_eq!(plane.syncs, 1);
    }

    #[test]
    fn repeated_pulls_are_idempotent_on_unchanged_counters() {
        let a = served_vtc(&[(0, 100)]);
        let mut plane = GlobalPlane::new(1, 1.0, HfParams::default());
        plane.pull_replica(0, &a);
        plane.finish_sync(1.0);
        plane.pull_replica(0, &a);
        plane.finish_sync(2.0);
        assert_eq!(plane.ufc(ClientId(0)), 100.0, "cumulative export must be differenced");
        assert_eq!(plane.syncs, 2);
    }

    #[test]
    fn sync_boundaries_respect_the_period() {
        let mut plane = GlobalPlane::new(1, 2.0, HfParams::default());
        assert!(!plane.due(1.9));
        assert!(plane.due(2.0));
        plane.finish_sync(2.1);
        assert!(!plane.due(3.9));
        assert!(plane.due(4.0));
        // A long macro-step crossing several boundaries advances past all
        // of them in one round.
        plane.finish_sync(11.0);
        assert!(!plane.due(11.9));
        assert!(plane.due(12.0));
    }

    #[test]
    fn zero_period_disables_syncing() {
        let plane = GlobalPlane::new(1, 0.0, HfParams::default());
        assert!(!plane.due(1e12));
    }

    #[test]
    fn steady_state_pulls_do_not_grow_the_baseline_store() {
        // After the first pull establishes the client set, repeated sync
        // rounds over the same (or served-further) schedulers must be
        // pure in-place updates: no new entries, no reallocation.
        let mut a = served_vtc(&[(0, 100), (1, 50), (2, 25)]);
        let mut plane = GlobalPlane::new(1, 1.0, HfParams::default());
        plane.pull_replica(0, &a);
        plane.finish_sync(1.0);
        let (len0, cap0) = plane.seen_shape(0);
        assert_eq!(len0, 3);
        for round in 0..100u32 {
            // Keep serving the same clients so the cumulative counters move.
            a.enqueue(req(1000 + round as u64, round % 3, 10), round as f64);
            let _ = a.pick(round as f64, &mut |_| true).unwrap();
            plane.pull_replica(0, &a);
            plane.finish_sync(2.0 + round as f64);
        }
        assert_eq!(
            plane.seen_shape(0),
            (len0, cap0),
            "steady-state pulls must not allocate in the baseline store"
        );
        assert_eq!(plane.syncs, 101);
    }

    #[test]
    fn next_sync_at_tracks_the_boundary() {
        let mut plane = GlobalPlane::new(1, 2.0, HfParams::default());
        assert_eq!(plane.next_sync_at(), 2.0);
        plane.finish_sync(2.5);
        assert_eq!(plane.next_sync_at(), 4.0);
        let disabled = GlobalPlane::new(1, 0.0, HfParams::default());
        assert!(disabled.next_sync_at().is_infinite());
    }

    /// Export-only stub: fixed cumulative (ufc, rfc) per client. The
    /// plane never schedules through the trait, so the scheduling
    /// methods are unreachable here.
    struct FixedCounters(Vec<(ClientId, f64, f64)>);

    impl Scheduler for FixedCounters {
        fn name(&self) -> &'static str {
            "fixed"
        }
        fn enqueue(&mut self, _req: Request, _now: f64) {
            unreachable!()
        }
        fn pick(
            &mut self,
            _now: f64,
            _feasible: &mut dyn FnMut(&Request) -> bool,
        ) -> Option<Request> {
            unreachable!()
        }
        fn requeue(&mut self, _req: Request) {
            unreachable!()
        }
        fn on_complete(&mut self, _req: &Request, _actual: &crate::sched::Actuals, _now: f64) {}
        fn queue_len(&self) -> usize {
            0
        }
        fn for_each_queued_client(&self, _f: &mut dyn FnMut(ClientId)) {}
        fn export_counters(&self, f: &mut dyn FnMut(ClientId, f64, f64)) {
            for &(c, u, r) in &self.0 {
                f(c, u, r);
            }
        }
    }

    #[test]
    fn rfc_mean_excludes_dead_replicas() {
        // Two replicas hold different latest RFC values for client 0.
        let a = FixedCounters(vec![(ClientId(0), 100.0, 2.0)]);
        let b = FixedCounters(vec![(ClientId(0), 300.0, 6.0)]);
        let mut plane = GlobalPlane::new(2, 1.0, HfParams::default());
        plane.pull_replica(0, &a);
        plane.pull_replica(1, &b);
        plane.finish_sync(1.0);
        assert_eq!(plane.rfc(ClientId(0)), 4.0, "alive mean over both holders");
        plane.set_alive(1, false);
        assert_eq!(plane.rfc(ClientId(0)), 2.0, "dead replica drops out of the mean");
        // Every holder dead: fall back to the stale values, not zero.
        plane.set_alive(0, false);
        assert_eq!(plane.rfc(ClientId(0)), 4.0);
        // UFC is unaffected by liveness (additive service already done).
        assert_eq!(plane.ufc(ClientId(0)), 400.0);
        // Revival restores the full mean.
        plane.set_alive(0, true);
        plane.set_alive(1, true);
        assert_eq!(plane.rfc(ClientId(0)), 4.0);
    }

    #[test]
    fn added_replica_merges_from_a_zero_baseline() {
        let a = served_vtc(&[(0, 100)]);
        let mut plane = GlobalPlane::new(1, 1.0, HfParams::default());
        plane.pull_replica(0, &a);
        plane.finish_sync(1.0);
        plane.add_replica();
        let b = served_vtc(&[(0, 300), (2, 50)]);
        plane.pull_replica(0, &a);
        plane.pull_replica(1, &b);
        plane.finish_sync(2.0);
        assert_eq!(plane.ufc(ClientId(0)), 400.0, "joiner's full history merges once");
        assert_eq!(plane.ufc(ClientId(2)), 50.0);
        assert_eq!(plane.syncs, 2);
    }

    #[test]
    fn underserved_band_tracks_min_hf() {
        let a = served_vtc(&[(0, 5000), (1, 100)]);
        let mut plane = GlobalPlane::new(1, 1.0, HfParams::default());
        plane.pull_replica(0, &a);
        plane.finish_sync(1.0);
        assert!(plane.is_underserved(ClientId(1)));
        assert!(!plane.is_underserved(ClientId(0)));
        // Never-seen clients are maximally underserved.
        assert!(plane.is_underserved(ClientId(9)));
        assert!(plane.hf_spread() > 0.0);
    }
}
