//! Fleet description: which replicas exist and what hardware each one
//! runs. The paper evaluates on two testbeds — a single A100-80GB and an
//! 8×A100-40GB machine — and claims bounded discrepancy *across* such
//! heterogeneous platforms; the presets here reproduce those shapes (plus
//! a capacity-skewed variant) so the cluster conformance cells can
//! measure it.

use crate::sim::{GpuKind, GpuModel, HostProfile, ModelSpec, SimConfig};

/// One replica's hardware + serving-stack profile. The engine-level
/// knobs (sample period, step mode, drain) come from the cluster's base
/// `SimConfig`; the spec overrides only what differs per replica.
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub name: &'static str,
    pub gpu: GpuModel,
    pub host: HostProfile,
}

impl ReplicaSpec {
    /// Paper testbed 1: A100-80GB, Llama-2-7b, vLLM profile — identical
    /// to the plain single-engine default (`SimConfig::a100_7b_vllm`),
    /// which is what makes `Fleet::solo()` a zero-drift wrapper.
    pub fn a100_80g() -> ReplicaSpec {
        ReplicaSpec { name: "a100-80g", gpu: GpuModel::a100_7b(), host: HostProfile::VLLM }
    }

    /// Paper testbed 2's building block: A100-40GB (lower HBM bandwidth
    /// and capacity), same model and host stack.
    pub fn a100_40g() -> ReplicaSpec {
        ReplicaSpec {
            name: "a100-40g",
            gpu: GpuModel::new(GpuKind::A100_40G, ModelSpec::LLAMA2_7B, 1),
            host: HostProfile::VLLM,
        }
    }

    /// Capacity-skewed small replica: A100-40GB with most of its KV pool
    /// unavailable (adapter residency, co-located services) — the shape
    /// that punishes routers ignoring KV headroom.
    pub fn a100_40g_skewed() -> ReplicaSpec {
        let mut host = HostProfile::VLLM;
        host.kv_fraction = 0.25;
        host.max_batch = 64;
        ReplicaSpec {
            name: "a100-40g-skewed",
            gpu: GpuModel::new(GpuKind::A100_40G, ModelSpec::LLAMA2_7B, 1),
            host,
        }
    }

    /// The replica's engine config: the cluster base with this replica's
    /// GPU and host swapped in.
    pub fn sim_config(&self, base: &SimConfig) -> SimConfig {
        base.clone().with_gpu(self.gpu).with_host(self.host)
    }

    /// Peak weighted-token throughput (wtok/s) — the router's capacity
    /// normaliser for predicted-cost balancing (output tokens carry the
    /// service weight 4).
    pub fn peak_weighted_tps(&self) -> f64 {
        4.0 * self.gpu.peak_decode_tps(64, 512)
    }
}

/// An ordered set of replicas. Replica ids are positions in `replicas`
/// and are stable for the whole run (the deterministic tie-break key).
#[derive(Debug, Clone)]
pub struct Fleet {
    pub name: String,
    pub replicas: Vec<ReplicaSpec>,
}

impl Fleet {
    /// One A100-80GB — the differential-testing fleet: a solo cluster
    /// must be bit-identical to the plain engine.
    pub fn solo() -> Fleet {
        Fleet { name: "solo".into(), replicas: vec![ReplicaSpec::a100_80g()] }
    }

    /// Homogeneous n×A100-40GB (the conformance default is n=4, the
    /// paper's multi-GPU testbed shape).
    pub fn homogeneous(n: usize) -> Fleet {
        Fleet {
            name: format!("homo{n}x40g"),
            replicas: (0..n.max(1)).map(|_| ReplicaSpec::a100_40g()).collect(),
        }
    }

    /// The paper-faithful heterogeneous fleet: one A100-80GB beside two
    /// A100-40GB replicas — capacity AND bandwidth asymmetry.
    pub fn hetero() -> Fleet {
        Fleet {
            name: "hetero-80+2x40".into(),
            replicas: vec![
                ReplicaSpec::a100_80g(),
                ReplicaSpec::a100_40g(),
                ReplicaSpec::a100_40g(),
            ],
        }
    }

    /// The minimal fleet an autoscaler starts from: two A100-40GB
    /// replicas — just enough capacity for baseline traffic, so a flash
    /// crowd forces the scale-out decision instead of being absorbed
    /// silently. The static-baseline arm of the autoscale experiments
    /// runs this fleet unchanged.
    pub fn minimal() -> Fleet {
        Fleet {
            name: "minimal-2x40g".into(),
            replicas: vec![ReplicaSpec::a100_40g(), ReplicaSpec::a100_40g()],
        }
    }

    /// Skewed-capacity fleet: one healthy 80GB replica plus `n-1`
    /// KV-starved 40GB replicas — the KV-headroom stress shape.
    pub fn skewed(n: usize) -> Fleet {
        let mut replicas = vec![ReplicaSpec::a100_80g()];
        for _ in 1..n.max(2) {
            replicas.push(ReplicaSpec::a100_40g_skewed());
        }
        Fleet { name: format!("skewed{}", n.max(2)), replicas }
    }

    /// CLI lookup. `homo4`/`hetero`/`solo`/`skewed3`/`minimal`.
    pub fn by_name(name: &str) -> Option<Fleet> {
        match name {
            "solo" => Some(Fleet::solo()),
            "homo4" => Some(Fleet::homogeneous(4)),
            "hetero" => Some(Fleet::hetero()),
            "skewed3" | "skewed" => Some(Fleet::skewed(3)),
            "minimal" => Some(Fleet::minimal()),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_matches_the_plain_engine_default() {
        let base = SimConfig::a100_7b_vllm();
        let cfg = Fleet::solo().replicas[0].sim_config(&base);
        assert_eq!(cfg.gpu.gpu.name, base.gpu.gpu.name);
        assert_eq!(cfg.host.name, base.host.name);
        assert_eq!(cfg.gpu.kv_token_capacity(), base.gpu.kv_token_capacity());
    }

    #[test]
    fn hetero_fleet_is_actually_heterogeneous() {
        let f = Fleet::hetero();
        assert_eq!(f.len(), 3);
        let fast = f.replicas[0].peak_weighted_tps();
        let slow = f.replicas[1].peak_weighted_tps();
        assert!(fast > slow * 1.1, "80GB must outrun 40GB: {fast} vs {slow}");
        assert!(
            f.replicas[0].gpu.kv_token_capacity() > 2 * f.replicas[1].gpu.kv_token_capacity(),
            "80GB must hold much more KV"
        );
    }

    #[test]
    fn skewed_replicas_are_kv_starved() {
        let f = Fleet::skewed(3);
        assert_eq!(f.len(), 3);
        let healthy = &f.replicas[0];
        let starved = &f.replicas[1];
        assert!(starved.host.kv_fraction < healthy.host.kv_fraction / 2.0);
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ["solo", "homo4", "hetero", "skewed3", "minimal"] {
            let f = Fleet::by_name(name).unwrap();
            assert!(!f.is_empty());
        }
        assert!(Fleet::by_name("nope").is_none());
    }
}
