//! Heterogeneous multi-replica serving cluster.
//!
//! Composes N *unmodified* single-GPU engines (`crate::sim`) over a
//! described fleet, with:
//!
//! - [`fleet`] — per-replica hardware/host specs and fleet builders
//!   (paper-faithful A100-80GB and A100-40GB presets, homogeneous /
//!   heterogeneous / capacity-skewed shapes);
//! - [`router`] — pluggable request→replica placement (`RoundRobin`,
//!   `JoinShortestQueue`, `PredictedCost`, fairness+locality-aware
//!   `FairShare`);
//! - [`global`] — the global dual-counter plane: per-replica UFC/RFC
//!   deltas merged cluster-wide on a configurable sync period, so
//!   fairness can be measured under bounded counter staleness;
//! - [`faults`] — the deterministic fault plane: pure-data fault plans
//!   (crashes, brownouts, KV squeezes) materialized by the driver only
//!   at barrier boundaries, plus the migration and admission policies
//!   (orphan re-placement through the router; weight-fair load
//!   shedding with per-client accounting);
//! - [`autoscale`] — deterministic replica autoscaling: pure-data scale
//!   schedules and a reactive target-backlog controller (hysteresis +
//!   cooldown), materialized at barrier boundaries only, with scale-out
//!   from a [`ReplicaSpec`] pool and scale-in as a graceful drain
//!   through the orphan-migration path (service conservation exact
//!   across fleet changes);
//! - [`driver`] — the deterministic driver interleaving the engines'
//!   macro-steps, in two bit-exact execution modes: the serial lock-step
//!   reference (lagging replica first, clock-heap indexed, stable
//!   replica-id tie-break) and barrier-bounded parallel horizon batching
//!   on a scoped worker pool ([`driver::DriveMode`]); plus the
//!   `ClusterResult` rollups + bit-exact fingerprint.
//!
//! The load-bearing properties, pinned by `tests/cluster.rs` and
//! `tests/parallel_driver.rs`: a 1-replica cluster is bit-identical to
//! the plain `Simulation` on every adversarial scenario, and
//! `DriveMode::Parallel` is fingerprint-identical to `DriveMode::Serial`
//! at every thread count — the cluster layer and its parallelisation add
//! zero behavioral drift.

pub mod autoscale;
pub mod driver;
pub mod faults;
pub mod fleet;
pub mod global;
pub mod router;

pub use autoscale::{AutoscalePolicy, ReactivePolicy, ScaleAction, ScaleEvent, ScaleState};
pub use driver::{run_cluster, Cluster, ClusterOpts, ClusterResult, DriveMode};
pub use faults::{
    AdmissionPolicy, FaultEvent, FaultPlan, FaultTimeline, MigrationPolicy, ReplicaHealth,
};
pub use fleet::{Fleet, ReplicaSpec};
pub use global::GlobalPlane;
pub use router::{
    ClusterView, FairShare, JoinShortestQueue, PredictedCost, ReplicaView, RoundRobin, Router,
    RouterKind,
};
