//! The deterministic fault plane: pure-data fault plans materialized by
//! the cluster driver ONLY at barrier boundaries.
//!
//! A [`FaultPlan`] is a set of timed events — replica crashes
//! ([`FaultEvent::ReplicaDown`]), throughput brownouts
//! ([`FaultEvent::Slowdown`]), and KV-capacity losses
//! ([`FaultEvent::KvShrink`]) — fixed before the run starts (hand-built
//! presets or [`FaultPlan::seeded`]). Nothing about fault *timing* is
//! sampled during execution: the plan compiles into a [`FaultTimeline`]
//! of sorted start/end transitions, and the driver applies every
//! transition whose time has been crossed at the next barrier (routing
//! gate, plane-sync boundary, or end-of-run). Because barriers are the
//! only points where anything outside a replica touches it, both
//! [`DriveMode::Serial`] and [`DriveMode::Parallel`] observe the
//! identical fault state at the identical engine clocks — the zero-drift
//! contract extends to every fault plan unchanged.
//!
//! The module also hosts the two fault-response policies the driver
//! composes with a plan:
//!
//! - [`MigrationPolicy`] — what happens to a downed replica's queued and
//!   in-flight requests: re-place them on survivors via the router
//!   (`Migrate`, the default; decode progress is re-priced through the
//!   engine's rework-watermark recompute machinery), freeze them until
//!   recovery (`Wait`, the no-migration baseline), or discard them
//!   (`Drop`, a deliberately lossy negative control for the chaos
//!   harness — see `harness::broken`).
//! - [`AdmissionPolicy`] — gate-level load shedding: when the
//!   cluster-wide outstanding predicted backlog exceeds a bound, new
//!   arrivals are shed (with per-client accounting in `ClusterResult`)
//!   instead of routed — except, by default, arrivals from globally
//!   underserved clients, which keeps the shedding itself weight-fair.
//!
//! [`DriveMode::Serial`]: super::DriveMode::Serial
//! [`DriveMode::Parallel`]: super::DriveMode::Parallel

use crate::util::rng::Rng;

/// One timed fault. `at`/`until` are simulated cluster seconds; every
/// event is an interval `[at, until)` with automatic recovery at `until`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// The replica crashes at `at` and rejoins (empty, fast-forwarded to
    /// the recovery time) at `until`. Its queued and in-flight requests
    /// are handled per the run's [`MigrationPolicy`].
    ReplicaDown { at: f64, replica: usize, until: f64 },
    /// The replica's GPU throughput (compute AND memory bandwidth) is
    /// divided by `factor` (≥ 1) on `[at, until)` — thermal throttling,
    /// a noisy co-tenant. Overlapping slowdowns on one replica compose
    /// multiplicatively. KV capacity is unaffected.
    Slowdown { at: f64, replica: usize, factor: f64, until: f64 },
    /// `pages` KV pages become unavailable on `[at, until)` — adapter
    /// residency, co-located services. Overlapping shrinks add up
    /// (saturating at the pool size; already-allocated pages are never
    /// revoked — the reservation throttles new growth).
    KvShrink { at: f64, replica: usize, pages: u32, until: f64 },
}

impl FaultEvent {
    pub fn at(&self) -> f64 {
        match *self {
            FaultEvent::ReplicaDown { at, .. }
            | FaultEvent::Slowdown { at, .. }
            | FaultEvent::KvShrink { at, .. } => at,
        }
    }

    pub fn until(&self) -> f64 {
        match *self {
            FaultEvent::ReplicaDown { until, .. }
            | FaultEvent::Slowdown { until, .. }
            | FaultEvent::KvShrink { until, .. } => until,
        }
    }

    pub fn replica(&self) -> usize {
        match *self {
            FaultEvent::ReplicaDown { replica, .. }
            | FaultEvent::Slowdown { replica, .. }
            | FaultEvent::KvShrink { replica, .. } => replica,
        }
    }
}

/// A pure-data fault schedule, fixed before the run. Build by preset,
/// by [`FaultPlan::with_event`], or seeded; [`FaultPlan::validate`]
/// before handing it to the driver.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: a faultless run (the driver's default).
    pub fn none() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn with_event(mut self, ev: FaultEvent) -> FaultPlan {
        self.events.push(ev);
        self
    }

    /// One replica crashes at `at` and recovers at `until`.
    pub fn crash_recover(replica: usize, at: f64, until: f64) -> FaultPlan {
        FaultPlan::none().with_event(FaultEvent::ReplicaDown { at, replica, until })
    }

    /// One replica runs at `1/factor` throughput on `[at, until)`.
    pub fn brownout(replica: usize, factor: f64, at: f64, until: f64) -> FaultPlan {
        FaultPlan::none().with_event(FaultEvent::Slowdown { at, replica, factor, until })
    }

    /// One replica loses `pages` KV pages on `[at, until)`.
    pub fn kv_squeeze(replica: usize, pages: u32, at: f64, until: f64) -> FaultPlan {
        FaultPlan::none().with_event(FaultEvent::KvShrink { at, replica, pages, until })
    }

    /// A seeded random plan over an `n_replicas` fleet and a `horizon`-
    /// second trace: each replica independently draws one fault shape
    /// (or none). At most ONE crash is emitted per plan so the all-down
    /// guard in [`FaultPlan::validate`] holds by construction. Purely a
    /// function of `(seed, n_replicas, horizon)` — the plan is data, the
    /// run never samples.
    pub fn seeded(seed: u64, n_replicas: usize, horizon: f64) -> FaultPlan {
        let mut plan = FaultPlan::none();
        if n_replicas == 0 || !(horizon > 0.0) {
            return plan;
        }
        let mut rng = Rng::new(seed ^ 0xFA17_FA17_FA17_FA17);
        let mut frac = move || (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        let mut crashed = false;
        for replica in 0..n_replicas {
            let at = horizon * (0.15 + 0.35 * frac());
            let until = at + horizon * (0.1 + 0.4 * frac());
            let shape = (frac() * 4.0) as u32;
            match shape {
                0 if n_replicas > 1 && !crashed => {
                    crashed = true;
                    plan.events.push(FaultEvent::ReplicaDown { at, replica, until });
                }
                1 => {
                    let factor = 1.5 + 2.0 * frac();
                    plan.events.push(FaultEvent::Slowdown { at, replica, factor, until });
                }
                2 => {
                    let pages = 64 + (frac() * 512.0) as u32;
                    plan.events.push(FaultEvent::KvShrink { at, replica, pages, until });
                }
                _ => {}
            }
        }
        plan
    }

    /// The latest crash-recovery time in the plan (0 when no replica
    /// ever goes down) — the chaos harness measures post-recovery
    /// discrepancy from here.
    pub fn last_recovery_at(&self) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match *e {
                FaultEvent::ReplicaDown { until, .. } => Some(until),
                _ => None,
            })
            .fold(0.0, f64::max)
    }

    /// Structural validation against a fleet size: in-range replicas,
    /// finite forward intervals, sane slowdown factors, and — because a
    /// migrating driver must always have a survivor to place orphans on
    /// — never every replica down simultaneously.
    pub fn validate(&self, n_replicas: usize) -> anyhow::Result<()> {
        anyhow::ensure!(n_replicas > 0, "fault plan: the fleet is empty");
        for (i, ev) in self.events.iter().enumerate() {
            let (at, until, replica) = (ev.at(), ev.until(), ev.replica());
            anyhow::ensure!(
                replica < n_replicas,
                "fault event {i}: replica {replica} out of range (fleet has {n_replicas})"
            );
            anyhow::ensure!(
                at.is_finite() && at >= 0.0,
                "fault event {i}: start time {at} must be finite and non-negative"
            );
            anyhow::ensure!(
                until.is_finite() && until > at,
                "fault event {i}: end time {until} must be finite and after start {at}"
            );
            if let FaultEvent::Slowdown { factor, .. } = *ev {
                anyhow::ensure!(
                    factor.is_finite() && factor >= 1.0,
                    "fault event {i}: slowdown factor {factor} must be finite and >= 1"
                );
            }
        }
        // Down intervals only change state at their endpoints, so "all
        // down at some instant" implies "all down at the latest start
        // among the overlapping intervals" — checking each start covers
        // every instant.
        let downs: Vec<(f64, usize, f64)> = self
            .events
            .iter()
            .filter_map(|e| match *e {
                FaultEvent::ReplicaDown { at, replica, until } => Some((at, replica, until)),
                _ => None,
            })
            .collect();
        for &(t, _, _) in &downs {
            let mut down_now: Vec<usize> = downs
                .iter()
                .filter(|&&(a, _, u)| a <= t && t < u)
                .map(|&(_, r, _)| r)
                .collect();
            down_now.sort_unstable();
            down_now.dedup();
            anyhow::ensure!(
                down_now.len() < n_replicas,
                "fault plan takes every replica down simultaneously at t={t}"
            );
        }
        Ok(())
    }

    /// Compile into the driver's runtime view. Call [`validate`] first;
    /// the timeline assumes a well-formed plan.
    ///
    /// [`validate`]: FaultPlan::validate
    pub fn timeline(&self, n_replicas: usize) -> FaultTimeline {
        let mut transitions = Vec::with_capacity(2 * self.events.len());
        for (i, ev) in self.events.iter().enumerate() {
            let id = i as u32;
            let (start, end) = match *ev {
                FaultEvent::ReplicaDown { .. } => (Change::DownStart, Change::DownEnd),
                FaultEvent::Slowdown { factor, .. } => (Change::SlowStart(factor), Change::SlowEnd),
                FaultEvent::KvShrink { pages, .. } => (Change::ShrinkStart(pages), Change::ShrinkEnd),
            };
            let replica = ev.replica();
            transitions.push(Transition { at: ev.at(), seq: 2 * id, replica, change: start });
            transitions.push(Transition { at: ev.until(), seq: 2 * id + 1, replica, change: end });
        }
        // Time order with a stable, content-independent tie-break: two
        // transitions at the same instant apply in event order, ends
        // after starts of the same event — deterministic regardless of
        // drive mode.
        transitions.sort_by(|a, b| a.at.total_cmp(&b.at).then(a.seq.cmp(&b.seq)));
        FaultTimeline {
            transitions,
            cursor: 0,
            applied: 0,
            down_depth: vec![0; n_replicas],
            slow: vec![Vec::new(); n_replicas],
            shrink: vec![Vec::new(); n_replicas],
        }
    }
}

/// One edge of a fault interval.
#[derive(Debug, Clone, Copy)]
enum Change {
    DownStart,
    DownEnd,
    SlowStart(f64),
    SlowEnd,
    ShrinkStart(u32),
    ShrinkEnd,
}

#[derive(Debug, Clone, Copy)]
struct Transition {
    at: f64,
    /// `2·event_index + is_end` — the deterministic tie-break AND the
    /// key (via `seq >> 1`) matching an end edge to its start.
    seq: u32,
    replica: usize,
    change: Change,
}

/// The aggregate fault state of one replica at a barrier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaHealth {
    pub down: bool,
    /// Product of all active slowdown factors (1.0 = full speed).
    pub slowdown: f64,
    /// Sum of all active KV reservations, in pages.
    pub reserved_pages: u32,
}

impl ReplicaHealth {
    pub fn healthy() -> ReplicaHealth {
        ReplicaHealth { down: false, slowdown: 1.0, reserved_pages: 0 }
    }
}

/// A [`FaultPlan`] compiled into a cursor over sorted transitions plus
/// the per-replica active-fault state. The driver polls
/// [`next_transition_at`]/[`due`] at every barrier and calls
/// [`advance`] to apply everything crossed, then reads [`state`] for
/// each affected replica.
///
/// [`next_transition_at`]: FaultTimeline::next_transition_at
/// [`due`]: FaultTimeline::due
/// [`advance`]: FaultTimeline::advance
/// [`state`]: FaultTimeline::state
#[derive(Debug)]
pub struct FaultTimeline {
    transitions: Vec<Transition>,
    cursor: usize,
    applied: u64,
    down_depth: Vec<u32>,
    /// Active slowdowns per replica, `(event id, factor)` sorted by
    /// event id — the composition order is part of the determinism
    /// contract (f64 products are order-sensitive).
    slow: Vec<Vec<(u32, f64)>>,
    /// Active KV reservations per replica, `(event id, pages)`.
    shrink: Vec<Vec<(u32, u32)>>,
}

impl FaultTimeline {
    /// Time of the next unapplied transition; `INFINITY` when exhausted.
    /// A parallel-drive horizon bound, exactly like the plane's
    /// `next_sync_at`.
    pub fn next_transition_at(&self) -> f64 {
        self.transitions.get(self.cursor).map_or(f64::INFINITY, |t| t.at)
    }

    /// Is a transition due at cluster time `t`?
    pub fn due(&self, t: f64) -> bool {
        self.next_transition_at() <= t
    }

    pub fn has_pending(&self) -> bool {
        self.cursor < self.transitions.len()
    }

    /// Transitions applied so far (both edges count).
    pub fn applied(&self) -> u64 {
        self.applied
    }

    /// Apply every transition with time ≤ `t`; returns the affected
    /// replica ids, ascending and deduplicated.
    pub fn advance(&mut self, t: f64) -> Vec<usize> {
        let mut affected = Vec::new();
        while self.cursor < self.transitions.len() && self.transitions[self.cursor].at <= t {
            let tr = self.transitions[self.cursor];
            self.cursor += 1;
            self.applied += 1;
            let r = tr.replica;
            let id = tr.seq >> 1;
            match tr.change {
                Change::DownStart => self.down_depth[r] += 1,
                Change::DownEnd => self.down_depth[r] = self.down_depth[r].saturating_sub(1),
                Change::SlowStart(f) => {
                    let v = &mut self.slow[r];
                    let pos = v.partition_point(|e| e.0 < id);
                    v.insert(pos, (id, f));
                }
                Change::SlowEnd => self.slow[r].retain(|e| e.0 != id),
                Change::ShrinkStart(p) => {
                    let v = &mut self.shrink[r];
                    let pos = v.partition_point(|e| e.0 < id);
                    v.insert(pos, (id, p));
                }
                Change::ShrinkEnd => self.shrink[r].retain(|e| e.0 != id),
            }
            if !affected.contains(&r) {
                affected.push(r);
            }
        }
        affected.sort_unstable();
        affected
    }

    /// Join a scale-out replica to the timeline's per-replica state
    /// (healthy, no active faults) under the next replica id. Fault
    /// plans are validated against the *construction-time* fleet, so a
    /// grown replica can never be named by an event — it only needs
    /// state slots so `state()` stays in-bounds. Driver-thread barrier
    /// code (scale materialization) — mode-invariant.
    pub fn grow(&mut self) {
        self.down_depth.push(0);
        self.slow.push(Vec::new());
        self.shrink.push(Vec::new());
    }

    /// The replica's aggregate fault state after the last `advance`.
    pub fn state(&self, replica: usize) -> ReplicaHealth {
        let slowdown = self.slow[replica].iter().fold(1.0, |acc, &(_, f)| acc * f);
        let reserved =
            self.shrink[replica].iter().fold(0u32, |acc, &(_, p)| acc.saturating_add(p));
        ReplicaHealth { down: self.down_depth[replica] > 0, slowdown, reserved_pages: reserved }
    }
}

/// What the driver does with a downed replica's queued and in-flight
/// requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MigrationPolicy {
    /// Extract them as orphans and re-place each on a surviving replica
    /// through the router (fresh router-plane estimate, same path as an
    /// arrival). Decode progress is preserved through the engine's
    /// rework watermark: the destination re-runs the prefill+decode
    /// compute, but the tokens already credited at the origin are never
    /// re-credited — exact service conservation.
    #[default]
    Migrate,
    /// Leave everything frozen on the dead replica; it resumes at
    /// recovery. The no-migration baseline the acceptance comparison
    /// runs against.
    Wait,
    /// Extract and silently discard — request loss. Exists ONLY as the
    /// chaos harness's negative control (`harness::broken`): the
    /// conservation-modulo-shed check must fail under it.
    Drop,
}

impl MigrationPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            MigrationPolicy::Migrate => "migrate",
            MigrationPolicy::Wait => "wait",
            MigrationPolicy::Drop => "drop",
        }
    }
}

/// Gate-level load shedding: when the fleet-wide outstanding predicted
/// backlog (router-estimated weighted tokens routed but not yet
/// delivered, alive replicas only) exceeds the bound, new arrivals are
/// shed instead of routed — recorded per client in `ClusterResult::shed`,
/// never silently lost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionPolicy {
    /// Shed when outstanding weighted tokens exceed this. `INFINITY`
    /// disables shedding (the default).
    pub max_outstanding_weighted: f64,
    /// Never shed arrivals from globally underserved clients (the
    /// plane's bottom HF band) — overload control must not become a
    /// starvation vector, so the shedding burden falls on the clients
    /// driving the backlog. This is what makes shedding weight-fair.
    pub protect_underserved: bool,
}

impl AdmissionPolicy {
    pub fn unlimited() -> AdmissionPolicy {
        AdmissionPolicy { max_outstanding_weighted: f64::INFINITY, protect_underserved: true }
    }

    pub fn bounded(max_outstanding_weighted: f64) -> AdmissionPolicy {
        AdmissionPolicy { max_outstanding_weighted, protect_underserved: true }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        // NaN fails the comparison too.
        anyhow::ensure!(
            self.max_outstanding_weighted > 0.0,
            "admission bound must be positive (got {})",
            self.max_outstanding_weighted
        );
        Ok(())
    }
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy::unlimited()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_rejects_malformed_events() {
        assert!(FaultPlan::crash_recover(3, 1.0, 2.0).validate(3).is_err(), "replica range");
        assert!(FaultPlan::crash_recover(0, 2.0, 1.0).validate(2).is_err(), "inverted interval");
        assert!(FaultPlan::crash_recover(0, f64::NAN, 1.0).validate(2).is_err(), "NaN start");
        assert!(FaultPlan::crash_recover(0, 1.0, f64::INFINITY).validate(2).is_err(), "inf end");
        assert!(FaultPlan::brownout(0, 0.5, 1.0, 2.0).validate(2).is_err(), "speedup factor");
        assert!(FaultPlan::brownout(0, 2.0, 1.0, 2.0).validate(2).is_ok());
        assert!(FaultPlan::none().validate(0).is_err(), "empty fleet");
    }

    #[test]
    fn validate_rejects_all_replicas_down() {
        // Overlapping crashes covering the whole 2-replica fleet.
        let plan = FaultPlan::crash_recover(0, 1.0, 5.0)
            .with_event(FaultEvent::ReplicaDown { at: 2.0, replica: 1, until: 3.0 });
        assert!(plan.validate(2).is_err());
        // Same plan over 3 replicas: one survivor remains — fine.
        assert!(plan.validate(3).is_ok());
        // Disjoint crashes on a 2-replica fleet: fine.
        let disjoint = FaultPlan::crash_recover(0, 1.0, 2.0)
            .with_event(FaultEvent::ReplicaDown { at: 2.0, replica: 1, until: 3.0 });
        assert!(disjoint.validate(2).is_ok());
    }

    #[test]
    fn timeline_applies_transitions_in_time_order() {
        let plan = FaultPlan::crash_recover(1, 2.0, 4.0)
            .with_event(FaultEvent::Slowdown { at: 1.0, replica: 0, factor: 2.0, until: 3.0 });
        plan.validate(2).unwrap();
        let mut tl = plan.timeline(2);
        assert_eq!(tl.next_transition_at(), 1.0);
        assert!(!tl.due(0.5));
        assert!(tl.due(1.0));

        assert_eq!(tl.advance(1.5), vec![0]);
        assert_eq!(tl.state(0), ReplicaHealth { down: false, slowdown: 2.0, reserved_pages: 0 });
        assert_eq!(tl.state(1), ReplicaHealth::healthy());

        // Crossing 2.0 and 3.0 at once: replica 1 goes down, replica 0
        // recovers its speed.
        assert_eq!(tl.advance(3.5), vec![0, 1]);
        assert!(tl.state(1).down);
        assert_eq!(tl.state(0), ReplicaHealth::healthy());

        assert_eq!(tl.advance(10.0), vec![1]);
        assert_eq!(tl.state(1), ReplicaHealth::healthy());
        assert!(!tl.has_pending());
        assert_eq!(tl.applied(), 4);
        assert!(tl.next_transition_at().is_infinite());
    }

    #[test]
    fn overlapping_slowdowns_compose_multiplicatively() {
        let plan = FaultPlan::brownout(0, 2.0, 1.0, 5.0)
            .with_event(FaultEvent::Slowdown { at: 2.0, replica: 0, factor: 1.5, until: 4.0 });
        plan.validate(1).unwrap();
        let mut tl = plan.timeline(1);
        tl.advance(2.5);
        assert_eq!(tl.state(0).slowdown, 3.0);
        tl.advance(4.5);
        assert_eq!(tl.state(0).slowdown, 2.0);
    }

    #[test]
    fn kv_shrinks_add_up_and_release() {
        let plan = FaultPlan::kv_squeeze(0, 100, 1.0, 5.0)
            .with_event(FaultEvent::KvShrink { at: 2.0, replica: 0, pages: 50, until: 3.0 });
        plan.validate(1).unwrap();
        let mut tl = plan.timeline(1);
        tl.advance(2.0);
        assert_eq!(tl.state(0).reserved_pages, 150);
        tl.advance(3.0);
        assert_eq!(tl.state(0).reserved_pages, 100);
        tl.advance(5.0);
        assert_eq!(tl.state(0).reserved_pages, 0);
    }

    #[test]
    fn grown_replica_starts_healthy_and_stays_unaddressed() {
        let plan = FaultPlan::crash_recover(0, 1.0, 4.0);
        plan.validate(2).unwrap();
        let mut tl = plan.timeline(2);
        tl.advance(2.0);
        tl.grow();
        assert_eq!(tl.state(2), ReplicaHealth::healthy());
        assert!(tl.state(0).down);
        // Remaining transitions keep addressing the original fleet.
        tl.advance(10.0);
        assert_eq!(tl.state(0), ReplicaHealth::healthy());
        assert_eq!(tl.state(2), ReplicaHealth::healthy());
    }

    #[test]
    fn last_recovery_at_tracks_crashes_only() {
        assert_eq!(FaultPlan::none().last_recovery_at(), 0.0);
        assert_eq!(FaultPlan::brownout(0, 2.0, 1.0, 9.0).last_recovery_at(), 0.0);
        let plan = FaultPlan::crash_recover(0, 1.0, 4.0)
            .with_event(FaultEvent::ReplicaDown { at: 5.0, replica: 1, until: 7.0 });
        assert_eq!(plan.last_recovery_at(), 7.0);
    }

    #[test]
    fn seeded_plans_validate_and_replay() {
        for seed in [1u64, 42, 2024, 0xDEAD_BEEF] {
            let plan = FaultPlan::seeded(seed, 4, 30.0);
            plan.validate(4).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert_eq!(plan, FaultPlan::seeded(seed, 4, 30.0), "seeded plan must replay");
            let crashes = plan
                .events
                .iter()
                .filter(|e| matches!(e, FaultEvent::ReplicaDown { .. }))
                .count();
            assert!(crashes <= 1, "seed {seed}: at most one crash per seeded plan");
        }
        assert!(FaultPlan::seeded(7, 0, 30.0).is_empty());
        assert!(FaultPlan::seeded(7, 4, 0.0).is_empty());
    }

    #[test]
    fn admission_policy_validates() {
        assert!(AdmissionPolicy::unlimited().validate().is_ok());
        assert!(AdmissionPolicy::bounded(50_000.0).validate().is_ok());
        assert!(AdmissionPolicy::bounded(0.0).validate().is_err());
        assert!(AdmissionPolicy::bounded(-1.0).validate().is_err());
        assert!(AdmissionPolicy::bounded(f64::NAN).validate().is_err());
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::unlimited());
    }

    #[test]
    fn migration_policy_default_and_labels() {
        assert_eq!(MigrationPolicy::default(), MigrationPolicy::Migrate);
        assert_eq!(MigrationPolicy::Migrate.label(), "migrate");
        assert_eq!(MigrationPolicy::Wait.label(), "wait");
        assert_eq!(MigrationPolicy::Drop.label(), "drop");
    }
}
