//! Pluggable request→replica routing policies.
//!
//! The router decides *placement only*: ordering within a replica stays
//! with that replica's local scheduler. Routing sees a deterministic
//! snapshot of every replica (clock, queue depth, outstanding predicted
//! work, KV headroom, peak throughput) plus the global dual-counter plane
//! — never a request's true output length (the same information rule the
//! schedulers live under; `PredictedCost`/`FairShare` consume the
//! router-plane MoPE estimate the driver attaches).
//!
//! Policies:
//! - [`RoundRobin`] — placement by arrival count, blind to everything.
//! - [`JoinShortestQueue`] — min queued+running requests.
//! - [`PredictedCost`] — min predicted backlog seconds (MoPE-estimated
//!   outstanding work ÷ replica peak weighted throughput), the
//!   heterogeneity-aware load balancer.
//! - [`FairShare`] — `PredictedCost` made fairness- and locality-aware:
//!   a hard KV-headroom filter (never park work on an exhausted replica
//!   while another has room), sticky session affinity so multi-turn
//!   clients keep their prefix KV warm, and a global-HF override that
//!   routes underserved clients to the fastest-draining replica even
//!   when affinity says otherwise — minimising predicted growth of the
//!   cluster-wide HF spread.

use super::global::GlobalPlane;
use crate::core::{ClientId, Request};
use std::collections::BTreeMap;

/// Deterministic snapshot of one replica at a routing decision.
#[derive(Debug, Clone)]
pub struct ReplicaView {
    pub id: usize,
    /// Replica engine clock (may lag the arrival by up to one iteration).
    pub clock: f64,
    /// Requests queued in the replica's scheduler.
    pub queued: usize,
    /// Requests resident in the running batch.
    pub running: usize,
    /// Router-estimated weighted tokens routed but not yet delivered.
    pub outstanding_weighted: f64,
    pub kv_free_tokens: u64,
    pub kv_total_tokens: u64,
    /// Peak weighted-token throughput (wtok/s) of this replica, already
    /// derated by any active slowdown fault.
    pub peak_weighted_tps: f64,
    pub max_batch: usize,
    /// Fault-plane liveness: every router must skip dead replicas while
    /// any alive one exists (the driver's fault plan guarantees at least
    /// one survivor at all times).
    pub alive: bool,
    /// Active slowdown divisor (1.0 = full speed) — informational;
    /// `peak_weighted_tps` already reflects it.
    pub slowdown: f64,
}

impl ReplicaView {
    /// Can this replica hold the request's prompt plus its *estimated*
    /// output without evicting (one page of slack)?
    pub fn kv_headroom(&self, req: &Request, est_out: u32) -> bool {
        req.input_tokens as u64 + est_out as u64 + 16 <= self.kv_free_tokens
    }

    /// Predicted backlog seconds after adding `extra` weighted tokens —
    /// the heterogeneity-aware load metric (outstanding work normalised
    /// by what this replica can actually sustain).
    pub fn load_seconds(&self, extra: f64) -> f64 {
        (self.outstanding_weighted + extra) / self.peak_weighted_tps.max(1e-9)
    }
}

/// Everything a routing decision may read.
pub struct ClusterView<'a> {
    pub replicas: &'a [ReplicaView],
    pub global: &'a GlobalPlane,
}

/// A request→replica placement policy.
///
/// Fleet-mutation contract (the autoscale plane depends on it): the
/// view's replica count may GROW between calls (scale-out appends new
/// ids) and replicas may permanently leave via `alive: false`
/// (scale-in drains). A router must therefore never cache
/// `view.replicas.len()` across calls, and any per-client replica
/// memory (like [`FairShare`]'s sticky map) must bounds-check and
/// liveness-check the remembered id before honoring it.
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Choose the replica for `req`. `est_out`/`est_weighted` are the
    /// router-plane output estimate and the corresponding weighted-token
    /// work. Must return an index < `view.replicas.len()`.
    fn route(&mut self, req: &Request, est_out: u32, est_weighted: f64, view: &ClusterView)
        -> usize;
}

/// Selector for the built-in routers (CLI / conformance axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    RoundRobin,
    JoinShortestQueue,
    PredictedCost,
    FairShare,
}

impl RouterKind {
    pub fn label(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round_robin",
            RouterKind::JoinShortestQueue => "jsq",
            RouterKind::PredictedCost => "predicted_cost",
            RouterKind::FairShare => "fair_share",
        }
    }

    pub fn make(&self) -> Box<dyn Router> {
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobin::new()),
            RouterKind::JoinShortestQueue => Box::new(JoinShortestQueue),
            RouterKind::PredictedCost => Box::new(PredictedCost),
            RouterKind::FairShare => Box::new(FairShare::new()),
        }
    }

    pub fn by_name(name: &str) -> Option<RouterKind> {
        match name {
            "round_robin" | "rr" => Some(RouterKind::RoundRobin),
            "jsq" => Some(RouterKind::JoinShortestQueue),
            "predicted_cost" | "cost" => Some(RouterKind::PredictedCost),
            "fair_share" | "fair" => Some(RouterKind::FairShare),
            _ => None,
        }
    }
}

/// Arrival-count round robin.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn route(&mut self, _req: &Request, _est_out: u32, _est: f64, view: &ClusterView) -> usize {
        // Skip dead replicas without disturbing the cycle shape: advance
        // the cursor at most once per replica until an alive one comes up
        // (whole fleet dead cannot happen — the fault plan keeps a
        // survivor — but degrade to plain cycling rather than spinning).
        let n = view.replicas.len();
        for _ in 0..n {
            let r = self.next % n;
            self.next = self.next.wrapping_add(1);
            if view.replicas[r].alive {
                return r;
            }
        }
        let r = self.next % n;
        self.next = self.next.wrapping_add(1);
        r
    }
}

/// Fewest queued+running requests; ties break on replica id.
#[derive(Debug, Default)]
pub struct JoinShortestQueue;

impl Router for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn route(&mut self, _req: &Request, _est_out: u32, _est: f64, view: &ClusterView) -> usize {
        alive_or_all(view)
            .min_by_key(|v| (v.queued + v.running, v.id))
            .map(|v| v.id)
            .expect("non-empty fleet")
    }
}

/// Alive replicas, or (degenerate: whole fleet dead — the driver's fault
/// plan forbids it) every replica, as an iterator of refs.
fn alive_or_all<'a>(view: &'a ClusterView) -> impl Iterator<Item = &'a ReplicaView> {
    let any_alive = view.replicas.iter().any(|v| v.alive);
    view.replicas.iter().filter(move |v| !any_alive || v.alive)
}

/// Minimum predicted backlog seconds including this request — the
/// MoPE-estimated work ÷ replica peak throughput balancer.
#[derive(Debug, Default)]
pub struct PredictedCost;

fn min_load(pool: &[&ReplicaView], est: f64) -> usize {
    pool.iter()
        .min_by(|a, b| {
            a.load_seconds(est).total_cmp(&b.load_seconds(est)).then(a.id.cmp(&b.id))
        })
        .map(|v| v.id)
        .expect("non-empty pool")
}

impl Router for PredictedCost {
    fn name(&self) -> &'static str {
        "predicted_cost"
    }

    fn route(&mut self, _req: &Request, _est_out: u32, est: f64, view: &ClusterView) -> usize {
        let pool: Vec<&ReplicaView> = alive_or_all(view).collect();
        min_load(&pool, est)
    }
}

/// Fairness- and locality-aware predicted-cost routing (see module docs).
#[derive(Debug)]
pub struct FairShare {
    /// Last replica each client was routed to (prefix/KV locality).
    sticky: BTreeMap<ClientId, usize>,
    /// Sticky replica tolerated while its predicted backlog exceeds the
    /// best replica's by at most this many SECONDS — an absolute queueing
    /// price for locality. (A relative slack collapses whenever the best
    /// replica is idle: any nonzero backlog would break affinity.)
    pub affinity_tolerance: f64,
}

impl FairShare {
    pub fn new() -> Self {
        FairShare { sticky: BTreeMap::new(), affinity_tolerance: 1.5 }
    }
}

impl Default for FairShare {
    fn default() -> Self {
        Self::new()
    }
}

impl Router for FairShare {
    fn name(&self) -> &'static str {
        "fair_share"
    }

    fn route(&mut self, req: &Request, est_out: u32, est: f64, view: &ClusterView) -> usize {
        // Liveness first, then the hard KV filter: a backlogged client
        // must never be parked on a dead replica or an exhausted one
        // while an alive replica with headroom exists (the properties
        // the router tests pin). Only when NO alive replica has headroom
        // does the alive fleet become eligible again.
        let with_room: Vec<&ReplicaView> =
            alive_or_all(view).filter(|v| v.kv_headroom(req, est_out)).collect();
        let pool: Vec<&ReplicaView> = if with_room.is_empty() {
            alive_or_all(view).collect()
        } else {
            with_room
        };
        let best = min_load(&pool, est);
        let best_load = view.replicas[best].load_seconds(est);

        // Sticky affinity: multi-turn clients keep their KV/prefix
        // locality as long as the sticky replica is feasible and not
        // materially slower — EXCEPT for globally underserved clients,
        // whose next token matters more than their cache: they go to the
        // fastest-draining replica unconditionally (this is the move that
        // shrinks predicted global HF spread).
        if let Some(&s) = self.sticky.get(&req.client) {
            if s < view.replicas.len() && !view.global.is_underserved(req.client) {
                let sv = &view.replicas[s];
                // A dead sticky replica fails over: affinity is a cache
                // optimisation, not a correctness anchor. The fresh
                // `best` below overwrites the sticky entry, so the
                // client re-homes on the survivor.
                if sv.alive
                    && sv.kv_headroom(req, est_out)
                    && sv.load_seconds(est) <= best_load + self.affinity_tolerance
                {
                    return s;
                }
            }
        }
        self.sticky.insert(req.client, best);
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestId;
    use crate::sched::HfParams;

    fn view(id: usize, outstanding: f64, kv_free: u64, peak: f64) -> ReplicaView {
        ReplicaView {
            id,
            clock: 0.0,
            queued: 0,
            running: 0,
            outstanding_weighted: outstanding,
            kv_free_tokens: kv_free,
            kv_total_tokens: 1 << 20,
            peak_weighted_tps: peak,
            max_batch: 256,
            alive: true,
            slowdown: 1.0,
        }
    }

    fn req(client: u32) -> Request {
        Request::new(RequestId(1), ClientId(client), 100, 100, 0.0)
    }

    fn plane() -> GlobalPlane {
        GlobalPlane::new(2, 1.0, HfParams::default())
    }

    #[test]
    fn round_robin_cycles() {
        let g = plane();
        let vs = vec![view(0, 0.0, 1 << 20, 1e4), view(1, 0.0, 1 << 20, 1e4)];
        let cv = ClusterView { replicas: &vs, global: &g };
        let mut r = RoundRobin::new();
        let picks: Vec<usize> =
            (0..4).map(|_| r.route(&req(0), 100, 500.0, &cv)).collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn jsq_prefers_shallow_queue() {
        let g = plane();
        let mut vs = vec![view(0, 0.0, 1 << 20, 1e4), view(1, 0.0, 1 << 20, 1e4)];
        vs[0].queued = 5;
        let cv = ClusterView { replicas: &vs, global: &g };
        assert_eq!(JoinShortestQueue.route(&req(0), 100, 500.0, &cv), 1);
    }

    #[test]
    fn predicted_cost_normalises_by_replica_speed() {
        let g = plane();
        // Replica 0 holds 2× the work of replica 1 but is 4× faster —
        // its predicted backlog is shorter, so it wins. A raw-work
        // balancer (or JSQ) would pick replica 1.
        let vs = vec![view(0, 20_000.0, 1 << 20, 40_000.0), view(1, 10_000.0, 1 << 20, 10_000.0)];
        let cv = ClusterView { replicas: &vs, global: &g };
        assert_eq!(PredictedCost.route(&req(0), 100, 500.0, &cv), 0);
    }

    #[test]
    fn fair_share_never_routes_to_kv_exhausted_replica_with_alternatives() {
        let g = plane();
        // Replica 0 is nearly idle but KV-exhausted; replica 1 has room.
        let vs = vec![view(0, 0.0, 64, 1e4), view(1, 50_000.0, 1 << 20, 1e4)];
        let cv = ClusterView { replicas: &vs, global: &g };
        let mut r = FairShare::new();
        assert_eq!(r.route(&req(0), 400, 500.0, &cv), 1);
        // With no headroom anywhere, the fleet is eligible again.
        let vs = vec![view(0, 0.0, 64, 1e4), view(1, 50_000.0, 32, 1e4)];
        let cv = ClusterView { replicas: &vs, global: &g };
        assert_eq!(r.route(&req(0), 400, 500.0, &cv), 0, "least-loaded when all exhausted");
    }

    #[test]
    fn fair_share_sticky_affinity_holds_within_slack() {
        // Client 7 must be known to the plane and OUTSIDE the underserved
        // band — underserved clients deliberately ignore affinity.
        let mut g = GlobalPlane::new(1, 1.0, HfParams::default());
        {
            use crate::sched::{Scheduler, Vtc};
            let mut s = Vtc::new();
            s.enqueue(Request::new(RequestId(10), ClientId(7), 5000, 10, 0.0), 0.0);
            s.enqueue(Request::new(RequestId(11), ClientId(3), 100, 10, 0.0), 0.0);
            let _ = s.pick(0.0, &mut |_| true).unwrap();
            let _ = s.pick(0.0, &mut |_| true).unwrap();
            g.pull_replica(0, &s);
            g.finish_sync(1.0);
        }
        assert!(!g.is_underserved(ClientId(7)), "test setup: c7 must not be underserved");
        let vs = vec![view(0, 1000.0, 1 << 20, 1e4), view(1, 900.0, 1 << 20, 1e4)];
        let cv = ClusterView { replicas: &vs, global: &g };
        let mut r = FairShare::new();
        // First route establishes stickiness on the best replica (1).
        assert_eq!(r.route(&req(7), 100, 500.0, &cv), 1);
        // Replica 1 now slightly worse, but within the absolute backlog
        // tolerance → sticky wins.
        let vs = vec![view(0, 900.0, 1 << 20, 1e4), view(1, 1000.0, 1 << 20, 1e4)];
        let cv = ClusterView { replicas: &vs, global: &g };
        assert_eq!(r.route(&req(7), 100, 500.0, &cv), 1, "affinity within tolerance");
        // Many seconds of extra backlog → rebalance to the best replica.
        let vs = vec![view(0, 900.0, 1 << 20, 1e4), view(1, 90_000.0, 1 << 20, 1e4)];
        let cv = ClusterView { replicas: &vs, global: &g };
        assert_eq!(r.route(&req(7), 100, 500.0, &cv), 0, "affinity yields under imbalance");
    }

    /// Property sweep: across randomized fleets and request shapes,
    /// FairShare NEVER places a request (in particular a backlogged
    /// min-HF client's — every unknown client is min-HF to the plane) on
    /// a KV-exhausted replica while any other replica has headroom.
    #[test]
    fn prop_fair_share_always_prefers_kv_headroom() {
        use crate::util::rng::Rng;
        let g = plane();
        let mut rng = Rng::new(2024);
        let mut r = FairShare::new();
        for case in 0..500u64 {
            let n = 2 + (rng.next_u64() % 6) as usize;
            let vs: Vec<ReplicaView> = (0..n)
                .map(|id| {
                    let exhausted = rng.next_u64() % 3 == 0;
                    view(
                        id,
                        (rng.next_u64() % 50_000) as f64,
                        if exhausted { rng.next_u64() % 128 } else { 1 << 20 },
                        10_000.0 + (rng.next_u64() % 10_000) as f64,
                    )
                })
                .collect();
            let cv = ClusterView { replicas: &vs, global: &g };
            let client = (rng.next_u64() % 16) as u32;
            let est_out = 64 + (rng.next_u64() % 512) as u32;
            let rq = req(client);
            let est = rq.input_tokens as f64 + 4.0 * est_out as f64;
            let choice = r.route(&rq, est_out, est, &cv);
            let any_room = vs.iter().any(|v| v.kv_headroom(&rq, est_out));
            if any_room {
                assert!(
                    vs[choice].kv_headroom(&rq, est_out),
                    "case {case}: routed to exhausted replica {choice} of {n} with room elsewhere"
                );
            }
        }
    }

    /// Degraded-fleet property sweep: with random down flags layered on
    /// the randomized fleets, EVERY router skips dead replicas while an
    /// alive one exists, and FairShare additionally never places work on
    /// a KV-exhausted replica while an alive replica with headroom
    /// exists.
    #[test]
    fn prop_routers_never_pick_dead_replicas() {
        use crate::util::rng::Rng;
        let g = plane();
        let mut rng = Rng::new(7_2024);
        let mut fair = FairShare::new();
        let mut rr = RoundRobin::new();
        for case in 0..500u64 {
            let n = 2 + (rng.next_u64() % 6) as usize;
            let mut vs: Vec<ReplicaView> = (0..n)
                .map(|id| {
                    let exhausted = rng.next_u64() % 3 == 0;
                    view(
                        id,
                        (rng.next_u64() % 50_000) as f64,
                        if exhausted { rng.next_u64() % 128 } else { 1 << 20 },
                        10_000.0 + (rng.next_u64() % 10_000) as f64,
                    )
                })
                .collect();
            // Take replicas down at random, but never the whole fleet
            // (the driver's fault-plan validation guarantees the same).
            for v in vs.iter_mut() {
                v.alive = rng.next_u64() % 3 != 0;
            }
            if !vs.iter().any(|v| v.alive) {
                let keep = (rng.next_u64() % n as u64) as usize;
                vs[keep].alive = true;
            }
            let cv = ClusterView { replicas: &vs, global: &g };
            let client = (rng.next_u64() % 16) as u32;
            let est_out = 64 + (rng.next_u64() % 512) as u32;
            let rq = req(client);
            let est = rq.input_tokens as f64 + 4.0 * est_out as f64;
            for (name, choice) in [
                ("round_robin", rr.route(&rq, est_out, est, &cv)),
                ("jsq", JoinShortestQueue.route(&rq, est_out, est, &cv)),
                ("predicted_cost", PredictedCost.route(&rq, est_out, est, &cv)),
                ("fair_share", fair.route(&rq, est_out, est, &cv)),
            ] {
                assert!(
                    vs[choice].alive,
                    "case {case}: {name} routed to dead replica {choice} of {n}"
                );
            }
            // FairShare's KV property, now among ALIVE replicas only.
            let fair_choice = fair.route(&rq, est_out, est, &cv);
            let any_alive_room = vs.iter().any(|v| v.alive && v.kv_headroom(&rq, est_out));
            if any_alive_room {
                assert!(
                    vs[fair_choice].alive && vs[fair_choice].kv_headroom(&rq, est_out),
                    "case {case}: fair_share parked work on replica {fair_choice} \
                     (alive={}, headroom={}) with a viable alternative",
                    vs[fair_choice].alive,
                    vs[fair_choice].kv_headroom(&rq, est_out)
                );
            }
        }
    }

    #[test]
    fn sticky_affinity_fails_over_when_the_replica_dies() {
        // Client 7 must sit OUTSIDE the underserved band (underserved
        // clients bypass affinity entirely) — same setup as the
        // affinity-within-slack test.
        let mut g = GlobalPlane::new(1, 1.0, HfParams::default());
        {
            use crate::sched::{Scheduler, Vtc};
            let mut s = Vtc::new();
            s.enqueue(Request::new(RequestId(10), ClientId(7), 5000, 10, 0.0), 0.0);
            s.enqueue(Request::new(RequestId(11), ClientId(3), 100, 10, 0.0), 0.0);
            let _ = s.pick(0.0, &mut |_| true).unwrap();
            let _ = s.pick(0.0, &mut |_| true).unwrap();
            g.pull_replica(0, &s);
            g.finish_sync(1.0);
        }
        assert!(!g.is_underserved(ClientId(7)), "test setup: c7 must not be underserved");
        let mut vs = vec![view(0, 900.0, 1 << 20, 1e4), view(1, 1000.0, 1 << 20, 1e4)];
        let cv = ClusterView { replicas: &vs, global: &g };
        let mut r = FairShare::new();
        assert_eq!(r.route(&req(7), 100, 500.0, &cv), 0, "establish affinity on 0");
        vs[0].alive = false;
        let cv = ClusterView { replicas: &vs, global: &g };
        assert_eq!(r.route(&req(7), 100, 500.0, &cv), 1, "dead sticky must fail over");
        // The failover re-homed the client: replica 0's revival does not
        // pull it back while the new home stays within tolerance.
        vs[0].alive = true;
        let cv = ClusterView { replicas: &vs, global: &g };
        assert_eq!(r.route(&req(7), 100, 500.0, &cv), 1, "affinity re-homed on survivor");
    }

    #[test]
    fn routers_absorb_mid_run_fleet_growth_and_drain() {
        // The autoscale contract: the same router instance sees the view
        // grow (scale-out) and a replica permanently die (drain) across
        // calls, and every pick stays in-bounds and alive.
        let g = plane();
        let mut rr = RoundRobin::new();
        let mut fair = FairShare::new();
        let two = vec![view(0, 1000.0, 1 << 20, 1e4), view(1, 900.0, 1 << 20, 1e4)];
        let cv = ClusterView { replicas: &two, global: &g };
        for router in [&mut rr as &mut dyn Router, &mut fair] {
            let c = router.route(&req(7), 100, 500.0, &cv);
            assert!(c < 2);
        }
        // Grow to three: the new replica is idle, so load-aware routers
        // must discover it without any registration step.
        let three = vec![
            view(0, 50_000.0, 1 << 20, 1e4),
            view(1, 50_000.0, 1 << 20, 1e4),
            view(2, 0.0, 1 << 20, 1e4),
        ];
        let cv = ClusterView { replicas: &three, global: &g };
        assert_eq!(PredictedCost.route(&req(0), 100, 500.0, &cv), 2);
        assert_eq!(fair.route(&req(9), 100, 500.0, &cv), 2);
        for _ in 0..3 {
            let c = rr.route(&req(0), 100, 500.0, &cv);
            assert!(c < 3, "round robin must cycle over the grown fleet");
        }
        // Drain replica 2 (retired: alive=false forever). A client whose
        // sticky home retired must re-home, and nothing may pick it.
        let mut drained = three.clone();
        drained[2].alive = false;
        let cv = ClusterView { replicas: &drained, global: &g };
        for _ in 0..4 {
            assert_ne!(rr.route(&req(0), 100, 500.0, &cv), 2);
        }
        let c = fair.route(&req(9), 100, 500.0, &cv);
        assert!(c < 2, "sticky client re-homes off the drained replica");
        // A router that remembered the 3-replica fleet must also survive
        // the view SHRINKING back (defensive: the driver keeps retired
        // replicas in the view, but the contract is stated on len()).
        let cv = ClusterView { replicas: &two, global: &g };
        let c = fair.route(&req(9), 100, 500.0, &cv);
        assert!(c < 2, "sticky ids beyond len() must not be honored");
    }

    #[test]
    fn router_kind_roundtrip() {
        for kind in [
            RouterKind::RoundRobin,
            RouterKind::JoinShortestQueue,
            RouterKind::PredictedCost,
            RouterKind::FairShare,
        ] {
            assert_eq!(RouterKind::by_name(kind.label()), Some(kind));
            assert_eq!(kind.make().name(), kind.label());
        }
        assert!(RouterKind::by_name("nope").is_none());
    }
}
