//! The lock-step cluster driver: N independent engines interleaved
//! deterministically, with online routing and a periodically-synced
//! global counter plane.
//!
//! # Determinism
//!
//! The driver always steps the *lagging* runnable replica (minimum
//! engine clock, stable replica-id tie-break), and never lets any
//! replica step uncapped past the next unrouted arrival: every step is
//! bounded by that arrival time exactly the way the single engine bounds
//! its own macro-steps by its queued arrivals. A request is routed once
//! every runnable replica's clock has reached its arrival (idle-empty
//! replicas don't gate — injecting wakes them through the engine's own
//! idle fast-forward), so the routing snapshot is as fresh as the
//! engines can make it: stale by at most one straddling iteration.
//!
//! The consequence that the differential tests pin: a 1-replica cluster
//! executes the *identical* pass sequence to the plain
//! `Simulation::run`, bit for bit, for every router — the cluster layer
//! adds zero behavioral drift.
//!
//! # Counter staleness
//!
//! The global plane pulls per-replica counter snapshots when the cluster
//! time (min runnable clock) crosses a sync boundary. Replicas ahead of
//! the boundary contribute slightly newer state, lagging ones older —
//! bounded by `sync_period` plus one iteration either way. The
//! conformance cells measure cross-replica discrepancy *under* that
//! staleness, which is the experiment the paper's bounded-discrepancy
//! claim needs.

use super::fleet::{Fleet, ReplicaSpec};
use super::global::GlobalPlane;
use super::router::{ClusterView, ReplicaView, Router};
use crate::core::{ClientId, Request};
use crate::exp::{make_pred, make_sched, PredKind, SchedKind};
use crate::metrics::LatencyStats;
use crate::predictor::{predict_request, PerfMap, Predictor};
use crate::sched::{HfParams, Scheduler};
use crate::sim::{step_once, RunState, SimConfig, SimResult};
use crate::workload::Trace;
use std::collections::{BTreeMap, BTreeSet};

/// Cluster-level options beyond the fleet itself.
#[derive(Debug, Clone)]
pub struct ClusterOpts {
    /// Engine base config (sample period, step mode, drain, max
    /// iterations); per-replica GPU/host come from the `ReplicaSpec`s.
    pub base: SimConfig,
    /// Global counter plane sync period in seconds (≤ 0 disables
    /// periodic sync; the plane still merges once at the end).
    pub sync_period: f64,
    /// Base seed: replica r's predictor derives its stream from
    /// `seed + r·φ` (replica 0 keeps the base seed, so a solo cluster
    /// reproduces the plain engine's stream exactly).
    pub seed: u64,
}

impl ClusterOpts {
    pub fn new(seed: u64) -> ClusterOpts {
        ClusterOpts { base: SimConfig::a100_7b_vllm(), sync_period: 1.0, seed }
    }
}

fn replica_seed(base: u64, replica: usize) -> u64 {
    base.wrapping_add((replica as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// One replica: an owned scheduler/predictor/perfmap plus the resumable
/// engine state. The engine itself is the *unmodified* single-GPU engine
/// — the cluster composes it, it does not fork it.
struct Replica {
    spec: ReplicaSpec,
    cfg: SimConfig,
    sched: Box<dyn Scheduler>,
    pred: Box<dyn Predictor>,
    perfmap: PerfMap,
    st: RunState,
}

impl Replica {
    fn new(spec: ReplicaSpec, opts: &ClusterOpts, sched_kind: SchedKind, pred_kind: PredKind, id: usize, horizon: f64) -> Replica {
        let cfg = spec.sim_config(&opts.base);
        let peak = cfg.gpu.peak_decode_tps(64, 512);
        let sched = make_sched(sched_kind, peak);
        let pred = make_pred(pred_kind, replica_seed(opts.seed, id));
        let perfmap = PerfMap::for_gpu(&cfg.gpu);
        let st = RunState::start_empty(&cfg, horizon);
        Replica { spec, cfg, sched, pred, perfmap, st }
    }

    fn step(&mut self, bound: Option<f64>) -> bool {
        step_once(&self.cfg, self.sched.as_mut(), self.pred.as_mut(), &mut self.perfmap, &mut self.st, bound)
    }

    fn runnable(&self) -> bool {
        !self.st.is_done()
            && (self.st.running_len() > 0 || !self.sched.is_empty() || self.st.has_pending_arrival())
    }

    fn view(&self, id: usize, outstanding: f64) -> ReplicaView {
        ReplicaView {
            id,
            clock: self.st.time(),
            queued: self.sched.queue_len(),
            running: self.st.running_len(),
            outstanding_weighted: outstanding,
            kv_free_tokens: self.st.kv_free_tokens(),
            kv_total_tokens: self.st.kv_total_tokens(),
            peak_weighted_tps: self.spec.peak_weighted_tps(),
            max_batch: self.cfg.host.max_batch,
        }
    }
}

/// A deterministic multi-replica serving cluster.
pub struct Cluster {
    fleet_name: String,
    replicas: Vec<Replica>,
    router: Box<dyn Router>,
    /// Router-plane estimator: predicts on a CLONE of each request so the
    /// replica's own predictor still sees the request fresh at arrival
    /// (keeping replica streams identical to the single-engine path).
    router_pred: Box<dyn Predictor>,
    router_perfmap: PerfMap,
    plane: GlobalPlane,
    /// Router-estimated weighted tokens routed to each replica.
    injected_est: Vec<f64>,
    routed: Vec<u64>,
}

impl Cluster {
    pub fn new(
        fleet: Fleet,
        router: Box<dyn Router>,
        sched_kind: SchedKind,
        pred_kind: PredKind,
        opts: &ClusterOpts,
        horizon: f64,
    ) -> Cluster {
        assert!(!fleet.is_empty(), "a cluster needs at least one replica");
        let n = fleet.len();
        let replicas: Vec<Replica> = fleet
            .replicas
            .iter()
            .enumerate()
            .map(|(i, spec)| Replica::new(spec.clone(), opts, sched_kind, pred_kind, i, horizon))
            .collect();
        Cluster {
            fleet_name: fleet.name,
            replicas,
            router,
            // The router plane always estimates with MoPE — routing is
            // infrastructure and must not read oracle truth even when the
            // replicas' schedulers run oracle ablations.
            router_pred: make_pred(PredKind::Mope, opts.seed ^ 0xC1B5_7E57_0A11_F0E5),
            router_perfmap: PerfMap::default_a100_7b(),
            plane: GlobalPlane::new(n, opts.sync_period, HfParams::default()),
            injected_est: vec![0.0; n],
            routed: vec![0; n],
        }
    }

    /// Minimum clock over runnable replicas — the cluster time that
    /// drives sync boundaries. `None` when nothing is runnable.
    fn cluster_time(&self) -> Option<f64> {
        self.replicas
            .iter()
            .filter(|r| r.runnable())
            .map(|r| r.st.time())
            .min_by(f64::total_cmp)
    }

    fn maybe_sync(&mut self) {
        if let Some(t) = self.cluster_time() {
            if self.plane.due(t) {
                for (i, rep) in self.replicas.iter().enumerate() {
                    self.plane.pull_replica(i, rep.sched.as_ref());
                }
                self.plane.finish_sync(t);
            }
        }
    }

    /// Advance runnable replicas (lagging-first, id tie-break) until all
    /// have reached `gate` or nothing is runnable. `None` = run to
    /// completion.
    fn advance(&mut self, gate: Option<f64>) {
        loop {
            let mut pick: Option<usize> = None;
            for (i, rep) in self.replicas.iter().enumerate() {
                if !rep.runnable() {
                    continue;
                }
                if let Some(g) = gate {
                    if rep.st.time() >= g {
                        continue;
                    }
                }
                let better = match pick {
                    None => true,
                    // Strict < keeps the lowest id on ties (stable
                    // replica-id tie-break).
                    Some(p) => rep.st.time() < self.replicas[p].st.time(),
                };
                if better {
                    pick = Some(i);
                }
            }
            let Some(i) = pick else { break };
            self.replicas[i].step(gate);
            self.maybe_sync();
        }
    }

    fn route_and_inject(&mut self, req: Request) {
        // Router-plane estimate on a clone: the injected request reaches
        // the replica unpredicted, exactly like a trace arrival reaches
        // the single engine.
        let mut probe = req.clone();
        let p = predict_request(self.router_pred.as_mut(), &self.router_perfmap, &mut probe);
        let est_out = p.output_tokens;
        let est_weighted = probe.input_tokens as f64 + 4.0 * est_out as f64;
        let views: Vec<ReplicaView> = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, rep)| {
                let outstanding =
                    (self.injected_est[i] - rep.st.delivered_weighted()).max(0.0);
                rep.view(i, outstanding)
            })
            .collect();
        let choice = self.router.route(
            &req,
            est_out,
            est_weighted,
            &ClusterView { replicas: &views, global: &self.plane },
        );
        assert!(choice < self.replicas.len(), "router returned replica {choice} of {}", self.replicas.len());
        self.injected_est[choice] += est_weighted;
        self.routed[choice] += 1;
        self.replicas[choice].st.inject(req);
    }

    /// Run the whole trace through the cluster (consumes the cluster —
    /// replica results move into the `ClusterResult`).
    pub fn run(mut self, trace: &Trace) -> ClusterResult {
        let mut next = 0usize;
        loop {
            let gate = trace.requests.get(next).map(|r| r.arrival);
            self.advance(gate);
            match trace.requests.get(next) {
                None => break,
                Some(r) => {
                    self.route_and_inject(r.clone());
                    next += 1;
                }
            }
        }
        // Final merge so the reported global HF reflects the whole run.
        for (i, rep) in self.replicas.iter().enumerate() {
            self.plane.pull_replica(i, rep.sched.as_ref());
        }
        let end = self.replicas.iter().map(|r| r.st.time()).fold(0.0f64, f64::max);
        self.plane.finish_sync(end);

        let router = self.router.name().to_string();
        let replica_names: Vec<&'static str> =
            self.replicas.iter().map(|r| r.spec.name).collect();
        let replicas: Vec<SimResult> = self
            .replicas
            .into_iter()
            .map(|rep| {
                let name = rep.sched.name();
                rep.st.into_result(name)
            })
            .collect();
        ClusterResult {
            fleet: self.fleet_name,
            router,
            replica_names,
            replicas,
            routed: self.routed,
            syncs: self.plane.syncs,
            sync_period: self.plane.sync_period(),
            global_hf: self.plane.all_hf(),
        }
    }
}

/// Everything a cluster run produces: the per-replica `SimResult`s plus
/// cluster-wide rollups and the bit-exact fingerprint.
#[derive(Debug)]
pub struct ClusterResult {
    pub fleet: String,
    pub router: String,
    pub replica_names: Vec<&'static str>,
    pub replicas: Vec<SimResult>,
    /// Requests routed to each replica.
    pub routed: Vec<u64>,
    /// Completed global-plane sync rounds.
    pub syncs: u64,
    pub sync_period: f64,
    /// Final global HF per client (merged counters).
    pub global_hf: Vec<(ClientId, f64)>,
}

impl ClusterResult {
    pub fn finished(&self) -> usize {
        self.replicas.iter().map(|r| r.finished).sum()
    }

    pub fn total_requests(&self) -> usize {
        self.replicas.iter().map(|r| r.total_requests).sum()
    }

    pub fn preemptions(&self) -> u64 {
        self.replicas.iter().map(|r| r.preemptions).sum()
    }

    /// Cluster wall clock: the latest replica finish time.
    pub fn wall(&self) -> f64 {
        self.replicas.iter().map(|r| r.wall).fold(1e-9, f64::max)
    }

    /// Union of clients served anywhere, ascending.
    pub fn clients(&self) -> Vec<ClientId> {
        let mut set = BTreeSet::new();
        for r in &self.replicas {
            set.extend(r.service.clients());
        }
        set.into_iter().collect()
    }

    /// Global (cross-replica summed) service for one client.
    pub fn service_total(&self, client: ClientId) -> f64 {
        self.replicas.iter().map(|r| r.service.total(client)).sum()
    }

    /// Global service at time `t` — sums the per-replica curves.
    pub fn service_at(&self, client: ClientId, t: f64) -> f64 {
        self.replicas
            .iter()
            .map(|r| r.service.curve(client).map(|cv| cv.at(t)).unwrap_or(0.0))
            .sum()
    }

    pub fn grand_service(&self) -> f64 {
        self.replicas.iter().map(|r| r.service.grand_total()).sum()
    }

    /// Cluster output tokens/s over the cluster wall clock.
    pub fn output_tps(&self) -> f64 {
        let tokens: f64 = self.replicas.iter().map(|r| r.output_tps * r.wall).sum();
        tokens / self.wall()
    }

    pub fn weighted_tps(&self) -> f64 {
        self.grand_service() / self.wall()
    }

    /// Mean per-replica busy-fraction utilization (idle tails included —
    /// a replica that finished early drags the mean down, as it should).
    pub fn mean_gpu_util(&self) -> f64 {
        let busy: f64 = self.replicas.iter().map(|r| r.gpu_util * r.wall).sum();
        busy / (self.replicas.len() as f64 * self.wall())
    }

    /// All replicas' latency samples merged (TTFT/e2e percentiles).
    pub fn merged_latency(&self) -> LatencyStats {
        let mut out = LatencyStats::new();
        for r in &self.replicas {
            out.merge(&r.latency);
        }
        out
    }

    /// Jain's index over per-client global service totals.
    pub fn jain_over_service(&self) -> f64 {
        let xs: Vec<f64> = self.clients().iter().map(|&c| self.service_total(c)).collect();
        crate::metrics::jain_index(&xs)
    }

    /// Union backlog timeline: for every sample time seen by any replica,
    /// the union of backlogged clients across replicas. Sample times are
    /// bit-identical across replicas (every engine samples at the same
    /// k·sample_dt accumulation), so the f64-bits key merges exactly.
    pub fn merged_backlog_timeline(&self) -> Vec<(f64, Vec<ClientId>)> {
        let mut merged: BTreeMap<u64, BTreeSet<ClientId>> = BTreeMap::new();
        for r in &self.replicas {
            for (t, set) in &r.backlog_timeline {
                merged.entry(t.to_bits()).or_default().extend(set.iter().copied());
            }
        }
        merged
            .into_iter()
            .map(|(bits, set)| (f64::from_bits(bits), set.into_iter().collect()))
            .collect()
    }

    /// Maximal intervals during which `client` was backlogged on ANY
    /// replica, merged from the union backlog timeline — the cluster
    /// no-starvation invariant is stated over these.
    pub fn backlogged_intervals(&self, client: ClientId) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut start: Option<f64> = None;
        let mut last = 0.0f64;
        for (t, set) in self.merged_backlog_timeline() {
            if set.contains(&client) {
                if start.is_none() {
                    start = Some(t);
                }
                last = t;
            } else if let Some(s) = start.take() {
                out.push((s, last));
            }
        }
        if let Some(s) = start {
            out.push((s, last));
        }
        out
    }

    /// Every client backlogged in at least one sample window, anywhere.
    pub fn ever_backlogged_clients(&self) -> Vec<ClientId> {
        let mut set = BTreeSet::new();
        for (_, clients) in self.merged_backlog_timeline() {
            set.extend(clients);
        }
        set.into_iter().collect()
    }

    /// Cluster-wide max co-backlogged pairwise service gap — the
    /// cross-replica generalisation of `SimResult::max_co_backlogged_diff`:
    /// service is the global sum, and a client counts as backlogged if it
    /// is backlogged on ANY replica.
    pub fn max_co_backlogged_diff(&self) -> f64 {
        let timeline = self.merged_backlog_timeline();
        let clients = self.clients();
        let mut worst = 0.0f64;
        for (i, &a) in clients.iter().enumerate() {
            for &b in clients.iter().skip(i + 1) {
                let mut window_start: Option<(f64, f64)> = None; // (sa0, sb0)
                for (t, set) in &timeline {
                    let both = set.contains(&a) && set.contains(&b);
                    match (both, window_start) {
                        (true, None) => {
                            window_start = Some((self.service_at(a, *t), self.service_at(b, *t)));
                        }
                        (true, Some((sa0, sb0))) => {
                            let d = ((self.service_at(a, *t) - sa0)
                                - (self.service_at(b, *t) - sb0))
                                .abs();
                            worst = worst.max(d);
                        }
                        (false, Some(_)) => window_start = None,
                        (false, None) => {}
                    }
                }
            }
        }
        worst
    }

    /// Bit-exact run fingerprint: every replica's engine fingerprint in
    /// replica order, plus the routing decision vector and sync count —
    /// two runs of the same (trace, fleet, router, seed) must match
    /// exactly (the deterministic-replay invariant).
    pub fn fingerprint(&self) -> Vec<u64> {
        let mut v = Vec::new();
        for r in &self.replicas {
            v.extend(crate::harness::fingerprint(r));
            v.push(u64::MAX); // replica separator
        }
        v.extend(self.routed.iter().copied());
        v.push(self.syncs);
        for (c, hf) in &self.global_hf {
            v.push(c.0 as u64);
            v.push(hf.to_bits());
        }
        v
    }

    /// FNV-1a digest of the fingerprint — one u64 per cluster run.
    pub fn digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for word in self.fingerprint() {
            for byte in word.to_le_bytes() {
                h = (h ^ byte as u64).wrapping_mul(0x1000_0000_01b3);
            }
        }
        h
    }
}

/// Convenience one-call runner for CLI / tests / benches.
pub fn run_cluster(
    fleet: Fleet,
    router: Box<dyn Router>,
    sched_kind: SchedKind,
    pred_kind: PredKind,
    trace: &Trace,
    opts: &ClusterOpts,
) -> ClusterResult {
    Cluster::new(fleet, router, sched_kind, pred_kind, opts, trace.horizon).run(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::RouterKind;
    use crate::workload::{generate, Scenario};

    fn quick_trace() -> Trace {
        generate(&Scenario::balanced_load(10.0), 42)
    }

    fn run(fleet: Fleet, kind: RouterKind) -> ClusterResult {
        let trace = quick_trace();
        run_cluster(
            fleet,
            kind.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &ClusterOpts::new(42),
        )
    }

    #[test]
    fn cluster_completes_all_requests_on_every_fleet() {
        for fleet in [Fleet::solo(), Fleet::homogeneous(4), Fleet::hetero()] {
            let res = run(fleet, RouterKind::FairShare);
            assert_eq!(res.finished(), res.total_requests(), "{}", res.fleet);
            assert_eq!(res.total_requests(), quick_trace().len(), "{}", res.fleet);
            assert!(res.wall() > 0.0);
        }
    }

    #[test]
    fn round_robin_spreads_request_counts_evenly() {
        let res = run(Fleet::homogeneous(4), RouterKind::RoundRobin);
        let total: u64 = res.routed.iter().sum();
        for &n in &res.routed {
            assert!(n >= total / 4 - 1 && n <= total / 4 + 1, "routed={:?}", res.routed);
        }
    }

    #[test]
    fn global_service_conservation_holds() {
        let trace = quick_trace();
        let res = run_cluster(
            Fleet::hetero(),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &ClusterOpts::new(42),
        );
        let mut demand: BTreeMap<ClientId, f64> = BTreeMap::new();
        for r in &trace.requests {
            *demand.entry(r.client).or_insert(0.0) += r.weighted_tokens();
        }
        for (&c, &d) in &demand {
            let s = res.service_total(c);
            assert!(
                (s - d).abs() / d < 1e-6,
                "conservation: service[{c}]={s} demand={d}"
            );
        }
        let total: f64 = demand.values().sum();
        assert!((res.grand_service() - total).abs() / total < 1e-6);
    }

    #[test]
    fn deterministic_replay_is_bit_exact() {
        let a = run(Fleet::hetero(), RouterKind::FairShare);
        let b = run(Fleet::hetero(), RouterKind::FairShare);
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn sync_rounds_happen_on_the_period() {
        let res = run(Fleet::homogeneous(2), RouterKind::PredictedCost);
        // 10 s trace (plus drain) with a 1 s period: several mid-run
        // syncs plus the final merge.
        assert!(res.syncs >= 5, "syncs={}", res.syncs);
        assert!(!res.global_hf.is_empty());
    }
}
