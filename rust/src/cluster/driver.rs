//! The deterministic cluster driver: N independent engines composed with
//! online routing and a periodically-synced global counter plane, in two
//! execution modes that produce bit-identical results.
//!
//! # Barriers and safe horizons
//!
//! Between consecutive *barriers* every replica's evolution is
//! independent: nothing outside a replica (router, plane, other
//! replicas) reads or writes its state. The barriers are
//!
//! 1. **routing gates** — the next unrouted arrival's routing decision
//!    (the router snapshot must see every runnable replica at its first
//!    clock ≥ the arrival time);
//! 2. **global-plane sync boundaries** — the counter pull that fires when
//!    the cluster time (minimum runnable replica clock) crosses
//!    `next_sync`;
//! 3. **fault transitions** — every edge of the run's [`FaultPlan`]
//!    (crash, recovery, brownout, KV squeeze) materializes on the driver
//!    thread when the cluster time crosses it, exactly like a sync;
//! 4. **scale transitions** — scheduled scale events and reactive
//!    autoscale evaluations ([`AutoscalePolicy`]) materialize on the
//!    driver thread when the cluster time crosses them (fixed check
//!    order at every barrier: faults → scale → sync), so the fleet
//!    itself can grow and drain mid-run without breaking the zero-drift
//!    contract;
//! 5. **end of run** — the final merge.
//!
//! [`DriveMode::Serial`] is the reference lock-step interleaving: always
//! step the *lagging* runnable replica (minimum engine clock, stable
//! replica-id tie-break, now indexed by a clock heap instead of an O(N)
//! scan), check the sync boundary after every step, never step a replica
//! past the current gate. [`DriveMode::Parallel`] exploits the
//! independence directly: each round computes the shared safe horizon
//! (`min(gate, next_sync)`), advances every runnable replica to its first
//! clock ≥ horizon on a `std::thread::scope` worker pool (replicas
//! partitioned by index), then handles the barrier on the driver thread
//! in replica-id order.
//!
//! # Why the modes are bit-exact
//!
//! Lagging-first stepping never steps a replica at or past a boundary
//! while any runnable replica is still below it — so when a sync fires in
//! serial mode, every runnable replica sits at its *first* clock ≥ the
//! boundary, which is exactly the state the parallel mode constructs by
//! advancing each replica to the horizon independently. The per-step
//! external-arrival bound passed to the engine is the routing gate in
//! both modes (a horizon only decides where stepping PAUSES, never how
//! far one step reaches), so each replica executes the identical step
//! sequence; barrier work (sync pulls, routing, reductions) runs on the
//! driver thread in replica-id order in both modes. `tests/parallel_driver.rs`
//! pins `fingerprint()`/`digest()` equality across scenarios × routers ×
//! fleets × thread counts — the same zero-drift contract the macro≡micro
//! and 1-replica≡engine differentials use.
//!
//! # Counter staleness
//!
//! The global plane pulls per-replica counter snapshots when the cluster
//! time crosses a sync boundary. Replicas ahead of the boundary
//! contribute slightly newer state, lagging ones older — bounded by
//! `sync_period` plus one iteration either way. The conformance cells
//! measure cross-replica discrepancy *under* that staleness, which is the
//! experiment the paper's bounded-discrepancy claim needs (`exp
//! sync-sweep` sweeps the period).

use super::autoscale::{AutoscalePolicy, ScaleAction, ScaleState};
use super::faults::{AdmissionPolicy, FaultPlan, FaultTimeline, MigrationPolicy};
use super::fleet::{Fleet, ReplicaSpec};
use super::global::GlobalPlane;
use super::router::{ClusterView, ReplicaView, Router};
use crate::core::{ClientId, Request};
use crate::exp::{make_pred, make_sched, PredKind, SchedKind};
use crate::metrics::LatencyStats;
use crate::obs::{
    EventKind, NullRecorder, Recorder, RunMeta, TraceCfg, TraceLog, TraceRecorder, DRIVER_TRACK,
};
use crate::predictor::{predict_request, DegradedPredictor, PerfMap, PredFaultPlan, Predictor};
use crate::sched::{GuardHealth, HfParams, Scheduler};
use crate::sim::{step_once, RunState, SimConfig, SimResult};
use crate::workload::Trace;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

/// How the driver executes per-replica advances between barriers. Both
/// modes are bit-exact (identical `fingerprint()`/`digest()`); the choice
/// trades the serial mode's reference simplicity for multi-core
/// wall-clock scaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriveMode {
    /// The reference lock-step interleaving: one replica steps per driver
    /// iteration (lagging-first, replica-id tie-break), sync checked
    /// after every step. Retained as the executable specification the
    /// parallel mode is differentially tested against.
    Serial,
    /// Barrier-bounded horizon batching on a scoped worker pool.
    /// `threads == 0` means auto: one worker per available core, capped
    /// by the fleet size.
    Parallel { threads: usize },
}

impl DriveMode {
    pub fn label(&self) -> String {
        match self {
            DriveMode::Serial => "serial".into(),
            DriveMode::Parallel { threads } => format!("parallel{threads}"),
        }
    }

    /// CLI lookup; `threads` applies to the parallel mode (0 = auto).
    pub fn by_name(name: &str, threads: usize) -> Option<DriveMode> {
        match name {
            "serial" => Some(DriveMode::Serial),
            "parallel" | "par" => Some(DriveMode::Parallel { threads }),
            _ => None,
        }
    }
}

/// Cluster-level options beyond the fleet itself.
#[derive(Debug, Clone)]
pub struct ClusterOpts {
    /// Engine base config (sample period, step mode, drain, max
    /// iterations); per-replica GPU/host come from the `ReplicaSpec`s.
    pub base: SimConfig,
    /// Global counter plane sync period in seconds (≤ 0 disables
    /// periodic sync; the plane still merges once at the end).
    pub sync_period: f64,
    /// Base seed: replica r's predictor derives its stream from
    /// `seed + r·φ` (replica 0 keeps the base seed, so a solo cluster
    /// reproduces the plain engine's stream exactly).
    pub seed: u64,
    /// Serial reference vs parallel horizon-batched execution.
    pub drive: DriveMode,
    /// Deterministic fault schedule, materialized at barriers only
    /// (empty = faultless run).
    pub faults: FaultPlan,
    /// Deterministic prediction-degradation plan, wrapped around every
    /// replica's predictor at construction (empty = clean predictions).
    /// Pure data keyed per `(seed, request)`, so degraded runs stay
    /// bit-identical across drive modes — see [`PredFaultPlan`].
    pub pred_faults: PredFaultPlan,
    /// Gate-level load shedding (unlimited = never shed).
    pub admission: AdmissionPolicy,
    /// What happens to a downed replica's queued/in-flight requests.
    pub migration: MigrationPolicy,
    /// Deterministic fleet scaling, materialized at barriers only
    /// (`Off` = static fleet, zero new barriers).
    pub autoscale: AutoscalePolicy,
    /// Flight-recorder configuration (`None` = tracing off: replicas keep
    /// the zero-cost `NullRecorder` and the run produces no `TraceLog`).
    pub trace: Option<TraceCfg>,
}

impl ClusterOpts {
    pub fn new(seed: u64) -> ClusterOpts {
        ClusterOpts {
            base: SimConfig::a100_7b_vllm(),
            sync_period: 1.0,
            seed,
            drive: DriveMode::Serial,
            faults: FaultPlan::none(),
            pred_faults: PredFaultPlan::none(),
            admission: AdmissionPolicy::unlimited(),
            migration: MigrationPolicy::Migrate,
            autoscale: AutoscalePolicy::Off,
            trace: None,
        }
    }

    pub fn with_drive(mut self, drive: DriveMode) -> ClusterOpts {
        self.drive = drive;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> ClusterOpts {
        self.faults = faults;
        self
    }

    pub fn with_pred_faults(mut self, plan: PredFaultPlan) -> ClusterOpts {
        self.pred_faults = plan;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> ClusterOpts {
        self.admission = admission;
        self
    }

    pub fn with_migration(mut self, migration: MigrationPolicy) -> ClusterOpts {
        self.migration = migration;
        self
    }

    pub fn with_autoscale(mut self, autoscale: AutoscalePolicy) -> ClusterOpts {
        self.autoscale = autoscale;
        self
    }

    pub fn with_trace(mut self, trace: TraceCfg) -> ClusterOpts {
        self.trace = Some(trace);
        self
    }

    /// Typed validation of everything the driver would otherwise only
    /// catch by panicking mid-run. `sync_period == 0` is legal (periodic
    /// sync disabled, final merge only); NaN/negative/infinite are not.
    pub fn validate(&self, fleet: &Fleet) -> anyhow::Result<()> {
        anyhow::ensure!(!fleet.is_empty(), "fleet '{}' has no replicas", fleet.name);
        anyhow::ensure!(
            self.sync_period.is_finite() && self.sync_period >= 0.0,
            "sync period must be finite and >= 0 (got {})",
            self.sync_period
        );
        self.faults.validate(fleet.len())?;
        self.pred_faults.validate(crate::predictor::mope::MopeConfig::default().n_experts)?;
        self.admission.validate()?;
        self.autoscale.validate()?;
        Ok(())
    }
}

fn replica_seed(base: u64, replica: usize) -> u64 {
    base.wrapping_add((replica as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// One replica: an owned scheduler/predictor/perfmap plus the resumable
/// engine state. The engine itself is the *unmodified* single-GPU engine
/// — the cluster composes it, it does not fork it. Everything inside is
/// plain owned data (`Scheduler`/`Predictor` are `Send`), so disjoint
/// replica slices can advance on worker threads.
struct Replica {
    spec: ReplicaSpec,
    cfg: SimConfig,
    sched: Box<dyn Scheduler>,
    pred: Box<dyn Predictor>,
    perfmap: PerfMap,
    st: RunState,
    /// Fault-plane health, written only at barriers (driver thread).
    alive: bool,
    /// Drained out of the fleet by a scale-in. A retired replica is
    /// permanently dead: fault up-edges must not revive it.
    retired: bool,
    /// Active slowdown divisor (1.0 = full speed).
    slowdown: f64,
    /// Pristine GPU model captured at construction — slowdown derates are
    /// always recomputed from this, never compounded onto a derated copy.
    base_gpu: crate::sim::GpuModel,
}

impl Replica {
    fn new(spec: ReplicaSpec, opts: &ClusterOpts, sched_kind: SchedKind, pred_kind: PredKind, id: usize, horizon: f64) -> Replica {
        let cfg = spec.sim_config(&opts.base);
        let peak = cfg.gpu.peak_decode_tps(64, 512);
        let sched = make_sched(sched_kind, peak);
        let mut pred = make_pred(pred_kind, replica_seed(opts.seed, id));
        if !opts.pred_faults.is_empty() {
            // Degradation is keyed per (plan seed, request, segment), so
            // every replica shares the plan without stream coupling.
            pred = Box::new(DegradedPredictor::new(pred, opts.pred_faults.clone()));
        }
        let perfmap = PerfMap::for_gpu(&cfg.gpu);
        let mut st = RunState::start_empty(&cfg, horizon);
        if let Some(tc) = opts.trace {
            // One trace track per replica; ids are monotone for the whole
            // run (scale-out appends), so the (t, replica, seq) merge key
            // stays stable across membership changes.
            st.set_recorder(Box::new(TraceRecorder::new(id as u32, tc.capacity)));
        }
        let base_gpu = cfg.gpu;
        Replica { spec, cfg, sched, pred, perfmap, st, alive: true, retired: false, slowdown: 1.0, base_gpu }
    }

    /// Apply a slowdown divisor: compute AND memory bandwidth are divided
    /// by `factor` (HBM capacity untouched — KV pool size is stable).
    /// The replica's own MoPE predictor keeps its calibration-time
    /// perfmap: a transiently throttled GPU does not re-announce its
    /// speed, so estimates go stale exactly as they would in production.
    /// Applied only at barriers, so both drive modes see the change at
    /// the identical engine clock.
    fn set_slowdown(&mut self, factor: f64) {
        if factor == self.slowdown {
            return;
        }
        self.slowdown = factor;
        let mut gpu = self.base_gpu;
        gpu.gpu.peak_flops /= factor;
        gpu.gpu.mem_bw /= factor;
        self.cfg.gpu = gpu;
    }

    /// Extract every queued and in-flight request for migration: preempt
    /// the running batch back into the scheduler (service already
    /// delivered stays credited; the rework watermark marks re-decoded
    /// tokens so they are never double-counted), then drain the scheduler
    /// charge-free and convert queued + untouched pending arrivals into
    /// orphans.
    fn extract_orphans(&mut self) -> Vec<crate::sim::engine::Orphan> {
        self.st.preempt_all_into(self.sched.as_mut());
        let queued = self.sched.drain_queued();
        self.st.take_orphans(queued)
    }

    fn step(&mut self, bound: Option<f64>) -> bool {
        step_once(&self.cfg, self.sched.as_mut(), self.pred.as_mut(), &mut self.perfmap, &mut self.st, bound)
    }

    /// Advance to the first engine clock ≥ `horizon` (or quiescence).
    /// `bound` is the same external-arrival bound the serial driver
    /// passes per step — the horizon changes the stopping point, never
    /// the step sequence (first-crossing semantics). Gating every step on
    /// `runnable()` makes this the per-replica projection of the serial
    /// loop BY CONSTRUCTION: a replica is stepped exactly when serial
    /// would step it, so a quiescent replica can never be probed into an
    /// external-arrival idle jump serial would not take. (The engine-level
    /// `sim::advance_until` is the same loop gated on the engine's own
    /// quiescence return — equivalent here, but the explicit gate keeps
    /// the equivalence local and auditable.)
    fn advance_until_horizon(&mut self, horizon: f64, bound: Option<f64>) {
        while self.runnable() && self.st.time() < horizon {
            if !self.step(bound) {
                break;
            }
        }
    }

    fn runnable(&self) -> bool {
        self.alive
            && !self.st.is_done()
            && (self.st.running_len() > 0 || !self.sched.is_empty() || self.st.has_pending_arrival())
    }

    fn view(&self, id: usize, outstanding: f64) -> ReplicaView {
        ReplicaView {
            id,
            clock: self.st.time(),
            queued: self.sched.queue_len(),
            running: self.st.running_len(),
            outstanding_weighted: outstanding,
            kv_free_tokens: self.st.kv_free_tokens(),
            kv_total_tokens: self.st.kv_total_tokens(),
            peak_weighted_tps: self.spec.peak_weighted_tps() / self.slowdown,
            max_batch: self.cfg.host.max_batch,
            alive: self.alive,
            slowdown: self.slowdown,
        }
    }
}

/// Serial clock-heap key: `(clock bits, replica id)`. Engine clocks are
/// non-negative, where IEEE-754 bit patterns order exactly as
/// `f64::total_cmp` — so the derived tuple `Ord` under [`Reverse`] pops
/// the lagging replica with the lowest id on clock ties, the identical
/// pick the seed's O(N) scan made, in O(log N).
type ClockKey = (u64, usize);

/// A deterministic multi-replica serving cluster.
pub struct Cluster {
    fleet_name: String,
    replicas: Vec<Replica>,
    router: Box<dyn Router>,
    /// Router-plane estimator: predicts on a CLONE of each request so the
    /// replica's own predictor still sees the request fresh at arrival
    /// (keeping replica streams identical to the single-engine path).
    router_pred: Box<dyn Predictor>,
    router_perfmap: PerfMap,
    plane: GlobalPlane,
    /// Router-estimated weighted tokens routed to each replica.
    injected_est: Vec<f64>,
    routed: Vec<u64>,
    drive: DriveMode,
    /// Lagging-replica index for the serial mode, rebuilt per advance.
    clock_heap: BinaryHeap<Reverse<ClockKey>>,
    /// Reused routing-snapshot buffer — no per-decision Vec.
    view_scratch: Vec<ReplicaView>,
    /// Compiled fault schedule (empty plan = never due).
    faults: FaultTimeline,
    migration: MigrationPolicy,
    admission: AdmissionPolicy,
    /// Requests migrated ONTO each replica after a crash.
    migrated: Vec<u64>,
    /// Per-client shed accounting: (count, weighted tokens).
    shed: BTreeMap<ClientId, (u64, f64)>,
    /// Fault-materialization barriers fired (mode-invariant).
    fault_transitions: u64,
    /// Everything needed to instantiate a scale-out replica mid-run
    /// exactly as `Cluster::new` would have (same base config, same
    /// per-id seed derivation).
    opts: ClusterOpts,
    sched_kind: SchedKind,
    pred_kind: PredKind,
    horizon: f64,
    /// Compiled autoscale policy (Off = never due).
    scale: ScaleState,
    /// Applied scale actions (mode-invariant).
    scale_transitions: u64,
    /// Fleet composition history: `(cluster time, member specs)` at 0
    /// and after every membership change.
    fleet_epochs: Vec<(f64, Vec<ReplicaSpec>)>,
    /// Per-replica accumulated alive time; `alive_since` is the open
    /// window's start for currently-alive replicas.
    alive_secs: Vec<f64>,
    alive_since: Vec<f64>,
    /// Driver-thread track of the flight recorder: routing, shedding,
    /// migration, and every barrier event. `NullRecorder` when tracing
    /// is off.
    driver_rec: Box<dyn Recorder>,
    /// Accumulates the per-barrier merged event chunks (None = off).
    trace_log: Option<TraceLog>,
}

impl Cluster {
    pub fn new(
        fleet: Fleet,
        router: Box<dyn Router>,
        sched_kind: SchedKind,
        pred_kind: PredKind,
        opts: &ClusterOpts,
        horizon: f64,
    ) -> Cluster {
        opts.validate(&fleet).expect("invalid cluster options");
        let n = fleet.len();
        let replicas: Vec<Replica> = fleet
            .replicas
            .iter()
            .enumerate()
            .map(|(i, spec)| Replica::new(spec.clone(), opts, sched_kind, pred_kind, i, horizon))
            .collect();
        // Resolve auto thread count once so the whole run uses one value.
        // (The count affects wall-clock only — results are bit-exact at
        // any value — but resolving early keeps logs/labels meaningful.)
        let drive = match opts.drive {
            DriveMode::Parallel { threads: 0 } => DriveMode::Parallel {
                threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(n),
            },
            d => d,
        };
        let initial_epoch = vec![(0.0, fleet.replicas.clone())];
        let driver_rec: Box<dyn Recorder> = match opts.trace {
            Some(tc) => Box::new(TraceRecorder::new(DRIVER_TRACK, tc.capacity)),
            None => Box::new(NullRecorder),
        };
        let trace_log = opts.trace.map(|_| {
            let mut meta = RunMeta::new(opts.seed, "");
            meta.drive = match drive {
                DriveMode::Serial => "serial".into(),
                DriveMode::Parallel { .. } => "parallel".into(),
            };
            meta.threads = match drive {
                DriveMode::Serial => 1,
                DriveMode::Parallel { threads } => threads,
            };
            meta.sync_period = opts.sync_period;
            meta.scheduler = sched_kind.label();
            meta.router = router.name().to_string();
            meta.fleet = fleet.name.clone();
            TraceLog::new(meta)
        });
        Cluster {
            fleet_name: fleet.name,
            replicas,
            router,
            // The router plane always estimates with MoPE — routing is
            // infrastructure and must not read oracle truth even when the
            // replicas' schedulers run oracle ablations.
            router_pred: make_pred(PredKind::Mope, opts.seed ^ 0xC1B5_7E57_0A11_F0E5),
            router_perfmap: PerfMap::default_a100_7b(),
            plane: GlobalPlane::new(n, opts.sync_period, HfParams::default()),
            injected_est: vec![0.0; n],
            routed: vec![0; n],
            drive,
            clock_heap: BinaryHeap::new(),
            view_scratch: Vec::with_capacity(n),
            faults: opts.faults.timeline(n),
            migration: opts.migration,
            admission: opts.admission,
            migrated: vec![0; n],
            shed: BTreeMap::new(),
            fault_transitions: 0,
            opts: opts.clone(),
            sched_kind,
            pred_kind,
            horizon,
            scale: opts.autoscale.state(),
            scale_transitions: 0,
            fleet_epochs: initial_epoch,
            alive_secs: vec![0.0; n],
            alive_since: vec![0.0; n],
            driver_rec,
            trace_log,
        }
    }

    /// Drain every track's ring (replica-id order, driver last) into the
    /// trace log as one barrier chunk. Runs on the driver thread at the
    /// identical cluster times in both drive modes, so chunk boundaries —
    /// and therefore ring-overflow behaviour — are mode-invariant.
    fn drain_trace(&mut self) {
        let Some(log) = self.trace_log.as_mut() else { return };
        let mut chunk = Vec::new();
        let mut dropped = 0u64;
        for rep in self.replicas.iter_mut() {
            rep.st.recorder_mut().drain_into(&mut chunk);
            dropped += rep.st.recorder_dropped();
        }
        self.driver_rec.drain_into(&mut chunk);
        dropped += self.driver_rec.dropped();
        log.absorb(chunk, dropped);
    }

    /// Minimum clock over runnable replicas — the cluster time that
    /// drives sync boundaries. `INFINITY` when nothing is runnable.
    fn min_runnable_clock(&self) -> f64 {
        self.replicas
            .iter()
            .filter(|r| r.runnable())
            .map(|r| r.st.time())
            .fold(f64::INFINITY, f64::min)
    }

    /// Pull every replica's counters (replica-id order — the reduction
    /// order is part of the determinism contract) and complete the round.
    fn sync_all(&mut self, cluster_time: f64) {
        let plane = &mut self.plane;
        for (i, rep) in self.replicas.iter().enumerate() {
            plane.pull_replica(i, rep.sched.as_ref());
        }
        plane.finish_sync(cluster_time);
        self.driver_rec.record(cluster_time, EventKind::Sync { syncs: self.plane.syncs });
        // Every sync is a barrier: merge the per-track rings here so the
        // trace is identical under both drive modes chunk for chunk.
        self.drain_trace();
    }

    /// Materialize every fault transition crossed by cluster time `t`:
    /// apply the new per-replica health (slowdown derate, KV
    /// reservation, down/up edges), extract and re-place orphans per the
    /// run's [`MigrationPolicy`], then complete a plane sync so routing
    /// resumes on merged post-fault state. Runs on the driver thread at
    /// a barrier in BOTH drive modes — at the identical cluster time, in
    /// replica-id order — so the zero-drift contract survives every
    /// plan. Returns whether anything was applied.
    fn materialize_faults(&mut self, t: f64) -> bool {
        if !self.faults.due(t) {
            return false;
        }
        let affected = self.faults.advance(t);
        let mut orphans = Vec::new();
        for &r in &affected {
            let h = self.faults.state(r);
            {
                // Health bitmask: down | throttled | KV-squeezed.
                let code = (h.down as u32)
                    | (((h.slowdown != 1.0) as u32) << 1)
                    | (((h.reserved_pages > 0) as u32) << 2);
                self.driver_rec.record(t, EventKind::Fault { code, replica: r as u32 });
            }
            {
                let rep = &mut self.replicas[r];
                rep.set_slowdown(h.slowdown);
                rep.st.kv_set_reserved_pages(h.reserved_pages);
            }
            if h.down && self.replicas[r].alive {
                self.replicas[r].alive = false;
                self.plane.set_alive(r, false);
                self.alive_secs[r] += t - self.alive_since[r];
                if self.migration != MigrationPolicy::Wait {
                    let extracted = self.replicas[r].extract_orphans();
                    // The dead replica's outstanding estimate collapses to
                    // zero — its unfinished work left with the orphans
                    // (or, under Drop, left entirely).
                    self.injected_est[r] = self.replicas[r].st.delivered_weighted();
                    if self.migration == MigrationPolicy::Migrate {
                        orphans.extend(extracted);
                    }
                    // Drop: the negative control discards `extracted`.
                }
            } else if !h.down && !self.replicas[r].alive && !self.replicas[r].retired {
                // A retired replica is out of the fleet for good — a
                // fault interval ending after its scale-in must not
                // revive it.
                self.replicas[r].alive = true;
                self.plane.set_alive(r, true);
                self.alive_since[r] = t;
                // The replica rejoins at the cluster time of this barrier
                // — it does not replay the outage as idle catch-up.
                self.replicas[r].st.fast_forward(t);
            }
        }
        for o in orphans {
            self.migrate_orphan(o, t);
        }
        self.sync_all(t);
        self.fault_transitions += 1;
        true
    }

    /// Re-place one orphan on a survivor through the router — the same
    /// probe/snapshot path as an arrival (the routers skip dead
    /// replicas). Admission is NOT re-checked: the request was already
    /// admitted once; migration must not become a shedding side door.
    fn migrate_orphan(&mut self, o: crate::sim::engine::Orphan, now: f64) {
        let mut probe = o.req.clone();
        let p = predict_request(self.router_pred.as_mut(), &self.router_perfmap, &mut probe);
        let est_out = p.output_tokens;
        let est_weighted = probe.input_tokens as f64 + 4.0 * est_out as f64;
        self.view_scratch.clear();
        for (i, rep) in self.replicas.iter().enumerate() {
            let outstanding = (self.injected_est[i] - rep.st.delivered_weighted()).max(0.0);
            self.view_scratch.push(rep.view(i, outstanding));
        }
        let choice = self.router.route(
            &o.req,
            est_out,
            est_weighted,
            &ClusterView { replicas: &self.view_scratch, global: &self.plane },
        );
        assert!(choice < self.replicas.len(), "router returned replica {choice} of {}", self.replicas.len());
        debug_assert!(self.replicas[choice].alive, "orphan migrated onto a dead replica");
        self.injected_est[choice] += est_weighted;
        self.migrated[choice] += 1;
        self.driver_rec.record(
            now,
            EventKind::Migrate { client: o.req.client, req: o.req.id, to: choice as u32 },
        );
        self.replicas[choice].st.inject_migrated(o.req, o.rework, now);
    }

    /// The reactive controller's signal: predicted seconds to drain the
    /// fleet's outstanding routed-but-undelivered weighted tokens at the
    /// alive replicas' aggregate (slowdown-derated) peak weighted
    /// throughput. Pure driver-thread arithmetic over barrier-stable
    /// state — both drive modes compute it at identical cluster times
    /// from identical replica states.
    fn drain_seconds(&self) -> f64 {
        let mut backlog = 0.0;
        let mut capacity = 0.0;
        for (i, rep) in self.replicas.iter().enumerate() {
            if rep.alive {
                backlog += (self.injected_est[i] - rep.st.delivered_weighted()).max(0.0);
                capacity += rep.spec.peak_weighted_tps() / rep.slowdown;
            }
        }
        backlog / capacity.max(1e-9)
    }

    fn alive_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.alive).count()
    }

    /// Append the current fleet composition (non-retired member specs,
    /// replica-id order) to the epoch history.
    fn record_epoch(&mut self, t: f64) {
        let specs: Vec<ReplicaSpec> =
            self.replicas.iter().filter(|r| !r.retired).map(|r| r.spec.clone()).collect();
        self.fleet_epochs.push((t, specs));
    }

    /// Materialize every scale boundary crossed by cluster time `t`:
    /// scheduled events in order, then (if due) one reactive evaluation.
    /// Runs on the driver thread at a barrier in BOTH drive modes — at
    /// the identical cluster time, from identical replica state — the
    /// same argument that keeps fault transitions and plane syncs
    /// zero-drift (the fixed check order everywhere is faults → scale →
    /// sync). A materialization that changes fleet membership records a
    /// new epoch and completes a plane sync so routing resumes on merged
    /// post-scale state. Returns whether the boundary fired (callers
    /// restart their advance loop — growth invalidates the serial clock
    /// heap, a drain moves orphans).
    fn materialize_scale(&mut self, t: f64) -> bool {
        if !self.scale.due(t) {
            return false;
        }
        let mut changed = false;
        while let Some(ev) = self.scale.pop_scheduled(t) {
            changed |= self.apply_scale_action(ev.action, t);
        }
        if self.scale.eval_due(t) {
            let decision = self.scale.decide(self.drain_seconds(), self.alive_count(), t);
            if let Some(action) = decision {
                if self.apply_scale_action(action, t) {
                    self.scale.note_action(t);
                    changed = true;
                }
            }
            self.scale.finish_eval(t);
        }
        if changed {
            self.record_epoch(t);
            self.driver_rec.record(
                t,
                EventKind::ScaleEpoch {
                    epoch: self.fleet_epochs.len() as u32,
                    alive: self.alive_count() as u32,
                },
            );
            self.sync_all(t);
        }
        true
    }

    /// Apply one scale action at cluster time `t`. Returns whether the
    /// fleet actually changed (a Shrink that would leave no alive
    /// replica is a no-op, not an error — the run must stay serviceable).
    fn apply_scale_action(&mut self, action: ScaleAction, t: f64) -> bool {
        match action {
            ScaleAction::Grow(spec) => {
                // New highest replica id — ids are monotone for the whole
                // run, so every existing replica keeps its predictor
                // stream, routing history, and heap identity.
                let id = self.replicas.len();
                let mut rep =
                    Replica::new(spec, &self.opts, self.sched_kind, self.pred_kind, id, self.horizon);
                // Join at the barrier time: the replica's engine clock
                // starts here — it does not replay the pre-join past.
                rep.st.fast_forward(t);
                self.replicas.push(rep);
                self.plane.add_replica();
                self.faults.grow();
                self.injected_est.push(0.0);
                self.routed.push(0);
                self.migrated.push(0);
                self.alive_secs.push(0.0);
                self.alive_since.push(t);
                self.scale_transitions += 1;
                true
            }
            ScaleAction::Shrink => {
                // Drain-and-retire the highest-id alive replica (a
                // deterministic victim pick; last-in-first-out matches
                // how reactive growth stacks capacity).
                if self.alive_count() <= 1 {
                    return false;
                }
                let victim = self
                    .replicas
                    .iter()
                    .rposition(|r| r.alive)
                    .expect("alive_count > 1 guarantees an alive replica");
                self.replicas[victim].alive = false;
                self.replicas[victim].retired = true;
                self.plane.set_alive(victim, false);
                self.alive_secs[victim] += t - self.alive_since[victim];
                // Graceful drain, never a kill: queued and in-flight work
                // leaves through the same orphan path a crash uses
                // (service already delivered stays credited; the rework
                // watermark prices re-decode exactly once), then re-places
                // on survivors through the router.
                let extracted = self.replicas[victim].extract_orphans();
                self.injected_est[victim] = self.replicas[victim].st.delivered_weighted();
                for o in extracted {
                    self.migrate_orphan(o, t);
                }
                self.scale_transitions += 1;
                true
            }
        }
    }

    /// Serial reference: step the lagging runnable replica (minimum
    /// clock, replica-id tie-break) until every runnable replica has
    /// reached `gate`, checking the sync boundary after every step — the
    /// seed's lock-step loop with the O(N) min-clock scan replaced by a
    /// clock heap. Heap entries cannot go stale between barriers: only a
    /// replica's own step changes its state, and the stepped replica is
    /// re-keyed on reinsertion. A fault materialization IS cross-replica
    /// surgery (orphans move, replicas die or revive), so the heap is
    /// rebuilt from scratch after every one — the outer loop.
    fn advance_serial(&mut self, gate: Option<f64>) {
        let below_gate = |rep: &Replica| gate.map_or(true, |g| rep.st.time() < g);
        'rebuild: loop {
            self.clock_heap.clear();
            for (i, rep) in self.replicas.iter().enumerate() {
                if rep.runnable() && below_gate(rep) {
                    self.clock_heap.push(Reverse((rep.st.time().to_bits(), i)));
                }
            }
            while let Some(Reverse((_, i))) = self.clock_heap.pop() {
                self.replicas[i].step(gate);
                // Barrier check after every step, as the reference
                // semantics demand. The minimum runnable clock is the heap
                // top or the just-stepped replica — anything parked at
                // ≥ gate is above every heap entry by construction. Only
                // when the heap is empty (the advance is ending) can a
                // parked replica hold the minimum, and that one O(N) scan
                // per advance is fine.
                let tmin = match self.clock_heap.peek() {
                    Some(Reverse((bits, _))) => {
                        let mut t = f64::from_bits(*bits);
                        let rep = &self.replicas[i];
                        if rep.runnable() {
                            t = t.min(rep.st.time());
                        }
                        t
                    }
                    None => self.min_runnable_clock(),
                };
                if tmin.is_finite() {
                    if self.materialize_faults(tmin) {
                        continue 'rebuild;
                    }
                    if self.materialize_scale(tmin) {
                        // Growth adds a heap-unknown replica; a drain
                        // moves orphans across replicas — rebuild.
                        continue 'rebuild;
                    }
                    if self.plane.due(tmin) {
                        self.sync_all(tmin);
                    }
                }
                let rep = &self.replicas[i];
                if rep.runnable() && below_gate(rep) {
                    self.clock_heap.push(Reverse((rep.st.time().to_bits(), i)));
                }
            }
            return;
        }
    }

    /// Lagging runnable replica strictly below `gate` (lowest id on
    /// clock ties) — the serial pick, as a one-off scan.
    fn lagging_below(&self, gate: Option<f64>) -> Option<usize> {
        let mut best: Option<ClockKey> = None;
        for (i, rep) in self.replicas.iter().enumerate() {
            if !rep.runnable() {
                continue;
            }
            if let Some(g) = gate {
                if rep.st.time() >= g {
                    continue;
                }
            }
            let key = (rep.st.time().to_bits(), i);
            if best.map_or(true, |b| key < b) {
                best = Some(key);
            }
        }
        best.map(|(_, i)| i)
    }

    /// Parallel mode: repeat { advance every runnable replica to the
    /// shared safe horizon — the next sync boundary or the routing gate,
    /// whichever is sooner — then handle any due sync on the driver
    /// thread } until the gate is reached (or nothing is runnable).
    fn advance_parallel(&mut self, gate: Option<f64>, threads: usize) {
        loop {
            // Stale-boundary entry state: a boundary (sync or fault) can
            // already be due before any stepping when an idle gap ended
            // with injections waking replicas parked beyond it (nothing
            // was runnable, so the boundary never fired). The serial
            // reference handles boundaries only AFTER a step — so it
            // steps the lagging below-gate replica once and then checks,
            // or, with nothing below the gate, does nothing at all.
            // Replicate that exactly.
            let t0 = self.min_runnable_clock();
            if t0.is_finite() && (self.plane.due(t0) || self.faults.due(t0) || self.scale.due(t0)) {
                let Some(i) = self.lagging_below(gate) else {
                    return; // serial: empty heap → no step, no barrier
                };
                self.replicas[i].step(gate);
                let t = self.min_runnable_clock();
                if t.is_finite()
                    && !self.materialize_faults(t)
                    && !self.materialize_scale(t)
                    && self.plane.due(t)
                {
                    self.sync_all(t);
                }
                continue;
            }
            let horizon_bound = self
                .plane
                .next_sync_at()
                .min(self.faults.next_transition_at())
                .min(self.scale.next_event_at());
            let horizon = match gate {
                Some(g) => g.min(horizon_bound),
                None => horizon_bound,
            };
            self.advance_round(horizon, gate, threads);
            let t = self.min_runnable_clock();
            if t.is_finite() {
                // Every runnable replica sits at its first clock ≥ the
                // boundary — the identical state serial mode handles the
                // barrier in (lagging-first never steps a replica past a
                // boundary while any runnable one is still below it).
                // Faults first, then scale, matching the serial per-step
                // check order; a materialization that changes anything
                // completes its own sync round.
                if self.materialize_faults(t) {
                    continue;
                }
                if self.materialize_scale(t) {
                    continue;
                }
                if self.plane.due(t) {
                    self.sync_all(t);
                    continue; // new boundary, same gate: next round
                }
            }
            return;
        }
    }

    /// One horizon round: every runnable replica strictly below `horizon`
    /// advances to its first clock ≥ `horizon` (or to quiescence).
    /// Replica evolutions are independent between barriers, so execution
    /// order cannot affect results; partitioning is by replica index and
    /// all reductions happen after the join, on the driver thread.
    fn advance_round(&mut self, horizon: f64, gate: Option<f64>, threads: usize) {
        let need = self
            .replicas
            .iter()
            .filter(|r| r.runnable() && r.st.time() < horizon)
            .count();
        if need == 0 {
            return;
        }
        // Never spawn more workers than replicas that actually need to
        // move — rounds fire per routing gate and per sync boundary, so
        // idle spawns are pure overhead. (A persistent channel-fed pool
        // would shave the remaining ~10µs/spawn; scoped threads keep the
        // borrow story trivial and add no dependencies.)
        let workers = threads.clamp(1, need);
        if need == 1 || workers == 1 {
            // Nothing to overlap — skip the spawn cost.
            for rep in self.replicas.iter_mut() {
                rep.advance_until_horizon(horizon, gate);
            }
            return;
        }
        let chunk = self.replicas.len().div_ceil(workers);
        std::thread::scope(|s| {
            for slab in self.replicas.chunks_mut(chunk) {
                s.spawn(move || {
                    for rep in slab {
                        rep.advance_until_horizon(horizon, gate);
                    }
                });
            }
        });
    }

    /// Route one arrival on a deterministic fleet snapshot and inject it
    /// into the chosen replica — or shed it at the gate when the
    /// admission bound is exceeded. Returns the choice (`None` = shed).
    fn route_and_inject(&mut self, req: Request) -> Option<usize> {
        // Router-plane estimate on a clone: the injected request reaches
        // the replica unpredicted, exactly like a trace arrival reaches
        // the single engine. Predicted before the shed decision so the
        // router-plane RNG stream is a pure function of the arrival
        // sequence, shed or not.
        let mut probe = req.clone();
        let p = predict_request(self.router_pred.as_mut(), &self.router_perfmap, &mut probe);
        let est_out = p.output_tokens;
        let est_weighted = probe.input_tokens as f64 + 4.0 * est_out as f64;
        self.view_scratch.clear();
        let mut outstanding_alive = 0.0;
        for (i, rep) in self.replicas.iter().enumerate() {
            let outstanding = (self.injected_est[i] - rep.st.delivered_weighted()).max(0.0);
            if rep.alive {
                outstanding_alive += outstanding;
            }
            self.view_scratch.push(rep.view(i, outstanding));
        }
        // Gate-level shedding: fleet-wide outstanding backlog (alive
        // replicas only — a dead replica's frozen queue is not load the
        // survivors carry) over the bound sheds the arrival, unless the
        // client is globally underserved and protected. Shed work is
        // accounted per client, never silently lost.
        if outstanding_alive > self.admission.max_outstanding_weighted
            && !(self.admission.protect_underserved && self.plane.is_underserved(req.client))
        {
            let e = self.shed.entry(req.client).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += req.weighted_tokens();
            self.driver_rec.record(
                req.arrival,
                EventKind::Shed { client: req.client, req: req.id, weighted: req.weighted_tokens() },
            );
            return None;
        }
        let choice = self.router.route(
            &req,
            est_out,
            est_weighted,
            &ClusterView { replicas: &self.view_scratch, global: &self.plane },
        );
        assert!(choice < self.replicas.len(), "router returned replica {choice} of {}", self.replicas.len());
        self.injected_est[choice] += est_weighted;
        self.routed[choice] += 1;
        self.driver_rec.record(
            req.arrival,
            EventKind::Route { client: req.client, req: req.id, to: choice as u32 },
        );
        self.replicas[choice].st.inject(req);
        Some(choice)
    }

    /// Run the whole trace through the cluster (consumes the cluster —
    /// replica results move into the `ClusterResult`).
    pub fn run(mut self, trace: &Trace) -> ClusterResult {
        let mut next = 0usize;
        loop {
            let gate = trace.requests.get(next).map(|r| r.arrival);
            match self.drive {
                DriveMode::Serial => self.advance_serial(gate),
                DriveMode::Parallel { threads } => self.advance_parallel(gate, threads),
            }
            if next >= trace.requests.len() {
                break;
            }
            // Batched routing: the advance left every runnable replica at
            // or past the gate, so the head arrival routes immediately —
            // and so does every later arrival the fleet's clocks have
            // already overtaken (for those, a fresh advance would be a
            // stepless no-op: skipping it removes overhead, not events).
            // Injection can wake a lagging idle replica, which the
            // running minimum accounts for before the next arrival.
            let mut min_clock = self.min_runnable_clock();
            while let Some(r) = trace.requests.get(next) {
                if r.arrival > min_clock {
                    break;
                }
                // Fault transitions at or before this arrival must be
                // materialized before its routing snapshot — an idle gap
                // can park every replica past a transition the advance
                // never fired (nothing was runnable below the gate).
                // Driver-thread code, identical in both modes.
                if self.materialize_faults(r.arrival) {
                    min_clock = self.min_runnable_clock();
                    if r.arrival > min_clock {
                        break;
                    }
                }
                if self.materialize_scale(r.arrival) {
                    min_clock = self.min_runnable_clock();
                    if r.arrival > min_clock {
                        break;
                    }
                }
                let choice = self.route_and_inject(r.clone());
                next += 1;
                if let Some(c) = choice {
                    min_clock = min_clock.min(self.replicas[c].st.time());
                }
            }
        }
        // Drain outstanding fault transitions: a `Wait`-frozen replica
        // still holds queued work it must finish after recovery, and
        // end-of-interval edges (speed/KV restore, revival) past the
        // last completion still count. Materialize each at its exact
        // transition time, then advance to quiescence.
        // A pending scheduled scale event past the last completion still
        // counts too (the epoch history must record it), same as an
        // end-of-interval fault edge.
        while self.faults.has_pending() || self.scale.has_pending() {
            let t = self.faults.next_transition_at().min(self.scale.next_scheduled_at());
            self.materialize_faults(t);
            self.materialize_scale(t);
            match self.drive {
                DriveMode::Serial => self.advance_serial(None),
                DriveMode::Parallel { threads } => self.advance_parallel(None, threads),
            }
        }
        // Final merge so the reported global HF reflects the whole run.
        let end = self.replicas.iter().map(|r| r.st.time()).fold(0.0f64, f64::max);
        self.sync_all(end);
        // Close the open alive windows: the run ends at `end` for every
        // replica still in service.
        for i in 0..self.replicas.len() {
            if self.replicas[i].alive {
                self.alive_secs[i] += (end - self.alive_since[i]).max(0.0);
            }
        }

        let router = self.router.name().to_string();
        // The final `sync_all(end)` above performed the last drain, so the
        // log already holds every event; `finish()` applies the global
        // (time, replica, seq) total order that makes the digest
        // drive-mode invariant.
        let trace = self.trace_log.take().map(|mut l| {
            l.finish();
            l
        });
        let replica_names: Vec<&'static str> =
            self.replicas.iter().map(|r| r.spec.name).collect();
        // Captured before the schedulers are dropped: receipt exactness
        // (every admission refunded or corrected exactly once, crashes
        // and migrations included) and final guard health are scheduler
        // state the per-replica `SimResult` does not carry.
        let outstanding_receipts: Vec<Option<usize>> =
            self.replicas.iter().map(|r| r.sched.outstanding_receipts()).collect();
        let guard_health: Vec<Option<GuardHealth>> =
            self.replicas.iter().map(|r| r.sched.guard_health()).collect();
        let replicas: Vec<SimResult> = self
            .replicas
            .into_iter()
            .map(|rep| {
                let name = rep.sched.name();
                rep.st.into_result(name)
            })
            .collect();
        ClusterResult {
            fleet: self.fleet_name,
            router,
            replica_names,
            replicas,
            routed: self.routed,
            syncs: self.plane.syncs,
            sync_period: self.plane.sync_period(),
            global_hf: self.plane.all_hf(),
            migrated: self.migrated,
            shed: self.shed.iter().map(|(&c, &(n, w))| (c, n, w)).collect(),
            fault_transitions: self.fault_transitions,
            scale_transitions: self.scale_transitions,
            fleet_epochs: self.fleet_epochs,
            alive_secs: self.alive_secs,
            outstanding_receipts,
            guard_health,
            trace,
        }
    }
}

/// Everything a cluster run produces: the per-replica `SimResult`s plus
/// cluster-wide rollups and the bit-exact fingerprint.
#[derive(Debug)]
pub struct ClusterResult {
    pub fleet: String,
    pub router: String,
    pub replica_names: Vec<&'static str>,
    pub replicas: Vec<SimResult>,
    /// Requests routed to each replica.
    pub routed: Vec<u64>,
    /// Completed global-plane sync rounds.
    pub syncs: u64,
    pub sync_period: f64,
    /// Final global HF per client (merged counters).
    pub global_hf: Vec<(ClientId, f64)>,
    /// Requests migrated ONTO each replica after crashes.
    pub migrated: Vec<u64>,
    /// Per-client shed accounting, ascending by client:
    /// `(client, count, weighted tokens)`.
    pub shed: Vec<(ClientId, u64, f64)>,
    /// Fault-materialization barriers fired (mode-invariant).
    pub fault_transitions: u64,
    /// Scale actions applied (grow + drain; mode-invariant).
    pub scale_transitions: u64,
    /// Fleet composition history: `(cluster time, member specs in
    /// replica-id order)` at t = 0 and after every membership change —
    /// the epoch record the alive-time-weighted metrics are stated
    /// against.
    pub fleet_epochs: Vec<(f64, Vec<ReplicaSpec>)>,
    /// Per-replica seconds spent alive and in the fleet (fault
    /// down-time and post-retirement time excluded; a late-joining
    /// replica only accrues from its join barrier).
    pub alive_secs: Vec<f64>,
    /// Per-replica in-flight admission receipts at end of run (`None`
    /// for schedulers without receipt tracking). Every fully drained run
    /// must end with 0 everywhere — a leak means some admission charge
    /// was never refunded (requeue/migration) or corrected (completion).
    pub outstanding_receipts: Vec<Option<usize>>,
    /// Per-replica final calibration-guard health (`None` unguarded).
    /// Diagnostic, excluded from `fingerprint()` like the trace — guard
    /// state is pinned by the trace digest via `GuardTransition` events.
    pub guard_health: Vec<Option<GuardHealth>>,
    /// Merged flight-recorder log when `ClusterOpts::with_trace` was set;
    /// `None` otherwise. Deliberately excluded from `fingerprint()` — the
    /// trace digest is its own (stronger) cross-drive determinism check.
    pub trace: Option<TraceLog>,
}

impl ClusterResult {
    pub fn finished(&self) -> usize {
        self.replicas.iter().map(|r| r.finished).sum()
    }

    pub fn total_requests(&self) -> usize {
        self.replicas.iter().map(|r| r.total_requests).sum()
    }

    pub fn preemptions(&self) -> u64 {
        self.replicas.iter().map(|r| r.preemptions).sum()
    }

    /// Cluster wall clock: the latest replica finish time.
    pub fn wall(&self) -> f64 {
        self.replicas.iter().map(|r| r.wall).fold(1e-9, f64::max)
    }

    /// Union of clients served anywhere, ascending.
    pub fn clients(&self) -> Vec<ClientId> {
        let mut set = BTreeSet::new();
        for r in &self.replicas {
            set.extend(r.service.clients());
        }
        set.into_iter().collect()
    }

    /// Global (cross-replica summed) service for one client.
    pub fn service_total(&self, client: ClientId) -> f64 {
        self.replicas.iter().map(|r| r.service.total(client)).sum()
    }

    /// Global service at time `t` — sums the per-replica curves.
    pub fn service_at(&self, client: ClientId, t: f64) -> f64 {
        self.replicas
            .iter()
            .map(|r| r.service.curve(client).map(|cv| cv.at(t)).unwrap_or(0.0))
            .sum()
    }

    pub fn grand_service(&self) -> f64 {
        self.replicas.iter().map(|r| r.service.grand_total()).sum()
    }

    /// Cluster output tokens/s over the cluster wall clock.
    pub fn output_tps(&self) -> f64 {
        let tokens: f64 = self.replicas.iter().map(|r| r.output_tps * r.wall).sum();
        tokens / self.wall()
    }

    pub fn weighted_tps(&self) -> f64 {
        self.grand_service() / self.wall()
    }

    /// Fleet busy-fraction utilization, weighted by per-replica alive
    /// time (idle tails included — a replica that finished early drags
    /// the mean down, as it should). Dividing by `replicas.len() ·
    /// wall()` would charge crashed replicas for their outage and
    /// late-joining / drained replicas for time they were not in the
    /// fleet at all; the membership-time denominator from `alive_secs`
    /// charges each replica exactly for the time it could have worked.
    /// For a static faultless fleet the two denominators coincide.
    pub fn mean_gpu_util(&self) -> f64 {
        let busy: f64 = self.replicas.iter().map(|r| r.gpu_util * r.wall).sum();
        let membership: f64 = self.alive_secs.iter().sum();
        busy / membership.max(1e-9)
    }

    /// All replicas' latency samples merged (TTFT/e2e percentiles).
    pub fn merged_latency(&self) -> LatencyStats {
        let mut out = LatencyStats::new();
        for r in &self.replicas {
            out.merge(&r.latency);
        }
        out
    }

    /// Jain's index over per-client global service totals.
    pub fn jain_over_service(&self) -> f64 {
        let xs: Vec<f64> = self.clients().iter().map(|&c| self.service_total(c)).collect();
        crate::metrics::jain_index(&xs)
    }

    /// Final merged-plane HF spread (max − min over known clients) — the
    /// sync-sweep figure's staleness-sensitivity metric.
    pub fn global_hf_spread(&self) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &(_, h) in &self.global_hf {
            lo = lo.min(h);
            hi = hi.max(h);
        }
        if lo.is_finite() {
            hi - lo
        } else {
            0.0
        }
    }

    /// Union backlog timeline: for every sample time seen by any replica,
    /// the union of backlogged clients across replicas. Sample times are
    /// bit-identical across replicas (every engine samples at the same
    /// k·sample_dt accumulation), so the f64-bits key merges exactly.
    pub fn merged_backlog_timeline(&self) -> Vec<(f64, Vec<ClientId>)> {
        let mut merged: BTreeMap<u64, BTreeSet<ClientId>> = BTreeMap::new();
        for r in &self.replicas {
            for (t, set) in &r.backlog_timeline {
                merged.entry(t.to_bits()).or_default().extend(set.iter().copied());
            }
        }
        merged
            .into_iter()
            .map(|(bits, set)| (f64::from_bits(bits), set.into_iter().collect()))
            .collect()
    }

    /// Maximal intervals during which `client` was backlogged on ANY
    /// replica, merged from the union backlog timeline — the cluster
    /// no-starvation invariant is stated over these.
    pub fn backlogged_intervals(&self, client: ClientId) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut start: Option<f64> = None;
        let mut last = 0.0f64;
        for (t, set) in self.merged_backlog_timeline() {
            if set.contains(&client) {
                if start.is_none() {
                    start = Some(t);
                }
                last = t;
            } else if let Some(s) = start.take() {
                out.push((s, last));
            }
        }
        if let Some(s) = start {
            out.push((s, last));
        }
        out
    }

    /// Every client backlogged in at least one sample window, anywhere.
    pub fn ever_backlogged_clients(&self) -> Vec<ClientId> {
        let mut set = BTreeSet::new();
        for (_, clients) in self.merged_backlog_timeline() {
            set.extend(clients);
        }
        set.into_iter().collect()
    }

    /// Total requests shed at the admission gate.
    pub fn shed_count(&self) -> u64 {
        self.shed.iter().map(|&(_, n, _)| n).sum()
    }

    /// Weighted tokens shed for one client (0 when never shed).
    pub fn shed_weighted_for(&self, client: ClientId) -> f64 {
        self.shed
            .iter()
            .find(|&&(c, _, _)| c == client)
            .map(|&(_, _, w)| w)
            .unwrap_or(0.0)
    }

    /// Cluster-wide max co-backlogged pairwise service gap — the
    /// cross-replica generalisation of `SimResult::max_co_backlogged_diff`:
    /// service is the global sum, and a client counts as backlogged if it
    /// is backlogged on ANY replica.
    pub fn max_co_backlogged_diff(&self) -> f64 {
        self.max_co_backlogged_diff_after(f64::NEG_INFINITY)
    }

    /// Same metric restricted to samples at `t ≥ t0` — the chaos
    /// harness's post-recovery discrepancy: how fast the fleet re-levels
    /// service after the last crash heals.
    ///
    /// Single timeline pass: each client's service delta is measured
    /// from its own *entry* into the co-backlogged set (its baseline;
    /// leaving the set closes the window, re-entry re-baselines), and
    /// each sample with ≥ 2 co-backlogged clients contributes the
    /// running (max − min) over the active deltas. For clients whose
    /// backlog windows open at the same sample — every sustained-
    /// overload scenario the bounded-discrepancy claim is stated over —
    /// this is bit-identical to the old all-pairs form (pinned by
    /// `linear_discrepancy_matches_quadratic_reference`) at O(Σ|set|)
    /// service lookups instead of O(C²·T): the old form was unusable at
    /// the 10k+ tenant scales (`tests/autoscale.rs` carries the
    /// wall-clock tripwire). Where windows open staggered, the per-pair
    /// baseline becomes each client's own entry rather than the pair's
    /// joint entry — at least as early, so no co-backlogged service gap
    /// is silently discarded.
    pub fn max_co_backlogged_diff_after(&self, t0: f64) -> f64 {
        let timeline = self.merged_backlog_timeline();
        let mut baseline: BTreeMap<ClientId, f64> = BTreeMap::new();
        let mut worst = 0.0f64;
        for (t, set) in &timeline {
            if *t < t0 {
                continue;
            }
            // Clients that left the set close their windows; survivors
            // keep the baselines from their own entries.
            let active: BTreeSet<ClientId> = set.iter().copied().collect();
            baseline.retain(|c, _| active.contains(c));
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for &c in set {
                let s = self.service_at(c, *t);
                let d = s - *baseline.entry(c).or_insert(s);
                lo = lo.min(d);
                hi = hi.max(d);
            }
            if set.len() >= 2 {
                worst = worst.max(hi - lo);
            }
        }
        worst
    }

    /// The seed's all-pairs formulation, kept as the executable
    /// reference the linear pass is differentially tested against on
    /// aligned-window traces (see `max_co_backlogged_diff_after`).
    #[cfg(test)]
    pub(crate) fn max_co_backlogged_diff_after_quadratic(&self, t0: f64) -> f64 {
        let timeline = self.merged_backlog_timeline();
        let clients = self.clients();
        let mut worst = 0.0f64;
        for (i, &a) in clients.iter().enumerate() {
            for &b in clients.iter().skip(i + 1) {
                let mut window_start: Option<(f64, f64)> = None; // (sa0, sb0)
                for (t, set) in &timeline {
                    let both = *t >= t0 && set.contains(&a) && set.contains(&b);
                    match (both, window_start) {
                        (true, None) => {
                            window_start = Some((self.service_at(a, *t), self.service_at(b, *t)));
                        }
                        (true, Some((sa0, sb0))) => {
                            let d = ((self.service_at(a, *t) - sa0)
                                - (self.service_at(b, *t) - sb0))
                                .abs();
                            worst = worst.max(d);
                        }
                        (false, Some(_)) => window_start = None,
                        (false, None) => {}
                    }
                }
            }
        }
        worst
    }

    /// Bit-exact run fingerprint: every replica's engine fingerprint in
    /// replica order, plus the routing decision vector and sync count —
    /// two runs of the same (trace, fleet, router, seed) must match
    /// exactly, regardless of [`DriveMode`] or thread count (the
    /// deterministic-replay and serial≡parallel invariants).
    pub fn fingerprint(&self) -> Vec<u64> {
        let mut v = Vec::new();
        for r in &self.replicas {
            v.extend(crate::harness::fingerprint(r));
            v.push(u64::MAX); // replica separator
        }
        v.extend(self.routed.iter().copied());
        v.push(self.syncs);
        for (c, hf) in &self.global_hf {
            v.push(c.0 as u64);
            v.push(hf.to_bits());
        }
        // Fault-plane state: migration targets, barrier count, and the
        // full shed ledger — a drive mode that sheds or migrates even
        // one request differently cannot produce a matching fingerprint.
        v.extend(self.migrated.iter().copied());
        v.push(self.fault_transitions);
        for &(c, n, w) in &self.shed {
            v.push(c.0 as u64);
            v.push(n);
            v.push(w.to_bits());
        }
        // Autoscale plane: applied actions, the full epoch history
        // (times + member-spec names), and the per-replica alive-time
        // ledger — a drive mode that scales at a different barrier, to a
        // different composition, or accounts membership differently
        // cannot produce a matching fingerprint.
        v.push(self.scale_transitions);
        for (t, specs) in &self.fleet_epochs {
            v.push(t.to_bits());
            v.push(specs.len() as u64);
            for spec in specs {
                let mut h = 0xcbf2_9ce4_8422_2325u64;
                for b in spec.name.bytes() {
                    h = (h ^ b as u64).wrapping_mul(0x1000_0000_01b3);
                }
                v.push(h);
            }
        }
        v.extend(self.alive_secs.iter().map(|s| s.to_bits()));
        v
    }

    /// FNV-1a digest of the fingerprint — one u64 per cluster run.
    pub fn digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for word in self.fingerprint() {
            for byte in word.to_le_bytes() {
                h = (h ^ byte as u64).wrapping_mul(0x1000_0000_01b3);
            }
        }
        h
    }
}

/// Convenience one-call runner for CLI / tests / benches.
pub fn run_cluster(
    fleet: Fleet,
    router: Box<dyn Router>,
    sched_kind: SchedKind,
    pred_kind: PredKind,
    trace: &Trace,
    opts: &ClusterOpts,
) -> ClusterResult {
    Cluster::new(fleet, router, sched_kind, pred_kind, opts, trace.horizon).run(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::RouterKind;
    use crate::workload::{generate, Scenario};

    fn quick_trace() -> Trace {
        generate(&Scenario::balanced_load(10.0), 42)
    }

    fn run(fleet: Fleet, kind: RouterKind) -> ClusterResult {
        run_with(fleet, kind, DriveMode::Serial)
    }

    fn run_with(fleet: Fleet, kind: RouterKind, drive: DriveMode) -> ClusterResult {
        let trace = quick_trace();
        run_cluster(
            fleet,
            kind.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &ClusterOpts::new(42).with_drive(drive),
        )
    }

    #[test]
    fn cluster_completes_all_requests_on_every_fleet() {
        for fleet in [Fleet::solo(), Fleet::homogeneous(4), Fleet::hetero()] {
            let res = run(fleet, RouterKind::FairShare);
            assert_eq!(res.finished(), res.total_requests(), "{}", res.fleet);
            assert_eq!(res.total_requests(), quick_trace().len(), "{}", res.fleet);
            assert!(res.wall() > 0.0);
        }
    }

    #[test]
    fn parallel_mode_completes_and_matches_serial() {
        for fleet in [Fleet::solo(), Fleet::homogeneous(4), Fleet::hetero()] {
            let serial = run_with(fleet.clone(), RouterKind::FairShare, DriveMode::Serial);
            let par = run_with(fleet, RouterKind::FairShare, DriveMode::Parallel { threads: 2 });
            assert_eq!(par.finished(), par.total_requests(), "{}", par.fleet);
            assert_eq!(
                par.fingerprint(),
                serial.fingerprint(),
                "{}: parallel drifted from serial",
                par.fleet
            );
        }
    }

    #[test]
    fn auto_thread_count_is_bit_exact_too() {
        let a = run_with(Fleet::hetero(), RouterKind::PredictedCost, DriveMode::Parallel { threads: 0 });
        let b = run_with(Fleet::hetero(), RouterKind::PredictedCost, DriveMode::Serial);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn clock_key_orders_lagging_first_with_id_tie_break() {
        // Non-negative f64 bit patterns order as total_cmp: the heap must
        // pop (earliest clock, lowest id) first.
        let mut heap: BinaryHeap<Reverse<ClockKey>> = BinaryHeap::new();
        for (t, id) in [(2.0f64, 0usize), (1.0, 2), (1.0, 1), (0.5, 3)] {
            heap.push(Reverse((t.to_bits(), id)));
        }
        let order: Vec<usize> = std::iter::from_fn(|| heap.pop().map(|Reverse((_, i))| i)).collect();
        assert_eq!(order, vec![3, 1, 2, 0]);
    }

    #[test]
    fn round_robin_spreads_request_counts_evenly() {
        let res = run(Fleet::homogeneous(4), RouterKind::RoundRobin);
        let total: u64 = res.routed.iter().sum();
        for &n in &res.routed {
            assert!(n >= total / 4 - 1 && n <= total / 4 + 1, "routed={:?}", res.routed);
        }
    }

    #[test]
    fn global_service_conservation_holds() {
        let trace = quick_trace();
        let res = run_cluster(
            Fleet::hetero(),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &ClusterOpts::new(42),
        );
        let mut demand: BTreeMap<ClientId, f64> = BTreeMap::new();
        for r in trace.requests.iter() {
            *demand.entry(r.client).or_insert(0.0) += r.weighted_tokens();
        }
        for (&c, &d) in &demand {
            let s = res.service_total(c);
            assert!(
                (s - d).abs() / d < 1e-6,
                "conservation: service[{c}]={s} demand={d}"
            );
        }
        let total: f64 = demand.values().sum();
        assert!((res.grand_service() - total).abs() / total < 1e-6);
    }

    #[test]
    fn deterministic_replay_is_bit_exact() {
        let a = run(Fleet::hetero(), RouterKind::FairShare);
        let b = run(Fleet::hetero(), RouterKind::FairShare);
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn sync_rounds_happen_on_the_period() {
        let res = run(Fleet::homogeneous(2), RouterKind::PredictedCost);
        // 10 s trace (plus drain) with a 1 s period: several mid-run
        // syncs plus the final merge.
        assert!(res.syncs >= 5, "syncs={}", res.syncs);
        assert!(!res.global_hf.is_empty());
    }

    fn run_faulty(
        fleet: Fleet,
        drive: DriveMode,
        faults: FaultPlan,
        migration: MigrationPolicy,
    ) -> ClusterResult {
        let trace = quick_trace();
        run_cluster(
            fleet,
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &ClusterOpts::new(42).with_drive(drive).with_faults(faults).with_migration(migration),
        )
    }

    #[test]
    fn fault_plans_keep_serial_and_parallel_bit_exact() {
        let plans = [
            FaultPlan::crash_recover(0, 2.5, 6.0),
            FaultPlan::brownout(1, 2.0, 2.0, 7.0),
            FaultPlan::kv_squeeze(2, 256, 1.5, 8.0),
            FaultPlan::seeded(7, 3, 10.0),
        ];
        for plan in plans {
            let serial = run_faulty(
                Fleet::hetero(),
                DriveMode::Serial,
                plan.clone(),
                MigrationPolicy::Migrate,
            );
            let par = run_faulty(
                Fleet::hetero(),
                DriveMode::Parallel { threads: 2 },
                plan.clone(),
                MigrationPolicy::Migrate,
            );
            assert_eq!(
                par.fingerprint(),
                serial.fingerprint(),
                "plan {plan:?}: parallel drifted from serial"
            );
            assert_eq!(serial.fault_transitions, par.fault_transitions);
        }
    }

    #[test]
    fn crash_with_migration_loses_nothing() {
        let res = run_faulty(
            Fleet::hetero(),
            DriveMode::Serial,
            FaultPlan::crash_recover(0, 2.5, 6.0),
            MigrationPolicy::Migrate,
        );
        assert_eq!(res.finished(), quick_trace().len());
        assert_eq!(res.total_requests(), quick_trace().len());
        let moved: u64 = res.migrated.iter().sum();
        assert!(moved > 0, "a mid-run crash must orphan something");
        assert_eq!(res.migrated[0], 0, "nothing migrates onto the dead replica");
        assert!(res.shed.is_empty());
    }

    #[test]
    fn crash_with_wait_policy_finishes_after_recovery() {
        let res = run_faulty(
            Fleet::hetero(),
            DriveMode::Serial,
            FaultPlan::crash_recover(0, 2.5, 6.0),
            MigrationPolicy::Wait,
        );
        assert_eq!(res.finished(), quick_trace().len());
        assert_eq!(res.migrated.iter().sum::<u64>(), 0);
    }

    #[test]
    fn drop_policy_loses_requests_as_the_negative_control_demands() {
        let res = run_faulty(
            Fleet::hetero(),
            DriveMode::Serial,
            FaultPlan::crash_recover(0, 2.5, 6.0),
            MigrationPolicy::Drop,
        );
        assert!(
            res.finished() < quick_trace().len(),
            "Drop must lose work, or the broken fixture proves nothing"
        );
        assert_eq!(res.shed_count(), 0, "dropped orphans are NOT shed accounting");
    }

    #[test]
    fn admission_bound_sheds_with_exact_accounting() {
        let trace = quick_trace();
        let opts = |drive| {
            ClusterOpts::new(42)
                .with_drive(drive)
                .with_admission(AdmissionPolicy {
                    max_outstanding_weighted: 2_000.0,
                    protect_underserved: false,
                })
        };
        let serial = run_cluster(
            Fleet::homogeneous(2),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &opts(DriveMode::Serial),
        );
        assert!(serial.shed_count() > 0, "a 2k-token bound must shed at 10 rps");
        assert_eq!(
            serial.finished() as u64 + serial.shed_count(),
            trace.len() as u64,
            "conservation modulo shed"
        );
        let par = run_cluster(
            Fleet::homogeneous(2),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &opts(DriveMode::Parallel { threads: 2 }),
        );
        assert_eq!(serial.fingerprint(), par.fingerprint());
    }

    #[test]
    fn faulty_runs_replay_bit_exact() {
        let plan = FaultPlan::seeded(11, 3, 10.0);
        let a = run_faulty(Fleet::hetero(), DriveMode::Serial, plan.clone(), MigrationPolicy::Migrate);
        let b = run_faulty(Fleet::hetero(), DriveMode::Serial, plan, MigrationPolicy::Migrate);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn opts_validate_catches_bad_configs() {
        let fleet = Fleet::homogeneous(2);
        assert!(ClusterOpts::new(1).validate(&fleet).is_ok());
        let mut o = ClusterOpts::new(1);
        o.sync_period = -1.0;
        assert!(o.validate(&fleet).is_err(), "negative sync period");
        o.sync_period = f64::NAN;
        assert!(o.validate(&fleet).is_err(), "NaN sync period");
        o.sync_period = 0.0;
        assert!(o.validate(&fleet).is_ok(), "zero disables periodic sync");
        let bad_plan = ClusterOpts::new(1).with_faults(FaultPlan::crash_recover(5, 1.0, 2.0));
        assert!(bad_plan.validate(&fleet).is_err(), "fault replica out of range");
        let bad_adm = ClusterOpts::new(1).with_admission(AdmissionPolicy::bounded(0.0));
        assert!(bad_adm.validate(&fleet).is_err(), "non-positive admission bound");
        let empty = Fleet { name: "empty".into(), replicas: vec![] };
        assert!(ClusterOpts::new(1).validate(&empty).is_err(), "empty fleet");
    }

    #[test]
    fn mean_gpu_util_weights_by_membership_time() {
        // Static faultless fleet: the membership denominator Σ alive_secs
        // equals replicas.len()·wall(), so the fixed metric reproduces
        // the naive one exactly.
        let res = run(Fleet::homogeneous(2), RouterKind::FairShare);
        let naive = res.replicas.iter().map(|r| r.gpu_util * r.wall).sum::<f64>()
            / (res.replicas.len() as f64 * res.wall());
        assert!(
            (res.mean_gpu_util() - naive).abs() < 1e-9,
            "static fleet must be unaffected: fixed={} naive={}",
            res.mean_gpu_util(),
            naive
        );
        assert!((res.alive_secs.iter().sum::<f64>()
            - res.replicas.len() as f64 * res.wall())
        .abs()
            < 1e-9);

        // crash_recover: replica 0 is out for 3.5 s. The naive form
        // charges it for the outage (denominator n·wall); the fixed form
        // only charges membership time, so it reads strictly higher.
        let faulty = run_faulty(
            Fleet::hetero(),
            DriveMode::Serial,
            FaultPlan::crash_recover(0, 2.5, 6.0),
            MigrationPolicy::Migrate,
        );
        let naive = faulty.replicas.iter().map(|r| r.gpu_util * r.wall).sum::<f64>()
            / (faulty.replicas.len() as f64 * faulty.wall());
        assert!(
            faulty.mean_gpu_util() > naive,
            "outage must shrink the denominator: fixed={} naive={}",
            faulty.mean_gpu_util(),
            naive
        );
        let total: f64 = faulty.alive_secs.iter().sum();
        let full = faulty.replicas.len() as f64 * faulty.wall();
        assert!(
            total < full - 3.0,
            "the ~3.5 s outage must be excluded: alive={total} full={full}"
        );
        assert!(faulty.mean_gpu_util() <= 1.0 + 1e-9);
    }

    #[test]
    fn linear_discrepancy_matches_quadratic_reference() {
        // balanced_load backlogs every client over the same windows
        // (uniform overload), where the linear pass and the seed's
        // all-pairs form are bit-identical by construction.
        for fleet in [Fleet::solo(), Fleet::hetero()] {
            let res = run(fleet, RouterKind::FairShare);
            for t0 in [f64::NEG_INFINITY, 0.0, 2.5, 5.0] {
                let fast = res.max_co_backlogged_diff_after(t0);
                let slow = res.max_co_backlogged_diff_after_quadratic(t0);
                assert_eq!(
                    fast.to_bits(),
                    slow.to_bits(),
                    "{} t0={t0}: linear={fast} quadratic={slow}",
                    res.fleet
                );
            }
            assert!(res.max_co_backlogged_diff() > 0.0, "overload must show a gap");
        }
    }

    #[test]
    fn scheduled_scale_grows_and_drains_with_exact_conservation() {
        use crate::cluster::autoscale::ScaleEvent;
        let trace = quick_trace();
        let policy = AutoscalePolicy::Schedule(vec![
            ScaleEvent::grow(2.0, ReplicaSpec::a100_40g()),
            ScaleEvent::shrink(6.0),
        ]);
        let res = run_cluster(
            Fleet::homogeneous(2),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &ClusterOpts::new(42).with_autoscale(policy),
        );
        assert_eq!(res.scale_transitions, 2);
        assert_eq!(res.replicas.len(), 3, "the grown replica stays in the result");
        // Epochs: initial 2-fleet, 3-fleet after the grow, 2-fleet after
        // the drain.
        let sizes: Vec<usize> = res.fleet_epochs.iter().map(|(_, s)| s.len()).collect();
        assert_eq!(sizes, vec![2, 3, 2], "epochs: {:?}", res.fleet_epochs);
        assert!(res.fleet_epochs[1].0 >= 2.0 && res.fleet_epochs[1].0 < 6.0);
        assert!(res.fleet_epochs[2].0 >= 6.0);
        // The drain loses nothing: every request finishes somewhere.
        assert_eq!(res.finished(), trace.len());
        assert!(res.shed.is_empty());
        // The retiree accrued membership only over its [join, drain)
        // window.
        assert!(res.alive_secs[2] < res.wall() - 1.0, "retiree: {:?}", res.alive_secs);
    }

    #[test]
    fn drive_mode_labels_and_lookup() {
        assert_eq!(DriveMode::Serial.label(), "serial");
        assert_eq!(DriveMode::Parallel { threads: 4 }.label(), "parallel4");
        assert_eq!(DriveMode::by_name("serial", 8), Some(DriveMode::Serial));
        assert_eq!(
            DriveMode::by_name("parallel", 8),
            Some(DriveMode::Parallel { threads: 8 })
        );
        assert_eq!(DriveMode::by_name("nope", 1), None);
    }
}
