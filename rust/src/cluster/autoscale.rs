//! Deterministic replica autoscaling: pure-data scale policies
//! materialized by the cluster driver ONLY at barrier boundaries.
//!
//! An [`AutoscalePolicy`] decides when the fleet grows or shrinks
//! mid-run. Like the fault plane, nothing about scaling is sampled
//! during execution and nothing happens off the driver thread:
//!
//! - [`AutoscalePolicy::Schedule`] is a fixed list of timed
//!   [`ScaleEvent`]s (grow from a [`ReplicaSpec`], or drain-and-retire
//!   one replica), compiled into a sorted cursor exactly like
//!   `FaultPlan::timeline` — the reproducible-experiment variant.
//! - [`AutoscalePolicy::Reactive`] is a target-backlog controller with
//!   hysteresis and cooldown. It is evaluated on its own fixed time
//!   grid (`eval_period`), which the driver treats as one more barrier
//!   family: the serial drive checks the grid after every step, the
//!   parallel drive folds the next evaluation time into its safe
//!   horizon. At an evaluation the controller reads the fleet's
//!   predicted drain time (outstanding routed-but-undelivered weighted
//!   tokens over alive replicas ÷ their aggregate peak weighted
//!   throughput) and grows above `high_backlog_s`, shrinks below
//!   `low_backlog_s` — both suppressed inside `cooldown_s` of the last
//!   action and clamped to `[min_replicas, max_replicas]` alive.
//!
//! Because every decision happens at a barrier — on the driver thread,
//! at identical cluster times, from identical replica state in both
//! drive modes — `DriveMode::Serial` and `DriveMode::Parallel` stay
//! bit-exact under every policy (pinned by `tests/autoscale.rs`).
//!
//! Scale-out instantiates a fresh replica from the spec (new highest
//! replica id, predictor stream derived from the same base seed,
//! engine clock fast-forwarded to the barrier time) and joins it to
//! the plane, the fault timeline, and the router views. Scale-in is a
//! graceful drain, never a kill: the victim (highest alive id) is
//! marked dead to routing, its queued and in-flight requests are
//! extracted through the same orphan path a crash uses and re-placed
//! on survivors with rework-watermark pricing — so per-client service
//! conservation holds exactly across every fleet change.

use super::fleet::ReplicaSpec;

/// What one scale event does to the fleet.
#[derive(Debug, Clone)]
pub enum ScaleAction {
    /// Instantiate a new replica from this spec and join it to the
    /// cluster (clock fast-forwarded to the barrier time).
    Grow(ReplicaSpec),
    /// Drain-and-retire the highest-id alive replica: mark it dead to
    /// routing, migrate its queued/in-flight work to survivors, never
    /// revive it. Skipped (not an error) if it would leave the fleet
    /// without an alive replica.
    Shrink,
}

/// One timed fleet change in a [`AutoscalePolicy::Schedule`].
#[derive(Debug, Clone)]
pub struct ScaleEvent {
    /// Cluster time at which the event materializes (at the first
    /// barrier whose time crosses it — same semantics as a fault
    /// transition).
    pub at: f64,
    pub action: ScaleAction,
}

impl ScaleEvent {
    pub fn grow(at: f64, spec: ReplicaSpec) -> ScaleEvent {
        ScaleEvent { at, action: ScaleAction::Grow(spec) }
    }

    pub fn shrink(at: f64) -> ScaleEvent {
        ScaleEvent { at, action: ScaleAction::Shrink }
    }
}

/// The reactive target-backlog controller's knobs (see module docs).
#[derive(Debug, Clone)]
pub struct ReactivePolicy {
    /// Grow when the fleet's predicted drain time exceeds this many
    /// seconds. Must be strictly above `low_backlog_s` (hysteresis).
    pub high_backlog_s: f64,
    /// Shrink when the predicted drain time falls below this.
    pub low_backlog_s: f64,
    /// Fixed evaluation grid: the controller looks at the signal when
    /// cluster time crosses k·eval_period, exactly like a plane sync.
    pub eval_period: f64,
    /// Minimum quiet time after any applied action before the next.
    pub cooldown_s: f64,
    /// Never shrink below this many alive replicas.
    pub min_replicas: usize,
    /// Never grow above this many alive replicas.
    pub max_replicas: usize,
    /// The spec every reactive scale-out instantiates.
    pub pool: ReplicaSpec,
}

impl ReactivePolicy {
    /// A reasonable controller around the given thresholds: 0.5 s
    /// evaluation grid, 1 s cooldown, 1..=8 alive replicas.
    pub fn new(high_backlog_s: f64, low_backlog_s: f64, pool: ReplicaSpec) -> ReactivePolicy {
        ReactivePolicy {
            high_backlog_s,
            low_backlog_s,
            eval_period: 0.5,
            cooldown_s: 1.0,
            min_replicas: 1,
            max_replicas: 8,
            pool,
        }
    }

    pub fn with_bounds(mut self, min_replicas: usize, max_replicas: usize) -> ReactivePolicy {
        self.min_replicas = min_replicas;
        self.max_replicas = max_replicas;
        self
    }

    pub fn with_cooldown(mut self, cooldown_s: f64) -> ReactivePolicy {
        self.cooldown_s = cooldown_s;
        self
    }

    pub fn with_eval_period(mut self, eval_period: f64) -> ReactivePolicy {
        self.eval_period = eval_period;
        self
    }
}

/// A pure-data autoscaling policy, fixed before the run. Validate with
/// [`AutoscalePolicy::validate`] (wired into `ClusterOpts::validate`).
#[derive(Debug, Clone, Default)]
pub enum AutoscalePolicy {
    /// Static fleet — the driver's default; zero overhead, zero new
    /// barriers.
    #[default]
    Off,
    /// Fixed timed events, applied in `(at, index)` order.
    Schedule(Vec<ScaleEvent>),
    /// Target-backlog controller with hysteresis and cooldown.
    Reactive(ReactivePolicy),
}

impl AutoscalePolicy {
    pub fn is_off(&self) -> bool {
        matches!(self, AutoscalePolicy::Off)
    }

    pub fn label(&self) -> String {
        match self {
            AutoscalePolicy::Off => "off".into(),
            AutoscalePolicy::Schedule(events) => format!("sched{}", events.len()),
            AutoscalePolicy::Reactive(_) => "reactive".into(),
        }
    }

    /// Structural validation: finite forward event times, coherent
    /// controller thresholds and bounds. A `Schedule` shrink that would
    /// empty the fleet is a *runtime* no-op (alive count is dynamic),
    /// not a validation error.
    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            AutoscalePolicy::Off => Ok(()),
            AutoscalePolicy::Schedule(events) => {
                for (i, ev) in events.iter().enumerate() {
                    anyhow::ensure!(
                        ev.at.is_finite() && ev.at >= 0.0,
                        "scale event {i}: time {} must be finite and non-negative",
                        ev.at
                    );
                }
                Ok(())
            }
            AutoscalePolicy::Reactive(p) => {
                anyhow::ensure!(
                    p.high_backlog_s.is_finite() && p.low_backlog_s.is_finite(),
                    "reactive thresholds must be finite (got high={}, low={})",
                    p.high_backlog_s,
                    p.low_backlog_s
                );
                anyhow::ensure!(
                    p.low_backlog_s >= 0.0 && p.high_backlog_s > p.low_backlog_s,
                    "reactive hysteresis needs 0 <= low < high (got low={}, high={})",
                    p.low_backlog_s,
                    p.high_backlog_s
                );
                anyhow::ensure!(
                    p.eval_period.is_finite() && p.eval_period > 0.0,
                    "reactive eval period must be finite and positive (got {})",
                    p.eval_period
                );
                anyhow::ensure!(
                    p.cooldown_s.is_finite() && p.cooldown_s >= 0.0,
                    "reactive cooldown must be finite and non-negative (got {})",
                    p.cooldown_s
                );
                anyhow::ensure!(
                    p.min_replicas >= 1 && p.max_replicas >= p.min_replicas,
                    "reactive bounds need 1 <= min <= max (got {}..={})",
                    p.min_replicas,
                    p.max_replicas
                );
                Ok(())
            }
        }
    }

    /// Compile into the driver's runtime cursor. Call [`validate`]
    /// first; the state assumes a well-formed policy.
    ///
    /// [`validate`]: AutoscalePolicy::validate
    pub fn state(&self) -> ScaleState {
        let mut events: Vec<ScaleEvent> = match self {
            AutoscalePolicy::Schedule(events) => events.clone(),
            _ => Vec::new(),
        };
        // Time order with a stable index tie-break (sort_by is stable):
        // two events at the same instant apply in schedule order.
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        let reactive = match self {
            AutoscalePolicy::Reactive(p) => Some(p.clone()),
            _ => None,
        };
        let next_eval = reactive.as_ref().map_or(f64::INFINITY, |p| p.eval_period);
        ScaleState { events, cursor: 0, reactive, next_eval, cooldown_until: f64::NEG_INFINITY }
    }
}

/// An [`AutoscalePolicy`] compiled into the driver's runtime view: a
/// cursor over sorted scheduled events plus the reactive controller's
/// evaluation grid and cooldown clock. The driver polls
/// [`due`]/[`next_event_at`] at every barrier, pops due scheduled
/// events, and asks [`decide`] at due evaluations.
///
/// [`due`]: ScaleState::due
/// [`next_event_at`]: ScaleState::next_event_at
/// [`decide`]: ScaleState::decide
#[derive(Debug)]
pub struct ScaleState {
    events: Vec<ScaleEvent>,
    cursor: usize,
    reactive: Option<ReactivePolicy>,
    /// Next reactive evaluation boundary; `INFINITY` when not reactive.
    next_eval: f64,
    /// No reactive action applies before this cluster time.
    cooldown_until: f64,
}

impl ScaleState {
    /// Time of the next scheduled (not reactive) event; `INFINITY` when
    /// exhausted. The post-trace drain loop forces these to materialize
    /// even after the fleet goes quiescent.
    pub fn next_scheduled_at(&self) -> f64 {
        self.events.get(self.cursor).map_or(f64::INFINITY, |ev| ev.at)
    }

    /// The next time anything about scaling can happen — a parallel-
    /// drive horizon bound, exactly like the plane's `next_sync_at` and
    /// the fault timeline's `next_transition_at`.
    pub fn next_event_at(&self) -> f64 {
        self.next_scheduled_at().min(self.next_eval)
    }

    /// Is a scheduled event or a reactive evaluation due at cluster
    /// time `t`?
    pub fn due(&self, t: f64) -> bool {
        self.next_event_at() <= t
    }

    /// Scheduled events not yet materialized (reactive evaluations
    /// carry no obligation past quiescence — with no work left the
    /// signal is 0 and the fleet only ever shrinks to `min_replicas`).
    pub fn has_pending(&self) -> bool {
        self.cursor < self.events.len()
    }

    /// Pop the next scheduled event with time ≤ `t` (driver applies
    /// them one at a time, in order).
    pub fn pop_scheduled(&mut self, t: f64) -> Option<ScaleEvent> {
        if self.next_scheduled_at() <= t {
            let ev = self.events[self.cursor].clone();
            self.cursor += 1;
            Some(ev)
        } else {
            None
        }
    }

    /// Is a reactive evaluation due at `t`?
    pub fn eval_due(&self, t: f64) -> bool {
        self.next_eval <= t
    }

    /// The controller's decision at an evaluation: `signal` is the
    /// fleet's predicted drain time in seconds, `alive` the current
    /// alive replica count. Pure function of its arguments and the
    /// cooldown clock — both drive modes call it at identical barrier
    /// times with identical state.
    pub fn decide(&self, signal: f64, alive: usize, t: f64) -> Option<ScaleAction> {
        let p = self.reactive.as_ref()?;
        if t < self.cooldown_until {
            return None;
        }
        if signal > p.high_backlog_s && alive < p.max_replicas {
            return Some(ScaleAction::Grow(p.pool.clone()));
        }
        if signal < p.low_backlog_s && alive > p.min_replicas {
            return Some(ScaleAction::Shrink);
        }
        None
    }

    /// Complete an evaluation at `t`: advance the grid past `t`
    /// (skipping boundaries the run never observed, like
    /// `GlobalPlane::finish_sync`).
    pub fn finish_eval(&mut self, t: f64) {
        if let Some(p) = &self.reactive {
            while self.next_eval <= t {
                self.next_eval += p.eval_period;
            }
        }
    }

    /// Record an applied reactive action at `t` (starts the cooldown).
    pub fn note_action(&mut self, t: f64) {
        if let Some(p) = &self.reactive {
            self.cooldown_until = t + p.cooldown_s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_accepts_sane_policies() {
        assert!(AutoscalePolicy::Off.validate().is_ok());
        let sched = AutoscalePolicy::Schedule(vec![
            ScaleEvent::grow(2.0, ReplicaSpec::a100_40g()),
            ScaleEvent::shrink(6.0),
        ]);
        assert!(sched.validate().is_ok());
        let reactive = AutoscalePolicy::Reactive(ReactivePolicy::new(
            3.0,
            0.5,
            ReplicaSpec::a100_40g(),
        ));
        assert!(reactive.validate().is_ok());
    }

    #[test]
    fn validate_rejects_malformed_policies() {
        let bad_time = AutoscalePolicy::Schedule(vec![ScaleEvent::shrink(f64::NAN)]);
        assert!(bad_time.validate().is_err(), "NaN event time");
        let neg = AutoscalePolicy::Schedule(vec![ScaleEvent::shrink(-1.0)]);
        assert!(neg.validate().is_err(), "negative event time");
        let p = ReplicaSpec::a100_40g;
        let inverted = AutoscalePolicy::Reactive(ReactivePolicy::new(0.5, 3.0, p()));
        assert!(inverted.validate().is_err(), "low above high");
        let mut zero_eval = ReactivePolicy::new(3.0, 0.5, p());
        zero_eval.eval_period = 0.0;
        assert!(AutoscalePolicy::Reactive(zero_eval).validate().is_err(), "zero eval grid");
        let bad_bounds = ReactivePolicy::new(3.0, 0.5, p()).with_bounds(4, 2);
        assert!(AutoscalePolicy::Reactive(bad_bounds).validate().is_err(), "min above max");
        let no_min = ReactivePolicy::new(3.0, 0.5, p()).with_bounds(0, 2);
        assert!(AutoscalePolicy::Reactive(no_min).validate().is_err(), "zero min");
    }

    #[test]
    fn schedule_state_pops_in_time_order() {
        // Deliberately unsorted schedule: the state sorts it.
        let policy = AutoscalePolicy::Schedule(vec![
            ScaleEvent::shrink(6.0),
            ScaleEvent::grow(2.0, ReplicaSpec::a100_40g()),
        ]);
        let mut st = policy.state();
        assert!(st.has_pending());
        assert_eq!(st.next_event_at(), 2.0);
        assert!(!st.due(1.9));
        assert!(st.due(2.0));
        let first = st.pop_scheduled(2.0).expect("grow due");
        assert!(matches!(first.action, ScaleAction::Grow(_)));
        assert!(st.pop_scheduled(2.0).is_none(), "shrink not due yet");
        assert_eq!(st.next_event_at(), 6.0);
        let second = st.pop_scheduled(10.0).expect("shrink due");
        assert!(matches!(second.action, ScaleAction::Shrink));
        assert!(!st.has_pending());
        assert!(st.next_event_at().is_infinite());
    }

    #[test]
    fn off_state_is_never_due() {
        let st = AutoscalePolicy::Off.state();
        assert!(!st.due(1e12));
        assert!(!st.has_pending());
        assert!(st.next_event_at().is_infinite());
    }

    #[test]
    fn reactive_state_runs_the_eval_grid_with_hysteresis() {
        let policy = AutoscalePolicy::Reactive(
            ReactivePolicy::new(3.0, 0.5, ReplicaSpec::a100_40g())
                .with_bounds(1, 3)
                .with_cooldown(2.0)
                .with_eval_period(1.0),
        );
        let mut st = policy.state();
        assert!(!st.has_pending(), "reactive has no scheduled obligations");
        assert_eq!(st.next_event_at(), 1.0);
        assert!(st.eval_due(1.0));
        // High signal under the cap: grow.
        assert!(matches!(st.decide(5.0, 2, 1.0), Some(ScaleAction::Grow(_))));
        st.note_action(1.0);
        st.finish_eval(1.0);
        assert_eq!(st.next_event_at(), 2.0);
        // Inside the cooldown window: suppressed even with a high signal.
        assert!(st.decide(5.0, 3, 2.0).is_none(), "cooldown suppresses");
        // At the cap: no grow; in the dead band: no action.
        assert!(st.decide(5.0, 3, 4.0).is_none(), "max replicas caps growth");
        assert!(st.decide(1.0, 2, 4.0).is_none(), "dead band holds");
        // Low signal above the floor: shrink; at the floor: hold.
        assert!(matches!(st.decide(0.1, 2, 4.0), Some(ScaleAction::Shrink)));
        assert!(st.decide(0.1, 1, 4.0).is_none(), "min replicas floors shrink");
        // A long quiescent gap skips every crossed boundary at once.
        st.finish_eval(7.25);
        assert_eq!(st.next_event_at(), 8.0);
    }

    #[test]
    fn labels_name_the_policy_shape() {
        assert_eq!(AutoscalePolicy::Off.label(), "off");
        assert_eq!(AutoscalePolicy::default().label(), "off");
        let sched = AutoscalePolicy::Schedule(vec![ScaleEvent::shrink(1.0)]);
        assert_eq!(sched.label(), "sched1");
        let reactive =
            AutoscalePolicy::Reactive(ReactivePolicy::new(3.0, 0.5, ReplicaSpec::a100_40g()));
        assert_eq!(reactive.label(), "reactive");
    }
}
