//! The deterministic prediction-fault plane: pure-data misprediction
//! plans wrapping any [`Predictor`].
//!
//! PR 6's `cluster/faults.rs` degrades *infrastructure* (crashes,
//! brownouts, KV squeezes); this module degrades *information*. A
//! [`PredFaultPlan`] is a set of timed segments — systematic bias
//! ([`PredFault::Bias`]), calibration drift growing with cluster time
//! ([`PredFault::Drift`]), rare huge misses ([`PredFault::HeavyTail`]),
//! one MoPE regime returning centroid garbage
//! ([`PredFault::ExpertBlackout`]), and a constant-output failure
//! ([`PredFault::Stuck`]) — fixed before the run starts (hand-built
//! presets or [`PredFaultPlan::seeded`]), then applied by
//! [`DegradedPredictor`] on top of the wrapped predictor's estimate.
//!
//! Determinism contract: the degradation applied to a request is a pure
//! function of `(plan seed, request id, request arrival)` — segment
//! activity keys off `req.arrival` (identical under both drive modes)
//! and every random draw comes from a fresh per-`(seed, request,
//! segment)` hashed stream, never a shared sequential generator. So the
//! exact same requests get the exact same degraded predictions under
//! `DriveMode::Serial`, `DriveMode::Parallel`, and across replays —
//! the zero-drift contract extends to every prediction-fault plan, and
//! `harness/mispredict.rs` machine-checks the trace digests to prove it.

use super::Predictor;
use crate::core::Request;
use crate::util::rng::Rng;

/// Stream-separation constant for prediction-fault randomness (distinct
/// from the `cluster/faults.rs` magic so a shared base seed never
/// correlates infrastructure and information faults).
const PRED_FAULT_MAGIC: u64 = 0xBAD5_EED0_BAD5_EED0;

/// One timed misprediction segment. `at`/`until` are simulated cluster
/// seconds against each request's *arrival* time; every segment is an
/// interval `[at, until)` with automatic recovery at `until`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PredFault {
    /// Every prediction is multiplied by `factor` (systematic
    /// over/under-estimation; `factor > 1` inflates, `< 1` deflates).
    Bias { at: f64, until: f64, factor: f64 },
    /// Calibration drift: the multiplicative error grows linearly with
    /// time-in-segment — a request arriving at `t` sees its prediction
    /// scaled by `1 + rate·(t − at)`. Models a workload shifting out
    /// from under a frozen regressor.
    Drift { at: f64, until: f64, rate: f64 },
    /// Heavy-tailed misses: with probability `p` (per request, hashed —
    /// never sampled sequentially) the prediction is multiplied by
    /// `factor`. Models rare catastrophic regressor failures.
    HeavyTail { at: f64, until: f64, p: f64, factor: f64 },
    /// One MoPE regime blacks out: any prediction routed into `regime`
    /// (by the paper's 3-expert boundaries) is replaced by noisy
    /// centroid garbage — the expert's weights are gone and the router
    /// can only emit its prior.
    ExpertBlackout { at: f64, until: f64, regime: usize },
    /// The predictor wedges and returns a constant `tokens` for every
    /// request — a crashed inference server behind a stale cache.
    Stuck { at: f64, until: f64, tokens: u32 },
}

impl PredFault {
    pub fn at(&self) -> f64 {
        match *self {
            PredFault::Bias { at, .. }
            | PredFault::Drift { at, .. }
            | PredFault::HeavyTail { at, .. }
            | PredFault::ExpertBlackout { at, .. }
            | PredFault::Stuck { at, .. } => at,
        }
    }

    pub fn until(&self) -> f64 {
        match *self {
            PredFault::Bias { until, .. }
            | PredFault::Drift { until, .. }
            | PredFault::HeavyTail { until, .. }
            | PredFault::ExpertBlackout { until, .. }
            | PredFault::Stuck { until, .. } => until,
        }
    }

    fn label(&self) -> &'static str {
        match self {
            PredFault::Bias { .. } => "bias",
            PredFault::Drift { .. } => "drift",
            PredFault::HeavyTail { .. } => "heavy_tail",
            PredFault::ExpertBlackout { .. } => "blackout",
            PredFault::Stuck { .. } => "stuck",
        }
    }
}

/// A pure-data misprediction schedule, fixed before the run. Build by
/// preset, by [`PredFaultPlan::with_event`], or seeded;
/// [`PredFaultPlan::validate`] before handing it to a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PredFaultPlan {
    /// Seed for the per-request hashed randomness (`HeavyTail` draws,
    /// `ExpertBlackout` garbage noise). Plans differing only in seed
    /// degrade the same windows with different per-request draws.
    pub seed: u64,
    pub events: Vec<PredFault>,
}

impl PredFaultPlan {
    /// The empty plan: predictions pass through untouched (the default).
    pub fn none() -> PredFaultPlan {
        PredFaultPlan { seed: 0, events: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn with_seed(mut self, seed: u64) -> PredFaultPlan {
        self.seed = seed;
        self
    }

    pub fn with_event(mut self, ev: PredFault) -> PredFaultPlan {
        self.events.push(ev);
        self
    }

    /// Every prediction scaled by `factor` on `[at, until)`.
    pub fn bias_storm(factor: f64, at: f64, until: f64) -> PredFaultPlan {
        PredFaultPlan::none().with_event(PredFault::Bias { at, until, factor })
    }

    /// Linear calibration drift at `rate` per second on `[at, until)`.
    pub fn drift_ramp(rate: f64, at: f64, until: f64) -> PredFaultPlan {
        PredFaultPlan::none().with_event(PredFault::Drift { at, until, rate })
    }

    /// One MoPE regime returns centroid garbage on `[at, until)`.
    pub fn regime_blackout(regime: usize, at: f64, until: f64) -> PredFaultPlan {
        PredFaultPlan::none().with_event(PredFault::ExpertBlackout { at, until, regime })
    }

    /// Rare huge misses: probability `p`, magnitude `factor`.
    pub fn heavy_tail(p: f64, factor: f64, at: f64, until: f64) -> PredFaultPlan {
        PredFaultPlan::none().with_event(PredFault::HeavyTail { at, until, p, factor })
    }

    /// The predictor wedges at a constant `tokens` on `[at, until)`.
    pub fn stuck_at(tokens: u32, at: f64, until: f64) -> PredFaultPlan {
        PredFaultPlan::none().with_event(PredFault::Stuck { at, until, tokens })
    }

    /// A seeded random plan over a `horizon`-second trace: one to three
    /// independently drawn segments. Purely a function of
    /// `(seed, horizon)` — the plan is data, the run never samples.
    pub fn seeded(seed: u64, horizon: f64) -> PredFaultPlan {
        let mut plan = PredFaultPlan::none().with_seed(seed);
        if !(horizon > 0.0) {
            return plan;
        }
        let mut rng = Rng::new(seed ^ PRED_FAULT_MAGIC);
        let mut frac = move || (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        let n = 1 + (frac() * 3.0) as usize;
        for _ in 0..n {
            let at = horizon * (0.10 + 0.40 * frac());
            let until = at + horizon * (0.10 + 0.35 * frac());
            let shape = (frac() * 5.0) as u32;
            let ev = match shape {
                0 => {
                    // Bias in [0.4, 0.8] ∪ [1.25, 2.5] — never ≈1.
                    let up = frac() < 0.5;
                    let factor =
                        if up { 1.25 + 1.25 * frac() } else { 0.4 + 0.4 * frac() };
                    PredFault::Bias { at, until, factor }
                }
                1 => {
                    let rate = (0.5 + 2.0 * frac()) / horizon.max(1.0);
                    PredFault::Drift { at, until, rate }
                }
                2 => {
                    let p = 0.02 + 0.08 * frac();
                    let factor = 4.0 + 12.0 * frac();
                    PredFault::HeavyTail { at, until, p, factor }
                }
                3 => {
                    let regime = (frac() * 3.0) as usize;
                    PredFault::ExpertBlackout { at, until, regime }
                }
                _ => {
                    let tokens = 8 + (frac() * 512.0) as u32;
                    PredFault::Stuck { at, until, tokens }
                }
            };
            plan.events.push(ev);
        }
        plan
    }

    /// The latest segment end in the plan (0 when empty) — the
    /// mispredict harness measures ladder recovery from here.
    pub fn last_recovery_at(&self) -> f64 {
        self.events.iter().map(|e| e.until()).fold(0.0, f64::max)
    }

    /// Structural validation against a regime count (for
    /// [`PredFault::ExpertBlackout`] targets): finite forward intervals,
    /// sane magnitudes, probabilities in range.
    pub fn validate(&self, n_regimes: usize) -> anyhow::Result<()> {
        anyhow::ensure!(n_regimes > 0, "prediction-fault plan: zero regimes");
        for (i, ev) in self.events.iter().enumerate() {
            let (at, until) = (ev.at(), ev.until());
            anyhow::ensure!(
                at.is_finite() && at >= 0.0,
                "pred fault {i} ({}): start time {at} must be finite and non-negative",
                ev.label()
            );
            anyhow::ensure!(
                until.is_finite() && until > at,
                "pred fault {i} ({}): end time {until} must be finite and after start {at}",
                ev.label()
            );
            match *ev {
                PredFault::Bias { factor, .. } => anyhow::ensure!(
                    factor.is_finite() && factor > 0.0,
                    "pred fault {i}: bias factor {factor} must be finite and positive"
                ),
                PredFault::Drift { rate, .. } => anyhow::ensure!(
                    rate.is_finite() && rate >= 0.0,
                    "pred fault {i}: drift rate {rate} must be finite and non-negative"
                ),
                PredFault::HeavyTail { p, factor, .. } => {
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&p),
                        "pred fault {i}: heavy-tail probability {p} must be in [0, 1]"
                    );
                    anyhow::ensure!(
                        factor.is_finite() && factor > 0.0,
                        "pred fault {i}: heavy-tail factor {factor} must be finite and positive"
                    );
                }
                PredFault::ExpertBlackout { regime, .. } => anyhow::ensure!(
                    regime < n_regimes,
                    "pred fault {i}: blackout regime {regime} out of range ({n_regimes} regimes)"
                ),
                PredFault::Stuck { tokens, .. } => anyhow::ensure!(
                    tokens >= 1,
                    "pred fault {i}: stuck tokens must be >= 1"
                ),
            }
        }
        Ok(())
    }
}

/// Wraps any predictor and applies an active [`PredFaultPlan`] to its
/// estimates. Regime classification for [`PredFault::ExpertBlackout`]
/// uses the paper's 3-expert boundaries (<53 / 53–210 / >210) applied
/// to the *inner* prediction — the blackout corrupts what the router
/// would have dispatched, without peeking at the truth.
pub struct DegradedPredictor {
    inner: Box<dyn Predictor>,
    plan: PredFaultPlan,
    boundaries: Vec<u32>,
    /// Geometric-mean centroid (log space) of the whole token range —
    /// the router's prior, which is all a blacked-out regime can emit.
    global_log_centroid: f64,
    max_tokens: u32,
}

impl DegradedPredictor {
    pub fn new(inner: Box<dyn Predictor>, plan: PredFaultPlan) -> DegradedPredictor {
        let max_tokens = super::MopeConfig::default().max_tokens;
        DegradedPredictor {
            inner,
            plan,
            boundaries: super::MopeConfig::default().boundaries(),
            global_log_centroid: (1.0f64 * max_tokens as f64).sqrt().ln(),
            max_tokens,
        }
    }

    fn regime_of(&self, tokens: u32) -> usize {
        self.boundaries.iter().position(|&b| tokens < b).unwrap_or(self.boundaries.len())
    }

    /// Fresh hashed stream for one `(plan seed, request, segment)`
    /// triple — order-independent by construction.
    fn req_rng(&self, req: &Request, segment: usize) -> Rng {
        Rng::new(
            self.plan.seed
                ^ PRED_FAULT_MAGIC
                ^ req.id.0.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (segment as u64).wrapping_mul(0xA24B_AED4_963E_E407),
        )
    }
}

impl Predictor for DegradedPredictor {
    fn name(&self) -> &'static str {
        "degraded"
    }

    fn predict_tokens(&mut self, req: &Request) -> u32 {
        let base = self.inner.predict_tokens(req);
        if self.plan.is_empty() {
            return base;
        }
        let t = req.arrival;
        let mut pred = base as f64;
        for (i, ev) in self.plan.events.iter().enumerate() {
            if !(ev.at() <= t && t < ev.until()) {
                continue;
            }
            match *ev {
                PredFault::Bias { factor, .. } => pred *= factor,
                PredFault::Drift { at, rate, .. } => pred *= 1.0 + rate * (t - at),
                PredFault::HeavyTail { p, factor, .. } => {
                    let mut rng = self.req_rng(req, i);
                    if rng.chance(p) {
                        pred *= factor;
                    }
                }
                PredFault::ExpertBlackout { regime, .. } => {
                    if self.regime_of(base) == regime {
                        let mut rng = self.req_rng(req, i);
                        let noise = crate::util::dist::std_normal(&mut rng);
                        pred = (self.global_log_centroid + 1.2 * noise).exp();
                    }
                }
                PredFault::Stuck { tokens, .. } => pred = tokens as f64,
            }
        }
        (pred.round() as u32).clamp(1, self.max_tokens)
    }

    fn predict_cost(&self) -> f64 {
        self.inner.predict_cost()
    }

    fn observe(&mut self, req: &Request, actual_output: u32) {
        self.inner.observe(req, actual_output);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, RequestId};
    use crate::predictor::Oracle;

    fn req(id: u64, out: u32, arrival: f64) -> Request {
        Request::new(RequestId(id), ClientId(0), 50, out, arrival)
    }

    fn degraded(plan: PredFaultPlan) -> DegradedPredictor {
        DegradedPredictor::new(Box::new(Oracle::new()), plan)
    }

    #[test]
    fn empty_plan_is_identity() {
        let mut d = degraded(PredFaultPlan::none());
        for out in [1u32, 53, 210, 512, 1024] {
            assert_eq!(d.predict_tokens(&req(out as u64, out, 5.0)), out);
        }
    }

    #[test]
    fn bias_scales_only_inside_window() {
        let mut d = degraded(PredFaultPlan::bias_storm(2.0, 10.0, 20.0));
        assert_eq!(d.predict_tokens(&req(1, 100, 5.0)), 100, "before window");
        assert_eq!(d.predict_tokens(&req(2, 100, 10.0)), 200, "window start inclusive");
        assert_eq!(d.predict_tokens(&req(3, 100, 19.9)), 200, "inside window");
        assert_eq!(d.predict_tokens(&req(4, 100, 20.0)), 100, "window end exclusive");
    }

    #[test]
    fn drift_error_grows_with_time() {
        let mut d = degraded(PredFaultPlan::drift_ramp(0.1, 0.0, 100.0));
        assert_eq!(d.predict_tokens(&req(1, 100, 0.0)), 100);
        let early = d.predict_tokens(&req(2, 100, 10.0));
        let late = d.predict_tokens(&req(3, 100, 50.0));
        assert_eq!(early, 200);
        assert_eq!(late, 600);
        assert!(late > early);
    }

    #[test]
    fn stuck_returns_constant() {
        let mut d = degraded(PredFaultPlan::stuck_at(7, 0.0, 100.0));
        for (i, out) in [1u32, 100, 900].into_iter().enumerate() {
            assert_eq!(d.predict_tokens(&req(i as u64, out, 50.0)), 7);
        }
    }

    #[test]
    fn blackout_hits_only_target_regime() {
        let mut d = degraded(PredFaultPlan::regime_blackout(2, 0.0, 100.0));
        // Regimes 0 and 1 untouched; regime 2 (>210) garbled.
        assert_eq!(d.predict_tokens(&req(1, 40, 5.0)), 40);
        assert_eq!(d.predict_tokens(&req(2, 100, 5.0)), 100);
        let garbled = d.predict_tokens(&req(3, 800, 5.0));
        assert_ne!(garbled, 800);
    }

    #[test]
    fn heavy_tail_hits_roughly_p_fraction() {
        let plan = PredFaultPlan::heavy_tail(0.1, 10.0, 0.0, 1e9).with_seed(42);
        let mut d = degraded(plan);
        let hits = (0..5_000)
            .filter(|&i| d.predict_tokens(&req(i, 100, 50.0)) == 1000)
            .count();
        let frac = hits as f64 / 5_000.0;
        assert!((0.07..0.13).contains(&frac), "heavy-tail hit rate {frac}, want ≈0.10");
    }

    #[test]
    fn degradation_is_order_independent() {
        // The same request set predicted in different orders (and
        // interleaved with other requests) gets identical degradations —
        // the cross-drive determinism property in miniature.
        let plan = PredFaultPlan::seeded(7, 100.0);
        plan.validate(3).unwrap();
        let reqs: Vec<Request> =
            (0..200).map(|i| req(i, 1 + (i as u32 * 37) % 1000, (i as f64) * 0.5)).collect();
        let mut fwd = degraded(plan.clone());
        let a: Vec<u32> = reqs.iter().map(|r| fwd.predict_tokens(r)).collect();
        let mut rev = degraded(plan);
        let mut b: Vec<u32> = reqs.iter().rev().map(|r| rev.predict_tokens(r)).collect();
        b.reverse();
        assert_eq!(a, b);
    }

    #[test]
    fn seeded_plans_validate_and_replay() {
        for seed in [1u64, 42, 2024, 0xDEAD_BEEF] {
            let plan = PredFaultPlan::seeded(seed, 30.0);
            plan.validate(3).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert_eq!(plan, PredFaultPlan::seeded(seed, 30.0), "seeded plan must replay");
            assert!(!plan.is_empty());
        }
        assert!(PredFaultPlan::seeded(7, 0.0).is_empty());
    }

    #[test]
    fn validate_rejects_malformed_plans() {
        assert!(PredFaultPlan::bias_storm(0.0, 1.0, 2.0).validate(3).is_err(), "zero bias");
        assert!(PredFaultPlan::bias_storm(2.0, 2.0, 1.0).validate(3).is_err(), "inverted");
        assert!(PredFaultPlan::bias_storm(2.0, f64::NAN, 2.0).validate(3).is_err(), "NaN");
        assert!(PredFaultPlan::heavy_tail(1.5, 4.0, 0.0, 1.0).validate(3).is_err(), "p > 1");
        assert!(PredFaultPlan::regime_blackout(3, 0.0, 1.0).validate(3).is_err(), "regime");
        assert!(PredFaultPlan::regime_blackout(2, 0.0, 1.0).validate(3).is_ok());
        assert!(PredFaultPlan::stuck_at(0, 0.0, 1.0).validate(3).is_err(), "zero stuck");
        assert!(PredFaultPlan::none().validate(0).is_err(), "zero regimes");
    }

    #[test]
    fn last_recovery_tracks_latest_segment_end() {
        assert_eq!(PredFaultPlan::none().last_recovery_at(), 0.0);
        let plan = PredFaultPlan::bias_storm(2.0, 1.0, 4.0)
            .with_event(PredFault::Drift { at: 2.0, until: 9.0, rate: 0.1 });
        assert_eq!(plan.last_recovery_at(), 9.0);
    }
}
