//! MoPE — Mixture of Prediction Experts (§6).
//!
//! A lightweight router classifies each prompt into an output-length
//! regime; a per-regime expert regressor predicts the length. The paper's
//! measurements (Fig 7): router accuracy ≈ 80% at full training size;
//! L1 error 80 (1 expert) → 33 (3 experts) → 25 (5 experts); router
//! overhead 0.02 ms on top of a 4.5 ms expert forward pass.
//!
//! This module reproduces MoPE's *information quality* deterministically.
//! The router is a threshold classifier over prompt features, so its
//! errors concentrate near the regime boundaries (<53 / 53–210 / >210 for
//! three experts, the paper's 33rd/66th LMSYS percentiles): requests well
//! inside a regime are always routed correctly, boundary-zone requests
//! flip sides with a probability chosen so the *global* top-1 accuracy
//! matches the configured value. Misrouted requests are handled by the
//! adjacent expert, which clamps its estimate into its own regime — the
//! mechanism behind Fig 4b's error-by-length profile. In-regime experts
//! are low-variance regressors whose σ tightens as regimes narrow.

use super::Predictor;
use crate::core::Request;
use crate::util::dist;
use crate::util::rng::Rng;

/// Configuration mirroring §6/§7.1.
#[derive(Debug, Clone)]
pub struct MopeConfig {
    /// Number of experts (paper evaluates 1, 3, 5; deploys 3).
    pub n_experts: usize,
    /// Router global top-1 accuracy (paper: ≈0.80 at 110k samples).
    pub router_accuracy: f64,
    /// In-regime expert log-noise σ at the 3-expert reference point;
    /// scaled by √(3/n) as regimes narrow/widen.
    pub expert_sigma: f64,
    /// Generation cap of the serving deployment (LMSYS arena ≈ 1k).
    pub max_tokens: u32,
}

impl Default for MopeConfig {
    fn default() -> Self {
        MopeConfig { n_experts: 3, router_accuracy: 0.80, expert_sigma: 0.16, max_tokens: 1024 }
    }
}

impl MopeConfig {
    /// Structural validation. The router model converts global accuracy
    /// into in-zone accuracy via `1 − (1 − acc)/ZONE_MASS`, which goes
    /// negative below `1 − ZONE_MASS` = 0.55 — the old code silently
    /// floored that to 0 (worse than random, masquerading as a valid
    /// configuration). Out-of-range accuracy is now a typed error.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_experts >= 1, "MoPE needs at least one expert");
        anyhow::ensure!(
            self.router_accuracy.is_finite()
                && (1.0 - ZONE_MASS..=1.0).contains(&self.router_accuracy),
            "router accuracy {} outside the model's valid range [{}, 1.0] \
             (in-zone accuracy would floor below random)",
            self.router_accuracy,
            1.0 - ZONE_MASS,
        );
        anyhow::ensure!(
            self.expert_sigma.is_finite() && self.expert_sigma > 0.0,
            "expert sigma {} must be finite and positive",
            self.expert_sigma
        );
        anyhow::ensure!(self.max_tokens >= 1, "max_tokens must be >= 1");
        Ok(())
    }

    /// Regime boundaries: output-length quantiles. For 3 experts these are
    /// the paper's <53 / 53–210 / >210 split; other counts use matched
    /// quantiles of the LMSYS-like distribution.
    pub fn boundaries(&self) -> Vec<u32> {
        match self.n_experts {
            0 | 1 => vec![],
            2 => vec![108],
            3 => vec![53, 210],
            4 => vec![40, 108, 300],
            5 => vec![30, 80, 160, 380],
            n => {
                // Geometric spacing as a fallback for ablations.
                let lo = 20.0f64;
                let hi = 800.0f64;
                (1..n)
                    .map(|i| (lo * (hi / lo).powf(i as f64 / n as f64)).round() as u32)
                    .collect()
            }
        }
    }

    /// Effective in-regime σ: a generic single model is far noisier; with
    /// more experts each regime is narrower and the regressor tighter.
    pub fn sigma_eff(&self) -> f64 {
        if self.n_experts <= 1 {
            0.60
        } else {
            self.expert_sigma * (3.0 / self.n_experts as f64).sqrt()
        }
    }

    /// Memory footprint estimate (Fig 7b): experts are BERT-base (110M
    /// params) in BF16 → ≈0.22 GB each, plus the shared router (~1 MB).
    pub fn memory_gb(&self) -> f64 {
        0.001 + self.n_experts as f64 * 0.22
    }

    /// End-to-end prediction latency (Fig 7d): router 0.02 ms + one expert
    /// forward ≈ 4.5 ms total, independent of expert count (only one
    /// expert runs per request).
    pub fn latency_s(&self) -> f64 {
        if self.n_experts <= 1 {
            0.00448
        } else {
            0.0045
        }
    }
}

/// Boundary-zone half-width in log space (× / ÷ 1.6 around a boundary).
const ZONE_LOG: f64 = 0.47; // ln(1.6)
/// Approximate probability mass inside the zones for the LMSYS-like
/// distribution with 2 boundaries; used to convert global accuracy into
/// in-zone accuracy.
const ZONE_MASS: f64 = 0.45;

#[derive(Debug)]
pub struct MoPE {
    pub config: MopeConfig,
    rng: Rng,
    boundaries: Vec<u32>,
    centroids: Vec<f64>,
}

impl MoPE {
    pub fn new(seed: u64) -> Self {
        Self::with_config(seed, MopeConfig::default())
    }

    /// Panicking constructor for static configurations; use
    /// [`MoPE::try_with_config`] when the config comes from user input.
    pub fn with_config(seed: u64, config: MopeConfig) -> Self {
        Self::try_with_config(seed, config).expect("invalid MoPE config")
    }

    pub fn try_with_config(seed: u64, config: MopeConfig) -> anyhow::Result<Self> {
        config.validate()?;
        let boundaries = config.boundaries();
        let centroids = Self::regime_centroids(&boundaries, config.max_tokens);
        Ok(MoPE { config, rng: Rng::new(seed), boundaries, centroids })
    }

    /// Geometric-mean centroid of each regime's range.
    fn regime_centroids(boundaries: &[u32], max_tokens: u32) -> Vec<f64> {
        let mut edges = vec![1.0f64];
        edges.extend(boundaries.iter().map(|&b| b as f64));
        edges.push(max_tokens as f64);
        edges.windows(2).map(|w| (w[0] * w[1]).sqrt()).collect()
    }

    /// True regime of an output length.
    pub fn regime_of(&self, out: u32) -> usize {
        self.boundaries.iter().position(|&b| out < b).unwrap_or(self.boundaries.len())
    }

    /// Route a request. Errors happen only in the log-space zone around
    /// the nearest boundary, with in-zone accuracy derived from the
    /// configured global accuracy.
    fn route(&mut self, true_out: u32) -> usize {
        let correct = self.regime_of(true_out);
        if self.boundaries.is_empty() {
            return correct;
        }
        let lt = (true_out.max(1) as f64).ln();
        let (dist_log, bi) = self
            .boundaries
            .iter()
            .enumerate()
            .map(|(i, &b)| ((lt - (b as f64).ln()).abs(), i))
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .unwrap();
        if dist_log >= ZONE_LOG {
            return correct;
        }
        // `MopeConfig::validate` guarantees accuracy ≥ 1 − ZONE_MASS,
        // so this is in [0, 1] by construction — no silent floor.
        let in_zone_acc = 1.0 - (1.0 - self.config.router_accuracy) / ZONE_MASS;
        if self.rng.chance(in_zone_acc) {
            correct
        } else if correct == bi {
            // Below boundary bi, flipped above it.
            bi + 1
        } else {
            bi
        }
    }

    fn regime_range(&self, regime: usize) -> (f64, f64) {
        let lo = if regime == 0 { 1.0 } else { self.boundaries[regime - 1] as f64 };
        let hi = if regime == self.boundaries.len() {
            self.config.max_tokens as f64
        } else {
            self.boundaries[regime] as f64
        };
        (lo, hi)
    }

    /// Empirical router accuracy over a sample of true lengths (used by
    /// the Fig 7c experiment).
    pub fn measure_router_accuracy(&mut self, sample: &[u32]) -> f64 {
        if sample.is_empty() {
            return 1.0;
        }
        let mut correct = 0usize;
        for &out in sample {
            if self.route(out) == self.regime_of(out) {
                correct += 1;
            }
        }
        correct as f64 / sample.len() as f64
    }
}

impl Predictor for MoPE {
    fn name(&self) -> &'static str {
        "mope"
    }

    fn predict_tokens(&mut self, req: &Request) -> u32 {
        let truth = req.true_output_tokens.max(1) as f64;
        let regime = self.route(req.true_output_tokens);
        let correct = self.regime_of(req.true_output_tokens) == regime;
        let pred = if correct {
            // Specialised expert: low-variance regression with mild
            // shrink toward its regime centroid.
            let mu = 0.95 * truth.ln() + 0.05 * self.centroids[regime].ln();
            (mu + dist::std_normal(&mut self.rng) * self.config.sigma_eff()).exp()
        } else {
            // Misrouted: the adjacent expert still sees length-correlated
            // features but clamps its estimate into its own regime.
            let (lo, hi) = self.regime_range(regime);
            (truth.ln() + dist::std_normal(&mut self.rng) * 0.3).exp().clamp(lo, hi)
        };
        (pred.round() as u32).clamp(1, self.config.max_tokens)
    }

    fn predict_cost(&self) -> f64 {
        self.config.latency_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, RequestId};
    use crate::util::rng::Rng;
    use crate::workload::tracegen::{LmsysLike, TraceGen};

    fn mae(n_experts: usize, router_acc: f64, n: usize, seed: u64) -> f64 {
        let gen = LmsysLike::default();
        let mut wrng = Rng::new(seed);
        let mut mope = MoPE::with_config(
            seed + 1,
            MopeConfig { n_experts, router_accuracy: router_acc, ..MopeConfig::default() },
        );
        let mut abs = 0.0;
        for i in 0..n {
            let (_, out) = gen.lengths(&mut wrng);
            let r = Request::new(RequestId(i as u64), ClientId(0), 50, out, 0.0);
            abs += (mope.predict_tokens(&r) as f64 - out as f64).abs();
        }
        abs / n as f64
    }

    /// Fig 7a: L1 error ≈ 33 with three experts.
    #[test]
    fn three_expert_l1_matches_paper() {
        let e = mae(3, 0.80, 20_000, 1);
        assert!((24.0..42.0).contains(&e), "3-expert MAE = {e}, want ≈33");
    }

    /// Fig 7a: one generic expert ≈ 80 — same level as the single proxy.
    #[test]
    fn one_expert_l1_matches_paper() {
        let e = mae(1, 0.80, 20_000, 4);
        assert!((60.0..105.0).contains(&e), "1-expert MAE = {e}, want ≈80");
    }

    /// Fig 7a: five experts ≈ 25, better than three.
    #[test]
    fn five_expert_beats_three() {
        let e3 = mae(3, 0.80, 30_000, 2);
        let e5 = mae(5, 0.80, 30_000, 2);
        assert!(e5 < e3, "e3={e3} e5={e5}");
        assert!((16.0..36.0).contains(&e5), "5-expert MAE = {e5}, want ≈25");
    }

    #[test]
    fn perfect_router_is_better() {
        let e80 = mae(3, 0.80, 10_000, 3);
        let e100 = mae(3, 1.0, 10_000, 3);
        assert!(e100 < e80, "e80={e80} e100={e100}");
    }

    /// Fig 7c: measured global router accuracy lands near the configured
    /// value on the LMSYS-like distribution.
    #[test]
    fn router_accuracy_calibrated() {
        let gen = LmsysLike::default();
        let mut wrng = Rng::new(5);
        let sample: Vec<u32> = (0..30_000).map(|_| gen.lengths(&mut wrng).1).collect();
        let mut mope = MoPE::new(6);
        let acc = mope.measure_router_accuracy(&sample);
        assert!((0.74..0.88).contains(&acc), "accuracy={acc}, want ≈0.80");
    }

    #[test]
    fn regime_boundaries_match_paper() {
        let m = MoPE::new(1);
        assert_eq!(m.regime_of(52), 0);
        assert_eq!(m.regime_of(53), 1);
        assert_eq!(m.regime_of(209), 1);
        assert_eq!(m.regime_of(210), 2);
        assert_eq!(m.regime_of(1000), 2);
    }

    #[test]
    fn memory_grows_with_experts() {
        let m1 = MopeConfig { n_experts: 1, ..MopeConfig::default() }.memory_gb();
        let m3 = MopeConfig::default().memory_gb();
        let m5 = MopeConfig { n_experts: 5, ..MopeConfig::default() }.memory_gb();
        assert!(m1 < m3 && m3 < m5);
    }

    #[test]
    fn overhead_is_sub_5ms() {
        let m = MoPE::new(1);
        assert!(m.predict_cost() < 0.005);
    }

    #[test]
    fn config_validation_rejects_out_of_range_accuracy() {
        assert!(MopeConfig::default().validate().is_ok());
        let low = MopeConfig { router_accuracy: 0.50, ..MopeConfig::default() };
        let err = low.validate().unwrap_err().to_string();
        assert!(err.contains("router accuracy"), "unexpected error: {err}");
        assert!(MoPE::try_with_config(1, low).is_err());
        let edge = MopeConfig { router_accuracy: 1.0 - ZONE_MASS, ..MopeConfig::default() };
        assert!(edge.validate().is_ok(), "boundary accuracy must be accepted");
        for bad in [
            MopeConfig { n_experts: 0, ..MopeConfig::default() },
            MopeConfig { router_accuracy: f64::NAN, ..MopeConfig::default() },
            MopeConfig { router_accuracy: 1.1, ..MopeConfig::default() },
            MopeConfig { expert_sigma: 0.0, ..MopeConfig::default() },
            MopeConfig { max_tokens: 0, ..MopeConfig::default() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn predictions_in_bounds() {
        let mut m = MoPE::new(9);
        for out in [1u32, 53, 210, 512, 1024] {
            for _ in 0..200 {
                let r = Request::new(RequestId(0), ClientId(0), 10, out, 0.0);
                let p = m.predict_tokens(&r);
                assert!(p >= 1 && p <= 1024);
            }
        }
    }
}
