//! Prediction framework (§6): estimating output tokens and mapping them to
//! the latency / GPU-utilization / throughput components that UFC and RFC
//! need *before* execution — the paper's answer to the scheduling paradox.
//!
//! Three predictors ship, matching §7.4's ablation: `Oracle` (perfect),
//! `SingleProxy` (one generic proxy model, L1 ≈ 80 tokens on LMSYS-like
//! workloads) and `MoPE` (router + specialised experts, L1 ≈ 33 with three
//! experts). The rust-side predictors are *error models*: deterministic,
//! seeded reproductions of the accuracy the paper measures for each
//! approach, so the scheduler ablation sees the same information quality.
//! The real BERT-regressor path is the AOT-compiled JAX expert in
//! `runtime::mope` (used by the serving binary, not the simulator).

pub mod degrade;
pub mod mope;
pub mod oracle;
pub mod perfmap;
pub mod single;

pub use degrade::{DegradedPredictor, PredFault, PredFaultPlan};
pub use mope::{MoPE, MopeConfig};
pub use oracle::Oracle;
pub use perfmap::PerfMap;
pub use single::SingleProxy;

use crate::core::Request;

/// Per-request predictions attached at arrival (Algorithm 1 lines 4–5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    pub output_tokens: u32,
    /// Expected GPU inference duration once execution begins (s).
    pub latency: f64,
    /// Expected GPU utilization during this request's service, 0..1.
    pub gpu_util: f64,
    /// Expected throughput contribution (tokens/s).
    pub tps: f64,
}

/// Output-token predictor interface. `predict` must not read
/// `req.true_output_tokens` except through its own error model (the
/// `Oracle` is the one legitimate exception).
pub trait Predictor: Send {
    fn name(&self) -> &'static str;

    /// Estimate the output length for a request.
    fn predict_tokens(&mut self, req: &Request) -> u32;

    /// Model inference cost of one prediction (s) — MoPE's §6 overhead
    /// accounting (router 0.02 ms + expert forward ≈ 4.5 ms total).
    fn predict_cost(&self) -> f64 {
        0.0
    }

    /// Feedback after completion (Algorithm 1 line 20) for predictors that
    /// calibrate online. Default: no-op.
    fn observe(&mut self, _req: &Request, _actual_output: u32) {}
}

/// Attach a full `Prediction` to a request: token estimate from the
/// predictor, metric estimates from the historical `PerfMap`.
pub fn predict_request(
    predictor: &mut dyn Predictor,
    perfmap: &PerfMap,
    req: &mut Request,
) -> Prediction {
    let tokens = predictor.predict_tokens(req);
    let mapped = perfmap.map(req.input_tokens, tokens);
    req.predicted_output_tokens = tokens;
    req.predicted_latency = mapped.latency;
    req.predicted_gpu_util = mapped.gpu_util;
    req.predicted_tps = mapped.tps;
    Prediction { output_tokens: tokens, latency: mapped.latency, gpu_util: mapped.gpu_util, tps: mapped.tps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, RequestId};

    #[test]
    fn predict_request_fills_fields() {
        let mut oracle = Oracle::new();
        let pm = PerfMap::default_a100_7b();
        let mut req = Request::new(RequestId(1), ClientId(0), 100, 400, 0.0);
        let p = predict_request(&mut oracle, &pm, &mut req);
        assert_eq!(p.output_tokens, 400);
        assert_eq!(req.predicted_output_tokens, 400);
        assert!(req.predicted_latency > 0.0);
        assert!(req.predicted_tps > 0.0);
        assert!(req.predicted_gpu_util > 0.0 && req.predicted_gpu_util <= 1.0);
    }
}
