//! Perfect predictor — the ideal benchmark in §7.4's ablation
//! ("Equinox + Oracle" / "VTC + Oracle" rows of Table 1).

use super::Predictor;
use crate::core::Request;

#[derive(Debug, Default)]
pub struct Oracle;

impl Oracle {
    pub fn new() -> Self {
        Oracle
    }
}

impl Predictor for Oracle {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn predict_tokens(&mut self, req: &Request) -> u32 {
        req.true_output_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, RequestId};

    #[test]
    fn oracle_is_exact() {
        let mut o = Oracle::new();
        for out in [1u32, 53, 210, 1800] {
            let r = Request::new(RequestId(0), ClientId(0), 10, out, 0.0);
            assert_eq!(o.predict_tokens(&r), out);
        }
    }

    #[test]
    fn oracle_costs_nothing() {
        assert_eq!(Oracle::new().predict_cost(), 0.0);
    }
}
