//! The historical token→metric mapping (§6, last paragraph): given a
//! predicted output length, estimate user-perceived latency, GPU
//! utilization, and throughput — the remaining three quarters of the
//! holistic-fairness inputs. Seeded from offline profiling (Fig 2's
//! curves) and recalibrated online from observed batch actuals
//! (Algorithm 1 line 20), following the roofline-driven method of
//! Imai et al. that the paper cites.

use std::collections::BTreeMap;

/// Metric estimates for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MappedMetrics {
    pub latency: f64,
    pub gpu_util: f64,
    pub tps: f64,
}

/// Piecewise-log-bucketed mapping from total tokens to metrics with
/// exponential-moving-average online updates.
#[derive(Debug, Clone)]
pub struct PerfMap {
    /// bucket upper edge (tokens) → metrics.
    buckets: BTreeMap<u32, MappedMetrics>,
    /// EMA factor for online recalibration.
    ema: f64,
}

impl PerfMap {
    /// Offline-profiled map for an A100-80GB running Llama-2-7b, derived
    /// from the same roofline model the simulator uses (sim::gpu). The
    /// latency column is dominated by decode (0.9+ of e2e, Fig 2a); TPS
    /// peaks near 1k tokens then declines (Fig 2b); util steps up with
    /// request length as batch refreshes amortise (Fig 2c).
    pub fn default_a100_7b() -> PerfMap {
        let mut buckets = BTreeMap::new();
        // (edge_tokens, latency_s, util, tps)
        for (edge, lat, util, tps) in [
            (64u32, 0.35, 0.55, 900.0),
            (128, 0.7, 0.62, 1300.0),
            (256, 1.4, 0.70, 1800.0),
            (512, 2.8, 0.78, 2300.0),
            (1024, 5.6, 0.86, 2600.0),
            (2048, 11.5, 0.92, 2300.0),
            (4096, 24.0, 0.95, 1800.0),
            (u32::MAX, 50.0, 0.96, 1400.0),
        ] {
            buckets.insert(edge, MappedMetrics { latency: lat, gpu_util: util, tps });
        }
        PerfMap { buckets, ema: 0.05 }
    }

    /// Per-replica map selection for heterogeneous fleets: the profiled
    /// A100-80GB reference, rescaled by the replica's sustained decode
    /// throughput relative to that reference (decode dominates e2e, Fig
    /// 2a, and is bandwidth-bound — so an A100-40GB at ~76% of the 80GB's
    /// HBM bandwidth serves ~1.3× slower per token). For the reference
    /// GPU itself the ratio is exactly 1.0 and the profile is returned
    /// unchanged — which is what keeps a 1×A100-80GB cluster bit-identical
    /// to the plain single-engine run.
    pub fn for_gpu(gpu: &crate::sim::GpuModel) -> PerfMap {
        let mut pm = Self::default_a100_7b();
        let reference = crate::sim::GpuModel::a100_7b();
        let scale = reference.peak_decode_tps(64, 512) / gpu.peak_decode_tps(64, 512);
        if scale == 1.0 {
            return pm;
        }
        for m in pm.buckets.values_mut() {
            m.latency *= scale;
            m.tps /= scale;
        }
        pm
    }

    /// A deliberately stale map (scaled metrics) for testing the online
    /// feedback loop's convergence.
    pub fn stale(scale: f64) -> PerfMap {
        let mut pm = Self::default_a100_7b();
        for m in pm.buckets.values_mut() {
            m.latency *= scale;
            m.tps /= scale;
        }
        pm
    }

    fn bucket_mut(&mut self, tokens: u32) -> &mut MappedMetrics {
        let key = *self
            .buckets
            .range(tokens..)
            .next()
            .map(|(k, _)| k)
            .unwrap_or(&u32::MAX);
        self.buckets.get_mut(&key).unwrap()
    }

    /// Estimate metrics for a request with `input` prompt tokens and
    /// `output` predicted output tokens.
    pub fn map(&self, input: u32, output: u32) -> MappedMetrics {
        let total = input.saturating_add(output.saturating_mul(4)); // decode-weighted
        let (_, m) = self
            .buckets
            .range(total..)
            .next()
            .map(|(k, v)| (*k, *v))
            .unwrap_or((u32::MAX, *self.buckets.values().last().unwrap()));
        m
    }

    /// Online recalibration with an observed (input, output, actuals)
    /// triple. EMA toward the observation.
    pub fn observe(&mut self, input: u32, output: u32, actual: MappedMetrics) {
        let total = input.saturating_add(output.saturating_mul(4));
        let ema = self.ema;
        let m = self.bucket_mut(total);
        m.latency += ema * (actual.latency - m.latency);
        m.gpu_util += ema * (actual.gpu_util - m.gpu_util);
        m.tps += ema * (actual.tps - m.tps);
    }

    /// Number of buckets (for tests / introspection).
    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_monotone_in_tokens() {
        let pm = PerfMap::default_a100_7b();
        let mut prev = 0.0;
        for out in [10u32, 50, 100, 300, 700, 1500] {
            let m = pm.map(50, out);
            assert!(m.latency >= prev, "latency not monotone at {out}");
            prev = m.latency;
        }
    }

    #[test]
    fn tps_is_non_monotone_peaking_mid() {
        // Fig 2b: throughput rises then falls past ~1k tokens.
        let pm = PerfMap::default_a100_7b();
        let small = pm.map(32, 16).tps;
        let mid = pm.map(128, 200).tps;
        let large = pm.map(512, 900).tps;
        assert!(mid > small, "mid={mid} small={small}");
        assert!(large < mid, "large={large} mid={mid}");
    }

    #[test]
    fn util_increases_with_length() {
        let pm = PerfMap::default_a100_7b();
        assert!(pm.map(16, 8).gpu_util < pm.map(512, 512).gpu_util);
    }

    #[test]
    fn observe_converges_stale_map() {
        let mut pm = PerfMap::stale(3.0);
        let truth = PerfMap::default_a100_7b().map(100, 100);
        let before = (pm.map(100, 100).latency - truth.latency).abs();
        for _ in 0..200 {
            pm.observe(100, 100, truth);
        }
        let after = (pm.map(100, 100).latency - truth.latency).abs();
        assert!(after < before / 10.0, "before={before} after={after}");
    }

    #[test]
    fn for_gpu_is_identity_on_the_reference_and_scales_slower_parts() {
        use crate::sim::{GpuKind, GpuModel, ModelSpec};
        // Reference GPU: bit-identical to the profiled default.
        let reference = PerfMap::for_gpu(&GpuModel::a100_7b());
        let default = PerfMap::default_a100_7b();
        for (inp, out) in [(50u32, 100u32), (512, 512), (16, 2000)] {
            let a = reference.map(inp, out);
            let b = default.map(inp, out);
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
            assert_eq!(a.tps.to_bits(), b.tps.to_bits());
        }
        // A100-40GB: lower HBM bandwidth → higher latency, lower TPS.
        let slow = PerfMap::for_gpu(&GpuModel::new(GpuKind::A100_40G, ModelSpec::LLAMA2_7B, 1));
        let (a, b) = (slow.map(100, 200), default.map(100, 200));
        assert!(a.latency > b.latency, "{} vs {}", a.latency, b.latency);
        assert!(a.tps < b.tps);
    }

    #[test]
    fn map_handles_extremes() {
        let pm = PerfMap::default_a100_7b();
        let m = pm.map(u32::MAX, u32::MAX);
        assert!(m.latency > 0.0 && m.tps > 0.0);
        let m0 = pm.map(0, 0);
        assert!(m0.latency > 0.0);
    }
}
