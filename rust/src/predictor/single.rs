//! Single generic proxy model baseline (§2.2, Fig 4): one BERT-style
//! regressor trained across all data. The paper measures L1 ≈ 80 tokens on
//! LMSYS-like traffic, with strong regression-to-the-mean — absolute error
//! compounds on long outputs (Fig 4b). This error model reproduces those
//! statistics deterministically from a seed.

use super::Predictor;
use crate::core::Request;
use crate::util::dist;
use crate::util::rng::Rng;

#[derive(Debug)]
pub struct SingleProxy {
    rng: Rng,
    /// Pull toward the corpus median: pred_log = shrink·true_log +
    /// (1-shrink)·log(median). A single model underfits the regimes, so
    /// shrink well below 1.
    shrink: f64,
    corpus_median: f64,
    /// Log-space noise σ.
    sigma: f64,
    max_tokens: u32,
}

impl SingleProxy {
    pub fn new(seed: u64) -> Self {
        // Calibrated so that mean |pred - true| ≈ 80 on the LmsysLike
        // distribution (see tests + fig4 experiment).
        SingleProxy { rng: Rng::new(seed), shrink: 0.80, corpus_median: 108.0, sigma: 0.35, max_tokens: 1024 }
    }

    /// Accessor for experiments that vary the error level.
    pub fn with_params(seed: u64, shrink: f64, sigma: f64) -> Self {
        SingleProxy { rng: Rng::new(seed), shrink, corpus_median: 108.0, sigma, max_tokens: 1024 }
    }
}

impl Predictor for SingleProxy {
    fn name(&self) -> &'static str {
        "single"
    }

    fn predict_tokens(&mut self, req: &Request) -> u32 {
        let truth = req.true_output_tokens.max(1) as f64;
        let mu = self.shrink * truth.ln() + (1.0 - self.shrink) * self.corpus_median.ln();
        let noise = dist::std_normal(&mut self.rng) * self.sigma;
        let pred = (mu + noise).exp();
        (pred.round() as u32).clamp(1, self.max_tokens)
    }

    /// §6 Fig 7d: proxy forward pass ≈ 4.5 ms.
    fn predict_cost(&self) -> f64 {
        0.0045
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, RequestId};
    use crate::util::rng::Rng;
    use crate::workload::tracegen::{LmsysLike, TraceGen};

    /// Mean absolute error over the LMSYS-like output distribution —
    /// the paper's headline "L1 prediction error 80" for a single model.
    #[test]
    fn l1_error_matches_paper_band() {
        let gen = LmsysLike::default();
        let mut wrng = Rng::new(1);
        let mut proxy = SingleProxy::new(2);
        let n = 20_000;
        let mut abs = 0.0;
        for i in 0..n {
            let (_, out) = gen.lengths(&mut wrng);
            let r = Request::new(RequestId(i), ClientId(0), 50, out, 0.0);
            let p = proxy.predict_tokens(&r);
            abs += (p as f64 - out as f64).abs();
        }
        let mae = abs / n as f64;
        assert!((60.0..100.0).contains(&mae), "single-proxy MAE = {mae}, want ≈80");
    }

    /// Fig 4b: absolute error grows sharply with true output length.
    #[test]
    fn error_compounds_on_long_outputs() {
        let mut proxy = SingleProxy::new(3);
        let mae_at = |truth: u32, proxy: &mut SingleProxy| {
            let n = 4_000;
            let mut abs = 0.0;
            for i in 0..n {
                let r = Request::new(RequestId(i), ClientId(0), 50, truth, 0.0);
                abs += (proxy.predict_tokens(&r) as f64 - truth as f64).abs();
            }
            abs / n as f64
        };
        let short = mae_at(30, &mut proxy);
        let long = mae_at(800, &mut proxy);
        assert!(long > 5.0 * short, "short={short} long={long}");
    }

    #[test]
    fn predictions_bounded() {
        let mut proxy = SingleProxy::new(4);
        for _ in 0..1_000 {
            let r = Request::new(RequestId(0), ClientId(0), 10, 1024, 0.0);
            let p = proxy.predict_tokens(&r);
            assert!(p >= 1 && p <= 1024);
        }
    }
}
