//! Configuration system: declarative experiment/serving configs in a
//! simple `key = value` format with `[section]`s (a TOML subset — the
//! offline registry ships no toml crate). This is what makes the
//! framework deployable beyond the built-in paper scenarios: operators
//! describe their workload, hardware and policy in a file and run
//! `equinox simulate --config my.eqx.toml`.

pub mod file;
pub mod spec;

pub use file::ConfigFile;
pub use spec::SimulateSpec;
