//! Minimal INI/TOML-subset parser: `[section]` headers, `key = value`
//! pairs, `#` comments, repeated sections allowed (e.g. one `[[client]]`
//! per tenant). Values: strings (quoted or bare), numbers, booleans.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_f64().map(|x| x as u32)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// One `[section]` instance.
#[derive(Debug, Clone, Default)]
pub struct Section {
    pub name: String,
    pub entries: BTreeMap<String, Value>,
}

impl Section {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn num(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }
}

/// A parsed config file: ordered list of sections. Keys before any
/// section header land in an implicit "" section.
#[derive(Debug, Clone, Default)]
pub struct ConfigFile {
    pub sections: Vec<Section>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<ConfigFile, String> {
        let mut sections = vec![Section { name: String::new(), entries: BTreeMap::new() }];
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                let name = line
                    .trim_start_matches('[')
                    .trim_end_matches(']')
                    .trim_matches('[')
                    .trim_matches(']')
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                sections.push(Section { name: name.to_string(), entries: BTreeMap::new() });
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {}: expected 'key = value'", lineno + 1));
            };
            let key = k.trim().to_string();
            let value = parse_value(v.trim());
            sections.last_mut().unwrap().entries.insert(key, value);
        }
        Ok(ConfigFile { sections })
    }

    pub fn load(path: &std::path::Path) -> Result<ConfigFile, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// First section with this name.
    pub fn section(&self, name: &str) -> Option<&Section> {
        self.sections.iter().find(|s| s.name == name)
    }

    /// All sections with this name (e.g. repeated `[client]`).
    pub fn all(&self, name: &str) -> Vec<&Section> {
        self.sections.iter().filter(|s| s.name == name).collect()
    }
}

fn parse_value(v: &str) -> Value {
    if let Some(stripped) = v.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Value::Str(stripped.to_string());
    }
    match v {
        "true" => return Value::Bool(true),
        "false" => return Value::Bool(false),
        _ => {}
    }
    if let Ok(x) = v.parse::<f64>() {
        return Value::Num(x);
    }
    Value::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a comment
seed = 7
name = "balanced"

[gpu]
kind = a100-80
tp = 2

[client]
rate = 2.0
input = 100
output = 400

[client]
rate = 1.0   # trailing comment
input = 100
output = 900
poisson = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(cfg.sections[0].num("seed", 0.0), 7.0);
        assert_eq!(cfg.sections[0].str_or("name", ""), "balanced");
        assert_eq!(cfg.section("gpu").unwrap().num("tp", 1.0), 2.0);
        assert_eq!(cfg.section("gpu").unwrap().str_or("kind", ""), "a100-80");
        let clients = cfg.all("client");
        assert_eq!(clients.len(), 2);
        assert_eq!(clients[1].num("output", 0.0), 900.0);
        assert_eq!(clients[1].get("poisson").unwrap().as_bool(), Some(true));
        assert!(clients[0].get("poisson").is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ConfigFile::parse("no equals here").is_err());
        assert!(ConfigFile::parse("[]").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let cfg = ConfigFile::parse("# only comments\n\n   \n").unwrap();
        assert_eq!(cfg.sections.len(), 1);
    }
}
