//! Typed simulation spec assembled from a ConfigFile: hardware, host,
//! scheduler/predictor policy, and the tenant workload — everything
//! `equinox simulate` needs.

use super::file::ConfigFile;
use crate::exp::{PredKind, SchedKind};
use crate::sim::{GpuKind, GpuModel, HostProfile, ModelSpec, SimConfig};
use crate::workload::arrivals::ArrivalProcess;
use crate::workload::{Arrival, ClientSpec, Scenario};

/// A fully resolved simulation run description.
#[derive(Debug, Clone)]
pub struct SimulateSpec {
    pub name: String,
    pub seed: u64,
    pub sim: SimConfig,
    pub scenario: Scenario,
    pub scheduler: SchedKind,
    pub predictor: PredKind,
}

impl SimulateSpec {
    pub fn from_config(cfg: &ConfigFile) -> Result<SimulateSpec, String> {
        let root = &cfg.sections[0];
        let name = root.str_or("name", "custom").to_string();
        let seed = root.num("seed", 42.0) as u64;
        let duration = root.num("duration", 120.0);

        // [gpu]
        let (gpu_kind, tp, model) = match cfg.section("gpu") {
            Some(g) => {
                let kind = match g.str_or("kind", "a100-80") {
                    "a100-80" => GpuKind::A100_80G,
                    "a100-40" => GpuKind::A100_40G,
                    other => return Err(format!("unknown gpu.kind '{other}'")),
                };
                let model = match g.str_or("model", "llama-2-7b") {
                    "llama-2-7b" => ModelSpec::LLAMA2_7B,
                    "llama-2-70b" => ModelSpec::LLAMA2_70B,
                    other => return Err(format!("unknown gpu.model '{other}'")),
                };
                (kind, g.num("tp", 1.0) as u32, model)
            }
            None => (GpuKind::A100_80G, 1, ModelSpec::LLAMA2_7B),
        };

        // [host]
        let host_name = cfg
            .section("host")
            .map(|h| h.str_or("profile", "vllm").to_string())
            .unwrap_or_else(|| "vllm".to_string());
        let host = HostProfile::by_name(&host_name)
            .ok_or_else(|| format!("unknown host.profile '{host_name}'"))?;

        // [policy]
        let (scheduler, predictor) = match cfg.section("policy") {
            Some(p) => {
                let sched = match p.str_or("scheduler", "equinox") {
                    "fcfs" => SchedKind::Fcfs,
                    "rpm" => SchedKind::Rpm,
                    "vtc" => SchedKind::Vtc,
                    "vtc+pred" => SchedKind::VtcPred,
                    "equinox" => {
                        let alpha = p.num("alpha", 0.7);
                        if (alpha - 0.7).abs() < 1e-9 {
                            SchedKind::Equinox
                        } else {
                            SchedKind::EquinoxAlpha(alpha)
                        }
                    }
                    other => return Err(format!("unknown policy.scheduler '{other}'")),
                };
                let pred = match p.str_or("predictor", "mope") {
                    "oracle" => PredKind::Oracle,
                    "single" => PredKind::Single,
                    "mope" => PredKind::Mope,
                    other => return Err(format!("unknown policy.predictor '{other}'")),
                };
                (sched, pred)
            }
            None => (SchedKind::Equinox, PredKind::Mope),
        };

        // [client] sections → scenario.
        let mut clients = Vec::new();
        for c in cfg.all("client") {
            let arrival = if c.get("poisson").and_then(|v| v.as_bool()).unwrap_or(false) {
                Arrival::Poisson
            } else {
                Arrival::Deterministic
            };
            let rate = c.num("rate", 1.0);
            // Optional rate step at a switch time.
            let process = match (c.get("rate_after"), c.get("rate_switch_at")) {
                (Some(after), Some(at)) => ArrivalProcess::Step {
                    before: rate,
                    after: after.as_f64().unwrap_or(rate),
                    at: at.as_f64().unwrap_or(duration / 2.0),
                },
                _ => ArrivalProcess::Constant(rate),
            };
            let mut spec = ClientSpec::fixed(
                arrival,
                process,
                c.num("input", 128.0) as u32,
                c.num("output", 128.0) as u32,
            );
            spec.length_jitter = c.num("jitter", 1.0);
            spec.weight = c.num("weight", 1.0);
            clients.push(spec);
        }
        if clients.is_empty() {
            return Err("config needs at least one [client] section".into());
        }

        let sim = SimConfig::a100_7b_vllm()
            .with_gpu(GpuModel::new(gpu_kind, model, tp.max(1)))
            .with_host(host);
        Ok(SimulateSpec {
            name,
            seed,
            sim,
            scenario: Scenario { name: "config", clients, duration },
            scheduler,
            predictor,
        })
    }

    /// Run the spec and return the result.
    pub fn run(&self) -> crate::sim::SimResult {
        let trace = crate::workload::generate(&self.scenario, self.seed);
        crate::exp::run_sim(&self.sim, self.scheduler, self.predictor, &trace, self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
name = "two-tenant overload"
seed = 9
duration = 20

[gpu]
kind = a100-80
model = llama-2-7b
tp = 1

[host]
profile = slora

[policy]
scheduler = equinox
predictor = mope

[client]
rate = 20
input = 20
output = 180

[client]
rate = 2
input = 200
output = 1800
poisson = true
"#;

    #[test]
    fn builds_and_runs_from_config() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        let spec = SimulateSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.scenario.clients.len(), 2);
        assert_eq!(spec.sim.host.name, "slora");
        assert_eq!(spec.scheduler, SchedKind::Equinox);
        let res = spec.run();
        assert!(res.finished > 0);
        assert_eq!(res.finished, res.total_requests);
    }

    #[test]
    fn rejects_unknown_enum_values() {
        let bad = SAMPLE.replace("profile = slora", "profile = triton");
        let cfg = ConfigFile::parse(&bad).unwrap();
        assert!(SimulateSpec::from_config(&cfg).is_err());
    }

    #[test]
    fn alpha_override_selects_variant() {
        let tweaked = SAMPLE.replace("scheduler = equinox", "scheduler = equinox\nalpha = 0.5");
        let cfg = ConfigFile::parse(&tweaked).unwrap();
        let spec = SimulateSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.scheduler, SchedKind::EquinoxAlpha(0.5));
    }

    #[test]
    fn defaults_without_sections() {
        let cfg = ConfigFile::parse("[client]\nrate = 1\n").unwrap();
        let spec = SimulateSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.scheduler, SchedKind::Equinox);
        assert_eq!(spec.sim.host.name, "vllm");
    }
}
