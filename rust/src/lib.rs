//! Equinox: holistic fair scheduling for LLM serving.
//!
//! Reproduction of "Equinox: Holistic Fair Scheduling in Serving Large
//! Language Models" (CS.DC 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer 3 (this crate): the paper's coordination contribution — request
//! frontend, per-client queues, the dual-counter (UFC/RFC) holistic-fairness
//! scheduler, continuous batcher, KV-cache manager, and the FCFS/VTC/RPM
//! baselines, plus a calibrated A100 discrete-event GPU simulator used to
//! regenerate every table and figure of the paper's evaluation.
//!
//! Layer 2/1 (build-time Python, never on the request path): a small
//! transformer LM whose attention hot-spot is a Pallas kernel; lowered via
//! `python/compile/aot.py` to HLO text artifacts that `runtime/` loads and
//! executes through the PJRT CPU client.

// Style lints the codebase deliberately trades away: index-based loops
// where parallel mutation of `running` slots needs them, and the wide
// counter-correction signatures that mirror Algorithm 1's parameter
// list. Correctness lints stay on (CI runs `clippy -- -D warnings`).
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod cluster;
pub mod config;
pub mod core;
pub mod exp;
pub mod harness;
pub mod kv;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod metrics;
pub mod predictor;
pub mod sched;
pub mod sim;
pub mod util;
pub mod workload;
