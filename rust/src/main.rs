//! Equinox CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   exp <id>|all [--quick] [--seed N]   regenerate a paper table/figure
//!   list                                list available experiments
//!   serve [--addr A] [--artifacts DIR]  HTTP frontend over TinyLM
//!   generate --prompt "..." [...]       one-shot generation
//!   info                                runtime/platform diagnostics

use equinox::core::ClientId;
use equinox::exp::{self, ExpOpts};
use equinox::server::http::{HttpResponse, HttpServer};
use equinox::server::service::{ServeService, ServiceConfig};
use equinox::util::json::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("exp") => cmd_exp(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("conformance") => cmd_conformance(&args[1..]),
        Some("cluster") => cmd_cluster(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("chaos") => cmd_chaos(&args[1..]),
        Some("autoscale") => cmd_autoscale(&args[1..]),
        Some("mispredict") => cmd_mispredict(&args[1..]),
        Some("list") => cmd_list(),
        Some("serve") => cmd_serve(&args[1..]),
        Some("generate") => cmd_generate(&args[1..]),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "equinox — holistic fair scheduling for LLM serving\n\n\
                 usage:\n  equinox list\n  equinox exp <id>|all [--quick] [--seed N]\n  \
                 equinox simulate --config <file.eqx.toml>\n  \
                 equinox conformance [--quick] [--seed N] [--json FILE] [--golden FILE] [--regen]\n  \
                 equinox cluster [--matrix] [--fleet solo|homo4|hetero|skewed3] \
[--router round_robin|jsq|predicted_cost|fair_share] [--scenario NAME] [--sync S] \
[--drive serial|parallel] [--threads N] [--quick] [--seed N] [--json FILE]\n  \
                 equinox trace [--scenario NAME] [--fleet solo|homo4|hetero|skewed3] \
[--router round_robin|jsq|predicted_cost|fair_share] [--drive serial|parallel] [--threads N] \
[--quick] [--seed N] [--out FILE] [--format perfetto|jsonl] [--explain REQUEST]\n  \
                 equinox chaos [--quick] [--seed N] [--drive serial|parallel] [--threads N] [--json FILE]\n  \
                 equinox autoscale [--quick] [--seed N] [--drive serial|parallel] [--threads N] [--json FILE]\n  \
                 equinox mispredict [--quick] [--seed N] [--drive serial|parallel] [--threads N] [--json FILE]\n  \
                 equinox serve [--addr 127.0.0.1:8090] [--artifacts artifacts]\n  \
                 equinox generate --prompt \"...\" [--max-tokens 32] [--client 0] [--artifacts artifacts]\n  \
                 equinox info"
            );
            2
        }
    };
    std::process::exit(code);
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

/// Strict flag parsing: an absent flag takes the default, but a present
/// flag that doesn't parse is a usage error — never a silent fallback
/// (`--sync bogus` must not quietly run with 1.0s).
fn parse_flag<T: std::str::FromStr>(args: &[String], name: &str, default: T) -> Result<T, String> {
    match flag_value(args, name) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| format!("invalid value '{v}' for {name} (expected {})", std::any::type_name::<T>())),
    }
}

fn cmd_list() -> i32 {
    println!("{:<8} paper artifact", "id");
    for e in exp::registry() {
        println!("{:<8} {}", e.id, e.paper_ref);
    }
    0
}

fn cmd_exp(args: &[String]) -> i32 {
    let Some(id) = args.first() else {
        eprintln!("usage: equinox exp <id>|all [--quick] [--seed N]");
        return 2;
    };
    let opts = ExpOpts {
        quick: args.iter().any(|a| a == "--quick"),
        seed: flag_value(args, "--seed").and_then(|v| v.parse().ok()).unwrap_or(42),
    };
    let run_one = |e: &exp::Experiment| {
        println!("=== {} — {} ===", e.id, e.paper_ref);
        let t = std::time::Instant::now();
        println!("{}", (e.run)(&opts));
        println!("[{} completed in {:.1}s]\n", e.id, t.elapsed().as_secs_f64());
    };
    if id == "all" {
        for e in exp::registry() {
            run_one(&e);
        }
        0
    } else if let Some(e) = exp::find(id) {
        run_one(&e);
        0
    } else {
        eprintln!("unknown experiment '{id}' — try `equinox list`");
        2
    }
}

/// Run the scheduler × scenario × step-mode conformance matrix, write
/// the JSON verdicts, and optionally diff/regenerate the golden
/// snapshot. Exit code 1 when any cell violates a hard invariant, or on
/// a golden mismatch without `--regen`.
fn cmd_conformance(args: &[String]) -> i32 {
    use equinox::harness::{self, ConformanceOpts};

    let opts = ConformanceOpts {
        quick: args.iter().any(|a| a == "--quick"),
        base_seed: flag_value(args, "--seed").and_then(|v| v.parse().ok()).unwrap_or(42),
        ..ConformanceOpts::default()
    };
    let t = std::time::Instant::now();
    let cells = harness::run_matrix(&opts, &harness::MODES);
    let failed: Vec<_> = cells.iter().filter(|c| !c.passed()).collect();
    println!(
        "conformance: {} cells ({} scenarios × {} schedulers × {} modes) in {:.1}s — {} failed",
        cells.len(),
        equinox::workload::adversarial::registry().len(),
        harness::SCHEDULERS.len(),
        harness::MODES.len(),
        t.elapsed().as_secs_f64(),
        failed.len()
    );
    for c in &failed {
        println!("  FAIL {}: {}", c.key(), c.violations.join("; "));
    }

    if let Some(path) = flag_value(args, "--json") {
        let doc = harness::matrix_to_json(&opts, &cells);
        if let Err(e) = std::fs::write(path, doc.to_string()) {
            eprintln!("cannot write verdicts to {path}: {e}");
            return 1;
        }
        println!("verdicts written to {path}");
    }

    let mut golden_mismatch = false;
    if let Some(path) = flag_value(args, "--golden") {
        let regen = args.iter().any(|a| a == "--regen");
        match std::fs::read_to_string(path) {
            Ok(text) => match Json::parse(&text) {
                Ok(golden) => {
                    let diffs = harness::compare_golden(&golden, &cells);
                    if diffs.is_empty() {
                        println!("golden {path}: clean");
                    } else {
                        golden_mismatch = !regen;
                        println!("golden {path}: {} mismatches", diffs.len());
                        for d in &diffs {
                            println!("  {d}");
                        }
                    }
                }
                Err(e) => {
                    eprintln!("golden {path}: unparseable ({e})");
                    golden_mismatch = !regen;
                }
            },
            Err(_) => println!("golden {path}: absent (run with --regen to create)"),
        }
        if regen {
            // Never pin a violating run as the reference — the test-side
            // GOLDEN_REGEN path gates the same way.
            if failed.is_empty() {
                let doc = harness::golden_from_cells(&cells);
                if let Some(dir) = std::path::Path::new(path).parent() {
                    let _ = std::fs::create_dir_all(dir);
                }
                if let Err(e) = std::fs::write(path, doc.to_string()) {
                    eprintln!("cannot write golden to {path}: {e}");
                    return 1;
                }
                println!("golden regenerated at {path}");
            } else {
                eprintln!(
                    "refusing to regenerate golden: {} cells failed hard invariants",
                    failed.len()
                );
            }
        }
    }

    if !failed.is_empty() || golden_mismatch {
        1
    } else {
        0
    }
}

/// Run one cluster cell (or, with `--matrix`, the whole cluster
/// conformance matrix) and print the global rollups. Exit code 1 when
/// any matrix cell violates a hard invariant.
fn cmd_cluster(args: &[String]) -> i32 {
    use equinox::cluster::{run_cluster, ClusterOpts, DriveMode, Fleet, RouterKind};
    use equinox::exp::{PredKind, SchedKind};
    use equinox::harness::cluster::{
        cluster_matrix_to_json, cluster_trace, run_cluster_matrix, SCENARIOS,
    };
    use equinox::harness::ConformanceOpts;

    let quick = args.iter().any(|a| a == "--quick");
    let seed = match parse_flag(args, "--seed", 42u64) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let threads = match parse_flag(args, "--threads", 0usize) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let drive_name = flag_value(args, "--drive").unwrap_or("serial");
    let Some(drive) = DriveMode::by_name(drive_name, threads) else {
        eprintln!("unknown drive mode '{drive_name}' (serial|parallel)");
        return 2;
    };

    if args.iter().any(|a| a == "--matrix") {
        let opts = ConformanceOpts { quick, base_seed: seed, drive };
        let t = std::time::Instant::now();
        let cells = run_cluster_matrix(&opts);
        let failed: Vec<_> = cells.iter().filter(|c| !c.passed()).collect();
        println!(
            "cluster conformance [{}]: {} cells ({} scenarios × 2 fleets × {} routers) in {:.1}s — {} failed",
            drive.label(),
            cells.len(),
            SCENARIOS.len(),
            equinox::harness::cluster::ROUTERS.len(),
            t.elapsed().as_secs_f64(),
            failed.len()
        );
        for c in &cells {
            println!(
                "  {} {:<44} disc {:>9.0}/{:<9.0} syncs {:<4} routed {:?}",
                if c.passed() { "ok  " } else { "FAIL" },
                c.key(),
                c.max_disc,
                c.disc_bound,
                c.syncs,
                c.routed
            );
            for v in &c.violations {
                println!("       {v}");
            }
        }
        if let Some(path) = flag_value(args, "--json") {
            let doc = cluster_matrix_to_json(&opts, &cells);
            if let Err(e) = std::fs::write(path, doc.to_string()) {
                eprintln!("cannot write verdicts to {path}: {e}");
                return 1;
            }
            println!("verdicts written to {path}");
        }
        return if failed.is_empty() { 0 } else { 1 };
    }

    let fleet_name = flag_value(args, "--fleet").unwrap_or("hetero");
    let Some(fleet) = Fleet::by_name(fleet_name) else {
        eprintln!("unknown fleet '{fleet_name}' (solo|homo4|hetero|skewed3)");
        return 2;
    };
    let router_name = flag_value(args, "--router").unwrap_or("fair_share");
    let Some(router) = RouterKind::by_name(router_name) else {
        eprintln!("unknown router '{router_name}' (round_robin|jsq|predicted_cost|fair_share)");
        return 2;
    };
    let scenario = flag_value(args, "--scenario").unwrap_or("heavy_hitter");
    if equinox::harness::cluster::cluster_scenario(scenario, quick).is_none() {
        eprintln!(
            "unknown cluster scenario '{scenario}' \
             (heavy_hitter|flash_crowd|tenant_churn|constant_overload|balanced_load)"
        );
        return 2;
    }
    let sync = match parse_flag(args, "--sync", 1.0f64) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    let trace = cluster_trace(scenario, fleet.len(), quick, seed);
    let opts = ClusterOpts { sync_period: sync, drive, ..ClusterOpts::new(seed) };
    // Reject impossible configurations (negative/NaN sync, empty fleet)
    // with a typed error instead of panicking deep in the driver.
    if let Err(e) = opts.validate(&fleet) {
        eprintln!("invalid cluster options: {e:#}");
        return 2;
    }
    let t = std::time::Instant::now();
    let res = run_cluster(
        fleet,
        router.make(),
        SchedKind::Equinox,
        PredKind::Mope,
        &trace,
        &opts,
    );
    let lat = res.merged_latency();
    println!(
        "cluster '{}' router {} scenario {} [{}] — {} replicas, {} requests in {:.1}s wall-clock sim {:.1}s",
        res.fleet,
        res.router,
        scenario,
        drive.label(),
        res.replicas.len(),
        trace.len(),
        t.elapsed().as_secs_f64(),
        res.wall()
    );
    println!(
        "finished {}/{} | {:.0} wtok/s | util {:.2} | preemptions {} | syncs {} (period {:.2}s)",
        res.finished(),
        res.total_requests(),
        res.weighted_tps(),
        res.mean_gpu_util(),
        res.preemptions(),
        res.syncs,
        res.sync_period
    );
    println!(
        "TTFT mean {:.2}s p90 {:.2}s | global max co-backlogged disc {:.0} | Jain(service) {:.3}",
        lat.ttft_mean(),
        lat.ttft_p(0.9),
        res.max_co_backlogged_diff(),
        res.jain_over_service()
    );
    for (i, (r, name)) in res.replicas.iter().zip(&res.replica_names).enumerate() {
        println!(
            "  r{i} {:<16} routed {:>5} finished {:>5} util {:.2} wall {:>7.1}s preempt {}",
            name, res.routed[i], r.finished, r.gpu_util, r.wall, r.preemptions
        );
    }
    if let Some(path) = flag_value(args, "--json") {
        let mut reps = Vec::new();
        for (i, r) in res.replicas.iter().enumerate() {
            reps.push(
                Json::obj()
                    .set("name", res.replica_names[i])
                    .set("routed", res.routed[i])
                    .set("finished", r.finished)
                    .set("gpu_util", r.gpu_util)
                    .set("wall", r.wall)
                    .set("preemptions", r.preemptions),
            );
        }
        let doc = Json::obj()
            .set("fleet", res.fleet.as_str())
            .set("router", res.router.as_str())
            .set("scenario", scenario)
            .set("drive", drive.label())
            .set("seed", format!("0x{seed:016x}"))
            .set("finished", res.finished())
            .set("total", res.total_requests())
            .set("weighted_tps", res.weighted_tps())
            .set("max_disc", res.max_co_backlogged_diff())
            .set("syncs", res.syncs)
            .set("digest", format!("0x{:016x}", res.digest()))
            .set("replicas", Json::Arr(reps));
        if let Err(e) = std::fs::write(path, doc.to_string()) {
            eprintln!("cannot write {path}: {e}");
            return 1;
        }
        println!("rollups written to {path}");
    }
    0
}

/// Run one traced cluster cell through the flight recorder and export
/// the merged event log (Perfetto JSON for chrome://tracing / ui.perfetto.dev,
/// or compact JSONL). `--explain REQUEST` prints that request's latency
/// attribution (queue ahead, preemption stalls, execution) instead of a
/// full export. Exit 2 on usage errors, 1 on IO errors.
fn cmd_trace(args: &[String]) -> i32 {
    use equinox::cluster::{DriveMode, Fleet, RouterKind};
    use equinox::core::RequestId;
    use equinox::harness::trace::run_traced_cell;
    use equinox::obs::export::{explain, to_jsonl, to_perfetto};

    let quick = args.iter().any(|a| a == "--quick");
    let seed = match parse_flag(args, "--seed", 42u64) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let threads = match parse_flag(args, "--threads", 0usize) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let drive_name = flag_value(args, "--drive").unwrap_or("serial");
    let Some(drive) = DriveMode::by_name(drive_name, threads) else {
        eprintln!("unknown drive mode '{drive_name}' (serial|parallel)");
        return 2;
    };
    let fleet_name = flag_value(args, "--fleet").unwrap_or("hetero");
    let Some(fleet) = Fleet::by_name(fleet_name) else {
        eprintln!("unknown fleet '{fleet_name}' (solo|homo4|hetero|skewed3)");
        return 2;
    };
    let router_name = flag_value(args, "--router").unwrap_or("fair_share");
    let Some(router) = RouterKind::by_name(router_name) else {
        eprintln!("unknown router '{router_name}' (round_robin|jsq|predicted_cost|fair_share)");
        return 2;
    };
    let scenario = flag_value(args, "--scenario").unwrap_or("heavy_hitter");
    if equinox::harness::cluster::cluster_scenario(scenario, quick).is_none() {
        eprintln!(
            "unknown cluster scenario '{scenario}' \
             (heavy_hitter|flash_crowd|tenant_churn|constant_overload|balanced_load)"
        );
        return 2;
    }

    let t = std::time::Instant::now();
    let cell = run_traced_cell(scenario, fleet, router, drive, quick, seed);
    eprintln!(
        "trace '{}' router {} [{}] — {} events ({} dropped) in {:.1}s, finished {}/{}",
        scenario,
        router_name,
        drive.label(),
        cell.log.events.len(),
        cell.log.dropped,
        t.elapsed().as_secs_f64(),
        cell.finished,
        cell.total
    );
    eprintln!(
        "trace digest 0x{:016x} | cluster digest 0x{:016x}",
        cell.trace_digest(),
        cell.cluster_digest
    );

    if let Some(reqstr) = flag_value(args, "--explain") {
        let Ok(id) = reqstr.parse::<u64>() else {
            eprintln!("invalid request id '{reqstr}' for --explain (expected u64)");
            return 2;
        };
        print!("{}", explain(&cell.log, RequestId(id)));
        return 0;
    }

    let format = flag_value(args, "--format").unwrap_or("perfetto");
    let text = match format {
        "perfetto" => to_perfetto(&cell.log),
        "jsonl" => to_jsonl(&cell.log),
        _ => {
            eprintln!("unknown format '{format}' (perfetto|jsonl)");
            return 2;
        }
    };
    match flag_value(args, "--out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &text) {
                eprintln!("cannot write {path}: {e}");
                return 1;
            }
            println!("{format} trace written to {path}");
        }
        None => print!("{text}"),
    }
    0
}

/// Run the chaos matrix (scenario × fault plan over the heterogeneous
/// fleet, FairShare + Equinox + MoPE): every cell replays bit-exact,
/// cross-checks the opposite drive mode, and enforces the fault-plane
/// invariants (conservation modulo shed, survivor no-starvation,
/// bounded post-recovery discrepancy). Exit 1 on any violated cell.
fn cmd_chaos(args: &[String]) -> i32 {
    use equinox::cluster::DriveMode;
    use equinox::harness::chaos::{
        chaos_matrix_to_json, run_chaos_matrix, CHAOS_PLANS, CHAOS_SCENARIOS,
    };
    use equinox::harness::ConformanceOpts;

    let quick = args.iter().any(|a| a == "--quick");
    let seed = match parse_flag(args, "--seed", 42u64) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let threads = match parse_flag(args, "--threads", 0usize) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let drive_name = flag_value(args, "--drive").unwrap_or("serial");
    let Some(drive) = DriveMode::by_name(drive_name, threads) else {
        eprintln!("unknown drive mode '{drive_name}' (serial|parallel)");
        return 2;
    };

    let opts = ConformanceOpts { quick, base_seed: seed, drive };
    let t = std::time::Instant::now();
    let cells = run_chaos_matrix(&opts);
    let failed: Vec<_> = cells.iter().filter(|c| !c.passed()).collect();
    println!(
        "chaos [{}]: {} cells ({} scenarios × {} fault plans, each replayed + cross-driven) in {:.1}s — {} failed",
        drive.label(),
        cells.len(),
        CHAOS_SCENARIOS.len(),
        CHAOS_PLANS.len(),
        t.elapsed().as_secs_f64(),
        failed.len()
    );
    for c in &cells {
        println!(
            "  {} {:<28} finished {:>5}/{:<5} shed {:<4} migrated {:<4} transitions {:<3} post-disc {:>9.0}/{:<9.0}",
            if c.passed() { "ok  " } else { "FAIL" },
            c.key(),
            c.finished,
            c.total,
            c.shed,
            c.migrated,
            c.fault_transitions,
            c.max_disc_post,
            c.disc_bound
        );
        for v in &c.violations {
            println!("       {v}");
        }
        for n in &c.notes {
            println!("       note: {n}");
        }
    }
    if let Some(path) = flag_value(args, "--json") {
        let doc = chaos_matrix_to_json(&opts, &cells);
        if let Err(e) = std::fs::write(path, doc.to_string()) {
            eprintln!("cannot write verdicts to {path}: {e}");
            return 1;
        }
        println!("verdicts written to {path}");
    }
    if failed.is_empty() {
        0
    } else {
        1
    }
}

/// Run the autoscale matrix (scenario × scale policy over the minimal
/// fleet, FairShare + Equinox + MoPE): every cell replays bit-exact,
/// cross-checks the opposite drive mode, and enforces the elasticity
/// invariants (conservation across drains, epoch-ledger consistency).
/// Exit 1 on any violated cell.
fn cmd_autoscale(args: &[String]) -> i32 {
    use equinox::cluster::DriveMode;
    use equinox::harness::autoscale::{
        autoscale_matrix_to_json, run_autoscale_matrix, AUTOSCALE_POLICIES, AUTOSCALE_SCENARIOS,
    };
    use equinox::harness::ConformanceOpts;

    let quick = args.iter().any(|a| a == "--quick");
    let seed = match parse_flag(args, "--seed", 42u64) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let threads = match parse_flag(args, "--threads", 0usize) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let drive_name = flag_value(args, "--drive").unwrap_or("serial");
    let Some(drive) = DriveMode::by_name(drive_name, threads) else {
        eprintln!("unknown drive mode '{drive_name}' (serial|parallel)");
        return 2;
    };

    let opts = ConformanceOpts { quick, base_seed: seed, drive };
    let t = std::time::Instant::now();
    let cells = run_autoscale_matrix(&opts);
    let failed: Vec<_> = cells.iter().filter(|c| !c.passed()).collect();
    println!(
        "autoscale [{}]: {} cells ({} scenarios × {} policies, each replayed + cross-driven) in {:.1}s — {} failed",
        drive.label(),
        cells.len(),
        AUTOSCALE_SCENARIOS.len(),
        AUTOSCALE_POLICIES.len(),
        t.elapsed().as_secs_f64(),
        failed.len()
    );
    for c in &cells {
        println!(
            "  {} {:<28} finished {:>5}/{:<5} migrated {:<4} transitions {:<3} epochs {:<3} final {:<2} util {:.2}",
            if c.passed() { "ok  " } else { "FAIL" },
            c.key(),
            c.finished,
            c.total,
            c.migrated,
            c.scale_transitions,
            c.epochs,
            c.final_replicas,
            c.mean_gpu_util
        );
        for v in &c.violations {
            println!("       {v}");
        }
        for n in &c.notes {
            println!("       note: {n}");
        }
    }
    if let Some(path) = flag_value(args, "--json") {
        let doc = autoscale_matrix_to_json(&opts, &cells);
        if let Err(e) = std::fs::write(path, doc.to_string()) {
            eprintln!("cannot write verdicts to {path}: {e}");
            return 1;
        }
        println!("verdicts written to {path}");
    }
    if failed.is_empty() {
        0
    } else {
        1
    }
}

/// Run the mispredict matrix (scenario × prediction-fault plan × guard
/// mitigation over a homogeneous pair, FairShare + MoPE): every cell
/// replays bit-exact, cross-checks the opposite drive's cluster AND
/// trace digests, and enforces the calibration-guard invariants
/// (conservation, bounded discrepancy degradation, drained admit
/// receipts, ladder engage/recover under blackout, debiased strictly
/// beating raw under bias). Exit 1 on any violated cell or matrix-level
/// check.
fn cmd_mispredict(args: &[String]) -> i32 {
    use equinox::cluster::DriveMode;
    use equinox::harness::mispredict::{
        check_mispredict_matrix, mispredict_matrix_to_json, run_mispredict_matrix,
        MISPREDICT_MITIGATIONS, MISPREDICT_PLANS, MISPREDICT_SCENARIOS,
    };
    use equinox::harness::ConformanceOpts;

    let quick = args.iter().any(|a| a == "--quick");
    let seed = match parse_flag(args, "--seed", 42u64) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let threads = match parse_flag(args, "--threads", 0usize) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let drive_name = flag_value(args, "--drive").unwrap_or("serial");
    let Some(drive) = DriveMode::by_name(drive_name, threads) else {
        eprintln!("unknown drive mode '{drive_name}' (serial|parallel)");
        return 2;
    };

    let opts = ConformanceOpts { quick, base_seed: seed, drive };
    let t = std::time::Instant::now();
    let cells = run_mispredict_matrix(&opts);
    let matrix_violations = check_mispredict_matrix(&cells);
    let failed: Vec<_> = cells.iter().filter(|c| !c.passed()).collect();
    println!(
        "mispredict [{}]: {} cells ({} scenarios × {} plans × {} mitigations, each replayed + cross-driven) in {:.1}s — {} failed",
        drive.label(),
        cells.len(),
        MISPREDICT_SCENARIOS.len(),
        MISPREDICT_PLANS.len(),
        MISPREDICT_MITIGATIONS.len(),
        t.elapsed().as_secs_f64(),
        failed.len()
    );
    for c in &cells {
        println!(
            "  {} {:<36} finished {:>5}/{:<5} disc {:>9.0}/{:<9.0} guard-trans {:<3} modes {:?}",
            if c.passed() { "ok  " } else { "FAIL" },
            c.key(),
            c.finished,
            c.total,
            c.max_disc,
            c.disc_bound,
            c.guard_transitions,
            c.final_modes
        );
        for v in &c.violations {
            println!("       {v}");
        }
        for n in &c.notes {
            println!("       note: {n}");
        }
    }
    for v in &matrix_violations {
        println!("  MATRIX FAIL: {v}");
    }
    if let Some(path) = flag_value(args, "--json") {
        let doc = mispredict_matrix_to_json(&opts, &cells);
        if let Err(e) = std::fs::write(path, doc.to_string()) {
            eprintln!("cannot write verdicts to {path}: {e}");
            return 1;
        }
        println!("verdicts written to {path}");
    }
    if failed.is_empty() && matrix_violations.is_empty() {
        0
    } else {
        1
    }
}

fn cmd_simulate(args: &[String]) -> i32 {
    let Some(path) = flag_value(args, "--config") else {
        eprintln!("usage: equinox simulate --config <file> (see configs/*.eqx.toml)");
        return 2;
    };
    let cfg = match equinox::config::ConfigFile::load(std::path::Path::new(path)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 1;
        }
    };
    let spec = match equinox::config::SimulateSpec::from_config(&cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("config error: {e}");
            return 1;
        }
    };
    println!(
        "simulating '{}' — {} on {} tp{} ({} host), scheduler {:?}, {} clients, {:.0}s",
        spec.name,
        spec.sim.gpu.model.name,
        spec.sim.gpu.gpu.name,
        spec.sim.gpu.tp,
        spec.sim.host.name,
        spec.scheduler,
        spec.scenario.clients.len(),
        spec.scenario.duration
    );
    let res = spec.run();
    println!(
        "finished {}/{} requests | wall {:.1}s | {:.0} wtok/s | util {:.2} | preemptions {}",
        res.finished, res.total_requests, res.wall, res.weighted_tps, res.gpu_util, res.preemptions
    );
    println!(
        "TTFT mean {:.2}s p90 {:.2}s | e2e mean {:.2}s | Jain(10s) {:.3}",
        res.latency.ttft_mean(),
        res.latency.ttft_p(0.9),
        res.latency.e2e_mean(),
        res.windowed_jain(10.0)
    );
    for c in res.service.clients() {
        let lat = res.per_client_latency.get(c).expect("served client has latency stats");
        println!(
            "  {c}: {} reqs, service {:.0} wtok, TTFT p50 {:.2}s",
            lat.count(),
            res.service.total(c),
            lat.ttft_p(0.5)
        );
    }
    0
}

fn cmd_info() -> i32 {
    match equinox::runtime::pjrt::Runtime::cpu() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            let dir = std::path::Path::new("artifacts");
            match equinox::runtime::Manifest::load(dir) {
                Ok(m) => {
                    println!(
                        "artifacts: model={} vocab={} layers={} max_seq={} ({} artifacts)",
                        m.model.name,
                        m.model.vocab,
                        m.model.n_layers,
                        m.model.max_seq,
                        m.artifacts.len()
                    );
                }
                Err(e) => println!("artifacts: not available ({e:#})"),
            }
            0
        }
        Err(e) => {
            eprintln!("PJRT unavailable: {e:#}");
            1
        }
    }
}

fn cmd_generate(args: &[String]) -> i32 {
    let prompt = flag_value(args, "--prompt").unwrap_or("explain rust lifetimes in detail");
    let max_tokens: u32 =
        flag_value(args, "--max-tokens").and_then(|v| v.parse().ok()).unwrap_or(32);
    let client: u32 = flag_value(args, "--client").and_then(|v| v.parse().ok()).unwrap_or(0);
    let artifacts = flag_value(args, "--artifacts").unwrap_or("artifacts");
    let service = match ServeService::start(ServiceConfig::new(artifacts)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to start service: {e:#}");
            return 1;
        }
    };
    match service.generate(ClientId(client), prompt, max_tokens) {
        Ok(done) => {
            println!(
                "client={} ttft={:.3}s e2e={:.3}s tokens={}",
                done.client, done.ttft, done.e2e, done.output_tokens
            );
            println!("{}", done.text);
            0
        }
        Err(e) => {
            eprintln!("generation failed: {e:#}");
            1
        }
    }
}

fn cmd_serve(args: &[String]) -> i32 {
    let addr = flag_value(args, "--addr").unwrap_or("127.0.0.1:8090");
    let artifacts = flag_value(args, "--artifacts").unwrap_or("artifacts");
    let service = match ServeService::start(ServiceConfig::new(artifacts)) {
        Ok(s) => std::sync::Arc::new(s),
        Err(e) => {
            eprintln!("failed to start service: {e:#}");
            return 1;
        }
    };
    let svc = service.clone();
    let server = HttpServer::start(addr, move |req| match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/generate") => {
            let Ok(body) = Json::parse(&req.body) else {
                return HttpResponse::error(400, r#"{"error":"invalid json"}"#);
            };
            let client = body.get("client").and_then(|v| v.as_u64()).unwrap_or(0) as u32;
            let prompt = body.get("prompt").and_then(|v| v.as_str()).unwrap_or("");
            let max_tokens = body.get("max_tokens").and_then(|v| v.as_u64()).unwrap_or(32) as u32;
            match svc.submit(ClientId(client), prompt, max_tokens) {
                Ok(rx) => match rx.recv() {
                    Ok(done) => HttpResponse::ok(
                        Json::obj()
                            .set("client", done.client.0 as u64)
                            .set("text", done.text)
                            .set("output_tokens", done.output_tokens as u64)
                            .set("ttft_s", done.ttft)
                            .set("e2e_s", done.e2e)
                            .to_string(),
                    ),
                    Err(_) => HttpResponse::error(503, r#"{"error":"service stopped"}"#),
                },
                Err(e) => {
                    HttpResponse::error(429, Json::obj().set("error", format!("{e}")).to_string())
                }
            }
        }
        ("GET", "/v1/stats") => HttpResponse::ok(svc.stats.snapshot_json().to_string()),
        ("GET", "/metrics") => HttpResponse::text(svc.metrics_prometheus()),
        _ => HttpResponse::error(404, r#"{"error":"not found"}"#),
    });
    match server {
        Ok(s) => {
            println!("equinox serving TinyLM on http://{}", s.addr());
            println!("POST /v1/generate {{\"client\":0,\"prompt\":\"...\",\"max_tokens\":32}} | GET /v1/stats | GET /metrics");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("server failed: {e:#}");
            1
        }
    }
}
