//! Sampling from the distributions the workload generators need.
//!
//! The offline registry lacks `rand_distr`, so exponential, Poisson,
//! normal, log-normal and Zipf samplers are implemented here directly.

use super::rng::Rng;

/// Exponential variate with the given rate (mean = 1/rate).
pub fn exponential(rng: &mut Rng, rate: f64) -> f64 {
    assert!(rate > 0.0);
    // Inverse CDF; guard against ln(0).
    let u = 1.0 - rng.f64();
    -u.ln() / rate
}

/// Standard normal via Marsaglia polar method.
pub fn std_normal(rng: &mut Rng) -> f64 {
    loop {
        let u = 2.0 * rng.f64() - 1.0;
        let v = 2.0 * rng.f64() - 1.0;
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            return u * (-2.0 * s.ln() / s).sqrt();
        }
    }
}

/// Normal with mean/std.
pub fn normal(rng: &mut Rng, mean: f64, std: f64) -> f64 {
    mean + std * std_normal(rng)
}

/// Log-normal parameterised by the *underlying* normal's mu/sigma.
pub fn log_normal(rng: &mut Rng, mu: f64, sigma: f64) -> f64 {
    normal(rng, mu, sigma).exp()
}

/// Log-normal parameterised by its own median and the multiplicative
/// sigma (geometric std). `median * gsd^N(0,1)`.
pub fn log_normal_median(rng: &mut Rng, median: f64, gsd: f64) -> f64 {
    assert!(median > 0.0 && gsd > 1.0);
    log_normal(rng, median.ln(), gsd.ln())
}

/// Poisson variate. Knuth's method for small lambda, normal approximation
/// (continuity-corrected, clamped at 0) for large lambda.
pub fn poisson(rng: &mut Rng, lambda: f64) -> u64 {
    assert!(lambda >= 0.0);
    if lambda == 0.0 {
        return 0;
    }
    if lambda < 30.0 {
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    } else {
        let x = normal(rng, lambda, lambda.sqrt());
        x.round().max(0.0) as u64
    }
}

/// Zipf-like rank sampler over [0, n) with exponent s (s=0 → uniform).
/// Used for skewed per-client popularity in multi-tenant traces.
pub fn zipf(rng: &mut Rng, n: usize, s: f64) -> usize {
    assert!(n > 0);
    if s == 0.0 {
        return rng.below(n as u64) as usize;
    }
    // Inverse-CDF over precomputable harmonic weights would allocate; for
    // the small n (≤ a few hundred clients) a linear scan is fine and
    // allocation-free.
    let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
    let mut x = rng.f64() * h;
    for k in 1..=n {
        let w = (k as f64).powf(-s);
        if x < w {
            return k - 1;
        }
        x -= w;
    }
    n - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(1);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| exponential(&mut r, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| normal(&mut r, 3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.03, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn poisson_small_lambda_mean() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| poisson(&mut r, 2.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.03, "mean={mean}");
    }

    #[test]
    fn poisson_large_lambda_mean() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| poisson(&mut r, 100.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 100.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn log_normal_median_is_median() {
        let mut r = Rng::new(5);
        let n = 100_001;
        let mut xs: Vec<f64> = (0..n).map(|_| log_normal_median(&mut r, 50.0, 2.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[n / 2];
        assert!((med - 50.0).abs() / 50.0 < 0.05, "median={med}");
    }

    #[test]
    fn zipf_skews_to_low_ranks() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 10];
        for _ in 0..50_000 {
            counts[zipf(&mut r, 10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > counts[9]);
    }

    #[test]
    fn zipf_uniform_when_s_zero() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[zipf(&mut r, 4, 0.0)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }
}
