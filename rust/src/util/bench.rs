//! Tiny benchmark runner for `harness = false` benches.
//!
//! The offline registry lacks `criterion`; this provides the same core
//! loop — warmup, calibrated iteration count, multiple samples, median +
//! MAD reporting — with stable plain-text output that EXPERIMENTS.md
//! records. Supports `cargo bench -- <filter>`.

pub use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark group; prints results as it runs.
pub struct Bench {
    filter: Option<String>,
    /// (name, median ns/iter) for every benchmark that ran.
    pub results: Vec<(String, f64)>,
    target_sample: Duration,
    samples: usize,
}

impl Bench {
    /// Construct from CLI args (`cargo bench -- <filter>` passes the filter).
    pub fn from_args() -> Self {
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        Bench {
            filter,
            results: Vec::new(),
            target_sample: Duration::from_millis(200),
            samples: 11,
        }
    }

    /// Faster settings for CI-ish runs.
    pub fn quick(mut self) -> Self {
        self.target_sample = Duration::from_millis(50);
        self.samples = 5;
        self
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map(|f| name.contains(f)).unwrap_or(true)
    }

    /// Run `f` repeatedly; report median ns/iteration.
    pub fn run<F, R>(&mut self, name: &str, mut f: F)
    where
        F: FnMut() -> R,
    {
        if !self.enabled(name) {
            return;
        }
        // Warmup + calibration: find iters such that one sample ≈ target.
        let mut iters: u64 = 1;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            let el = t.elapsed();
            if el >= self.target_sample / 4 || iters >= 1 << 30 {
                let per = el.as_nanos().max(1) as f64 / iters as f64;
                iters = ((self.target_sample.as_nanos() as f64 / per).ceil() as u64).max(1);
                break;
            }
            iters *= 2;
        }
        let mut samples: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mad = {
            let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
            devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            devs[devs.len() / 2]
        };
        println!(
            "bench {name:<48} {:>12}/iter  (±{}, {iters} iters x {} samples)",
            fmt_ns(median),
            fmt_ns(mad),
            self.samples
        );
        self.results.push((name.to_string(), median));
    }

    /// Run a benchmark that measures a whole batch internally and reports
    /// a throughput-style metric (items/sec).
    pub fn run_throughput<F>(&mut self, name: &str, items: u64, mut f: F)
    where
        F: FnMut(),
    {
        if !self.enabled(name) {
            return;
        }
        let mut samples: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let rate = items as f64 / median.max(1e-12);
        println!(
            "bench {name:<48} {rate:>12.0} items/s  ({:.3} s/run, {} samples)",
            median, self.samples
        );
        self.results.push((name.to_string(), rate));
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with(" s"));
    }
}
