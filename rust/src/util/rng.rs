//! Deterministic PCG64-family PRNG.
//!
//! The offline registry ships no `rand` crate, so the whole repo uses this
//! small, seedable generator. Determinism matters: every experiment in
//! `exp/` is reproducible from its seed, which is how EXPERIMENTS.md numbers
//! are regenerated bit-for-bit.

/// Splitmix64 — used to expand a single `u64` seed into stream state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator: fast, 256-bit state, good statistical quality.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-client generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → uniform double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's nearly-divisionless method.
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick an element index by weight (weights need not sum to 1).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2_000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(17);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
