//! Lightweight randomized property-testing helper.
//!
//! The offline registry lacks `proptest`; this gives the same workflow for
//! the invariants we care about (scheduler fairness bounds, KV-cache
//! alloc/free safety, batcher feasibility): generate many random cases
//! from a deterministic seed, shrink-free but with the failing seed
//! printed so a case is reproducible by construction.

use super::rng::Rng;

/// Default number of cases per property (overridable via EQX_CHECK_CASES).
pub fn default_cases() -> u64 {
    std::env::var("EQX_CHECK_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// Run `prop` on `cases` random inputs. The property receives a fresh RNG
/// per case; on failure the panic message carries the case seed.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Rng) + std::panic::RefUnwindSafe,
{
    let base = 0x45_51_58_00u64; // "EQX"
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Convenience: run with the default number of cases.
pub fn check_default<F>(name: &str, prop: F)
where
    F: Fn(&mut Rng) + std::panic::RefUnwindSafe,
{
    check(name, default_cases(), prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 parity", 64, |rng| {
            let x = rng.next_u64();
            assert_eq!(x % 2, x & 1);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failing_seed() {
        check("always fails", 4, |_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn case_seeds_are_distinct_streams() {
        // Two different cases must see different random values — guards
        // against accidentally reusing one seed for all cases.
        use std::sync::atomic::{AtomicU64, Ordering};
        static FIRST: AtomicU64 = AtomicU64::new(0);
        static DIFFERENT: AtomicU64 = AtomicU64::new(0);
        check("distinct", 8, |rng| {
            let v = rng.next_u64();
            let prev = FIRST.swap(v, Ordering::SeqCst);
            if prev != 0 && prev != v {
                DIFFERENT.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(DIFFERENT.load(Ordering::SeqCst) > 0);
    }
}
