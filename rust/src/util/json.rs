//! Minimal JSON emitter and parser.
//!
//! The offline registry lacks `serde`/`serde_json`; the repo only needs
//! JSON for (a) the artifact manifest written by `python/compile/aot.py`,
//! (b) the HTTP frontend's request/response bodies and (c) experiment
//! result dumps. This module implements exactly that subset: objects,
//! arrays, strings (with escapes), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Object` uses a BTreeMap so emission is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics if self is not an object.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns the value and rejects trailing junk.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(x: Vec<f64>) -> Json {
        Json::Arr(x.into_iter().map(Json::from).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj()
            .set("name", "equinox")
            .set("alpha", 0.7)
            .set("experts", 3u64)
            .set("flags", Json::Arr(vec![Json::Bool(true), Json::Null]));
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""line\nbreak A \"q\"""#).unwrap();
        assert_eq!(j.as_str(), Some("line\nbreak A \"q\""));
    }

    #[test]
    fn emit_escapes() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("-3.25e2").unwrap().as_f64(), Some(-325.0));
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
    }

    #[test]
    fn rejects_trailing_junk() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}
