//! Streaming and batch statistics used by the metrics layer and the
//! experiment harness: mean/variance (Welford), percentiles, histograms.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample with linear interpolation (q in [0,1]).
/// Sorts a copy; fine for experiment-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Fixed-bucket histogram over [lo, hi); out-of-range values clamp to the
/// edge buckets. Used for latency distributions in the HTTP frontend.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram { lo, hi, buckets: vec![0; nbuckets], count: 0, sum: 0.0 }
    }

    pub fn record(&mut self, x: f64) {
        let n = self.buckets.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            n - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * n as f64) as usize
        };
        self.buckets[idx.min(n - 1)] += 1;
        self.count += 1;
        self.sum += x;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    /// Approximate quantile from bucket mid-points.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        let width = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return self.lo + (i as f64 + 0.5) * width;
            }
        }
        self.hi
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-9);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
    }

    #[test]
    fn welford_merge_equals_combined() {
        let a = [1.0, 5.0, 2.0];
        let b = [9.0, 3.0, 7.0, 4.0];
        let mut wa = Welford::new();
        let mut wb = Welford::new();
        a.iter().for_each(|&x| wa.push(x));
        b.iter().for_each(|&x| wb.push(x));
        wa.merge(&wb);
        let all: Vec<f64> = a.iter().chain(b.iter()).cloned().collect();
        assert!((wa.mean() - mean(&all)).abs() < 1e-12);
        assert!((wa.variance() - variance(&all)).abs() < 1e-9);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 3.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_is_zero() {
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn histogram_quantiles_roughly_right() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.record(i as f64 / 10.0);
        }
        let p50 = h.quantile(0.5);
        assert!((p50 - 50.0).abs() < 2.0, "p50={p50}");
        let p90 = h.quantile(0.9);
        assert!((p90 - 90.0).abs() < 2.0, "p90={p90}");
    }

    #[test]
    fn histogram_clamps_out_of_range() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.record(-5.0);
        h.record(50.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[9], 1);
    }
}
