//! Shared utilities: deterministic RNG, distributions, statistics, a
//! minimal JSON codec, the bench runner, and the property-check helper.
//! All hand-rolled because the offline crate registry ships only the `xla`
//! crate's dependency closure (see DESIGN.md substitution ledger).

pub mod bench;
pub mod check;
pub mod dist;
pub mod json;
pub mod rng;
pub mod stats;
