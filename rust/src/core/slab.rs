//! Dense per-client storage: the million-tenant hot path.
//!
//! `ClientSlab<T>` is a contiguous `Vec<T>` indexed directly by
//! `ClientId` (ids are dense u32s assigned from 0 by the workload
//! layer) with a u64-word occupancy bitset. Compared to the
//! `BTreeMap<ClientId, T>` it replaces on every per-client hot
//! structure, a lookup is one bounds-checked array index instead of a
//! pointer-chasing log-time descent, and iteration is a linear bitset
//! scan in ASCENDING id order — bit-identical to `BTreeMap`'s
//! ascending-key order (`ClientId`'s `Ord` is `u32`'s), so every
//! fingerprint, digest, and golden snapshot downstream of an iteration
//! order is preserved. That order equivalence is the zero-drift
//! argument; `tests/scale.rs` machine-checks it by replaying the full
//! adversarial registry on both backends.
//!
//! The `ClientMap` trait + `ClientMapFamily` GAT let the schedulers be
//! generic over the backend: `SlabFamily` is the production path,
//! `BTreeFamily` instantiates the SAME algorithm over `BTreeMap` as the
//! retained reference (`sched/reference.rs` pattern), so the
//! slab-vs-BTreeMap comparison in `benches/scale.rs` is an
//! apples-to-apples measurement of the storage layer alone.

use super::ClientId;
use std::collections::BTreeMap;

/// Dense map from `ClientId` to `T`: `Vec` slots + occupancy bitset.
///
/// Growth is by `ClientId` value (`slots.len() == max_id + 1`), so the
/// memory model is explicit: one `T` slot per id ever seen plus one bit
/// per id of address space — `bytes_resident()` reports it for the
/// bench's bytes-per-idle-tenant line. Removal never shrinks; retired
/// slots keep their storage so reactivation is allocation-free.
#[derive(Debug, Clone)]
pub struct ClientSlab<T> {
    slots: Vec<T>,
    /// Bit `id % 64` of word `id / 64` set ⇔ `id` is present.
    occupied: Vec<u64>,
    len: usize,
}

impl<T: Default> Default for ClientSlab<T> {
    fn default() -> Self {
        ClientSlab::new()
    }
}

impl<T: Default> ClientSlab<T> {
    pub fn new() -> Self {
        ClientSlab { slots: Vec::new(), occupied: Vec::new(), len: 0 }
    }

    /// Pre-size for ids `0..n` (benches at 10⁶ tenants skip regrowth).
    pub fn with_capacity(n: usize) -> Self {
        let mut s = ClientSlab::new();
        if n > 0 {
            s.slots.resize_with(n, T::default);
            s.occupied.resize(n.div_ceil(64), 0);
        }
        s
    }

    #[inline]
    fn word(id: ClientId) -> (usize, u64) {
        ((id.0 as usize) >> 6, 1u64 << (id.0 & 63))
    }

    #[inline]
    fn grow_to(&mut self, id: ClientId) {
        let idx = id.0 as usize;
        if idx >= self.slots.len() {
            self.slots.resize_with(idx + 1, T::default);
            self.occupied.resize((idx >> 6) + 1, 0);
        }
    }

    #[inline]
    pub fn contains(&self, id: ClientId) -> bool {
        let (w, m) = Self::word(id);
        self.occupied.get(w).is_some_and(|&bits| bits & m != 0)
    }

    #[inline]
    pub fn get(&self, id: ClientId) -> Option<&T> {
        if self.contains(id) {
            Some(&self.slots[id.0 as usize])
        } else {
            None
        }
    }

    #[inline]
    pub fn get_mut(&mut self, id: ClientId) -> Option<&mut T> {
        if self.contains(id) {
            Some(&mut self.slots[id.0 as usize])
        } else {
            None
        }
    }

    /// Insert or overwrite, returning the previous value if present
    /// (same contract as `BTreeMap::insert`).
    pub fn insert(&mut self, id: ClientId, value: T) -> Option<T> {
        self.grow_to(id);
        let (w, m) = Self::word(id);
        let slot = &mut self.slots[id.0 as usize];
        if self.occupied[w] & m != 0 {
            Some(std::mem::replace(slot, value))
        } else {
            self.occupied[w] |= m;
            self.len += 1;
            *slot = value;
            None
        }
    }

    /// Mark present and return the slot, KEEPING whatever storage the
    /// slot last held (`Default` on first touch). The `retire` contract
    /// guarantees a retired slot holds a Default-equivalent value, so a
    /// reactivated client observes exactly a fresh `Default` — but
    /// reuses e.g. a `VecDeque`'s buffer, keeping reactivation
    /// allocation-free.
    pub fn or_default(&mut self, id: ClientId) -> &mut T {
        self.grow_to(id);
        let (w, m) = Self::word(id);
        if self.occupied[w] & m == 0 {
            self.occupied[w] |= m;
            self.len += 1;
        }
        &mut self.slots[id.0 as usize]
    }

    /// Mark present; when absent the slot is first set to `f()` (same
    /// contract as `BTreeMap::entry().or_insert_with`).
    pub fn or_insert_with(&mut self, id: ClientId, f: impl FnOnce() -> T) -> &mut T {
        self.grow_to(id);
        let (w, m) = Self::word(id);
        if self.occupied[w] & m == 0 {
            self.occupied[w] |= m;
            self.len += 1;
            self.slots[id.0 as usize] = f();
        }
        &mut self.slots[id.0 as usize]
    }

    /// Remove: clears membership and takes the value out, leaving a
    /// fresh `Default` in the slot (`BTreeMap::remove` contract).
    pub fn take(&mut self, id: ClientId) -> Option<T> {
        let (w, m) = Self::word(id);
        if self.occupied.get(w).is_some_and(|&b| b & m != 0) {
            self.occupied[w] &= !m;
            self.len -= 1;
            Some(std::mem::take(&mut self.slots[id.0 as usize]))
        } else {
            None
        }
    }

    /// Drop membership WITHOUT touching the slot, retaining its storage
    /// for an allocation-free `or_default` reactivation. Contract: the
    /// caller may only retire a slot whose value is Default-equivalent
    /// (drained deque, zeroed counter) — otherwise stale state would
    /// resurrect on reactivation. Returns whether the id was present.
    pub fn retire(&mut self, id: ClientId) -> bool {
        let (w, m) = Self::word(id);
        if self.occupied.get(w).is_some_and(|&b| b & m != 0) {
            self.occupied[w] &= !m;
            self.len -= 1;
            true
        } else {
            false
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Remove every entry, resetting occupied slots to `Default`
    /// (`BTreeMap::clear` semantics). O(capacity/64 + occupied).
    pub fn clear(&mut self) {
        for (w, bits) in self.occupied.iter_mut().enumerate() {
            let mut b = *bits;
            while b != 0 {
                let i = b.trailing_zeros() as usize;
                self.slots[(w << 6) | i] = T::default();
                b &= b - 1;
            }
            *bits = 0;
        }
        self.len = 0;
    }

    /// Visit present entries in ascending id order — bit-identical to
    /// `BTreeMap<ClientId, T>` ascending-key iteration.
    pub fn for_each(&self, f: &mut dyn FnMut(ClientId, &T)) {
        for (w, &bits) in self.occupied.iter().enumerate() {
            let mut b = bits;
            while b != 0 {
                let i = b.trailing_zeros() as usize;
                let idx = (w << 6) | i;
                f(ClientId(idx as u32), &self.slots[idx]);
                b &= b - 1;
            }
        }
    }

    /// Mutable ascending visit.
    pub fn for_each_mut(&mut self, f: &mut dyn FnMut(ClientId, &mut T)) {
        for (w, &bits) in self.occupied.iter().enumerate() {
            let mut b = bits;
            while b != 0 {
                let i = b.trailing_zeros() as usize;
                let idx = (w << 6) | i;
                f(ClientId(idx as u32), &mut self.slots[idx]);
                b &= b - 1;
            }
        }
    }

    /// Ascending iterator over present entries.
    pub fn iter(&self) -> SlabIter<'_, T> {
        SlabIter { slab: self, word: 0, bits: self.occupied.first().copied().unwrap_or(0) }
    }

    /// Slots allocated (one per id in `0..=max_id` ever touched).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Resident heap bytes of the slab itself (slot array + bitset) —
    /// the bytes-per-idle-tenant numerator in `benches/scale.rs`. Does
    /// not chase per-slot heap (e.g. deque buffers).
    pub fn bytes_resident(&self) -> usize {
        self.slots.capacity() * std::mem::size_of::<T>()
            + self.occupied.capacity() * std::mem::size_of::<u64>()
    }
}

/// Ascending `(ClientId, &T)` iterator over a slab's present entries.
#[derive(Debug)]
pub struct SlabIter<'a, T> {
    slab: &'a ClientSlab<T>,
    word: usize,
    bits: u64,
}

impl<'a, T> Iterator for SlabIter<'a, T> {
    type Item = (ClientId, &'a T);

    fn next(&mut self) -> Option<(ClientId, &'a T)> {
        while self.bits == 0 {
            self.word += 1;
            self.bits = *self.slab.occupied.get(self.word)?;
        }
        let i = self.bits.trailing_zeros() as usize;
        self.bits &= self.bits - 1;
        let idx = (self.word << 6) | i;
        Some((ClientId(idx as u32), &self.slab.slots[idx]))
    }
}

/// Uniform per-client map interface over the dense slab and the
/// pointer-chasing `BTreeMap` reference. Schedulers are generic over a
/// [`ClientMapFamily`], so the slab-vs-BTreeMap differential in
/// `tests/scale.rs` / `benches/scale.rs` runs the IDENTICAL algorithm
/// on both storages — any divergence is a storage bug, any speedup is
/// the storage layer alone.
pub trait ClientMap<T: Default>: std::fmt::Debug + Default + Send {
    fn get(&self, id: ClientId) -> Option<&T>;
    fn get_mut(&mut self, id: ClientId) -> Option<&mut T>;
    /// Insert or overwrite, returning the previous value.
    fn insert(&mut self, id: ClientId, value: T) -> Option<T>;
    /// Entry-or-default; slab backends retain retired storage.
    fn or_default(&mut self, id: ClientId) -> &mut T;
    /// Entry-or-insert-with: both backends run `f` under exactly the
    /// same condition (absence), so initialisation is bit-identical.
    fn or_insert_with(&mut self, id: ClientId, f: impl FnOnce() -> T) -> &mut T;
    /// Remove, returning the value (slot resets to `Default`).
    fn take(&mut self, id: ClientId) -> Option<T>;
    /// Drop membership; slab backends keep the slot's storage, so only
    /// Default-equivalent values may be retired (see `ClientSlab`).
    fn retire(&mut self, id: ClientId);
    fn contains(&self, id: ClientId) -> bool;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn clear(&mut self);
    /// Ascending-id visit — identical order on both backends.
    fn for_each(&self, f: &mut dyn FnMut(ClientId, &T));
    fn for_each_mut(&mut self, f: &mut dyn FnMut(ClientId, &mut T));
}

impl<T: Default + std::fmt::Debug + Send> ClientMap<T> for ClientSlab<T> {
    fn get(&self, id: ClientId) -> Option<&T> {
        ClientSlab::get(self, id)
    }

    fn get_mut(&mut self, id: ClientId) -> Option<&mut T> {
        ClientSlab::get_mut(self, id)
    }

    fn insert(&mut self, id: ClientId, value: T) -> Option<T> {
        ClientSlab::insert(self, id, value)
    }

    fn or_default(&mut self, id: ClientId) -> &mut T {
        ClientSlab::or_default(self, id)
    }

    fn or_insert_with(&mut self, id: ClientId, f: impl FnOnce() -> T) -> &mut T {
        ClientSlab::or_insert_with(self, id, f)
    }

    fn take(&mut self, id: ClientId) -> Option<T> {
        ClientSlab::take(self, id)
    }

    fn retire(&mut self, id: ClientId) {
        ClientSlab::retire(self, id);
    }

    fn contains(&self, id: ClientId) -> bool {
        ClientSlab::contains(self, id)
    }

    fn len(&self) -> usize {
        ClientSlab::len(self)
    }

    fn clear(&mut self) {
        ClientSlab::clear(self)
    }

    fn for_each(&self, f: &mut dyn FnMut(ClientId, &T)) {
        ClientSlab::for_each(self, f)
    }

    fn for_each_mut(&mut self, f: &mut dyn FnMut(ClientId, &mut T)) {
        ClientSlab::for_each_mut(self, f)
    }
}

impl<T: Default + std::fmt::Debug + Send> ClientMap<T> for BTreeMap<ClientId, T> {
    fn get(&self, id: ClientId) -> Option<&T> {
        BTreeMap::get(self, &id)
    }

    fn get_mut(&mut self, id: ClientId) -> Option<&mut T> {
        BTreeMap::get_mut(self, &id)
    }

    fn insert(&mut self, id: ClientId, value: T) -> Option<T> {
        BTreeMap::insert(self, id, value)
    }

    fn or_default(&mut self, id: ClientId) -> &mut T {
        self.entry(id).or_default()
    }

    fn or_insert_with(&mut self, id: ClientId, f: impl FnOnce() -> T) -> &mut T {
        self.entry(id).or_insert_with(f)
    }

    fn take(&mut self, id: ClientId) -> Option<T> {
        self.remove(&id)
    }

    fn retire(&mut self, id: ClientId) {
        self.remove(&id);
    }

    fn contains(&self, id: ClientId) -> bool {
        self.contains_key(&id)
    }

    fn len(&self) -> usize {
        BTreeMap::len(self)
    }

    fn clear(&mut self) {
        BTreeMap::clear(self)
    }

    fn for_each(&self, f: &mut dyn FnMut(ClientId, &T)) {
        for (&c, v) in self.iter() {
            f(c, v);
        }
    }

    fn for_each_mut(&mut self, f: &mut dyn FnMut(ClientId, &mut T)) {
        for (&c, v) in self.iter_mut() {
            f(c, v);
        }
    }
}

/// Storage-family selector (GAT): pick the concrete `ClientMap` for
/// every value type a scheduler needs. `SlabFamily` is the production
/// hot path; `BTreeFamily` is the retained like-for-like reference.
pub trait ClientMapFamily: std::fmt::Debug + Default + Clone + 'static {
    type Map<T: Default + std::fmt::Debug + Send>: ClientMap<T>;
    /// Short label for bench/test output ("slab" / "btree").
    const LABEL: &'static str;
}

/// Dense `ClientSlab` storage — the production configuration.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SlabFamily;

impl ClientMapFamily for SlabFamily {
    type Map<T: Default + std::fmt::Debug + Send> = ClientSlab<T>;
    const LABEL: &'static str = "slab";
}

/// `BTreeMap` storage — the retained reference the scale bench and the
/// zero-drift tests compare against.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct BTreeFamily;

impl ClientMapFamily for BTreeFamily {
    type Map<T: Default + std::fmt::Debug + Send> = BTreeMap<ClientId, T>;
    const LABEL: &'static str = "btree";
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::VecDeque;

    #[test]
    fn iteration_is_ascending_across_word_boundaries() {
        let mut s: ClientSlab<u32> = ClientSlab::new();
        for id in [1000u32, 64, 0, 63, 65, 127, 128] {
            s.insert(ClientId(id), id * 10);
        }
        let got: Vec<(u32, u32)> = s.iter().map(|(c, &v)| (c.0, v)).collect();
        assert_eq!(
            got,
            vec![(0, 0), (63, 630), (64, 640), (65, 650), (127, 1270), (128, 1280), (1000, 10000)]
        );
        let mut visited = Vec::new();
        s.for_each(&mut |c, &v| visited.push((c.0, v)));
        assert_eq!(visited, got);
    }

    #[test]
    fn insert_take_contains_match_btreemap_contract() {
        let mut s: ClientSlab<u64> = ClientSlab::new();
        assert_eq!(s.insert(ClientId(7), 70), None);
        assert_eq!(s.insert(ClientId(7), 71), Some(70));
        assert_eq!(s.len(), 1);
        assert!(s.contains(ClientId(7)));
        assert!(!s.contains(ClientId(6)));
        assert_eq!(s.take(ClientId(7)), Some(71));
        assert_eq!(s.take(ClientId(7)), None);
        assert!(s.is_empty());
        // Slot was reset to Default by take.
        assert_eq!(*s.or_default(ClientId(7)), 0);
    }

    #[test]
    fn retire_retains_storage_for_allocation_free_reactivation() {
        let mut s: ClientSlab<VecDeque<u64>> = ClientSlab::new();
        let q = s.or_default(ClientId(3));
        for i in 0..32 {
            q.push_back(i);
        }
        q.clear();
        let cap = s.get(ClientId(3)).unwrap().capacity();
        assert!(cap >= 32);
        s.retire(ClientId(3));
        assert!(!s.contains(ClientId(3)));
        assert_eq!(s.len(), 0);
        // Reactivation sees an empty deque with the old buffer intact.
        let q = s.or_default(ClientId(3));
        assert!(q.is_empty());
        assert!(q.capacity() >= cap);
    }

    #[test]
    fn or_insert_with_runs_init_only_when_absent() {
        let mut s: ClientSlab<f64> = ClientSlab::new();
        let mut calls = 0;
        *s.or_insert_with(ClientId(9), || {
            calls += 1;
            2.5
        }) += 1.0;
        assert_eq!(*s.get(ClientId(9)).unwrap(), 3.5);
        s.or_insert_with(ClientId(9), || {
            calls += 1;
            99.0
        });
        assert_eq!(calls, 1, "init must not rerun while present");
        // After take (value removed), init reruns; after retire it also
        // reruns — retire only retires Default-equivalent values.
        s.take(ClientId(9));
        assert_eq!(*s.or_insert_with(ClientId(9), || 7.0), 7.0);
    }

    #[test]
    fn clear_resets_values_to_default() {
        let mut s: ClientSlab<u64> = ClientSlab::new();
        s.insert(ClientId(1), 11);
        s.insert(ClientId(130), 12);
        s.clear();
        assert!(s.is_empty());
        assert!(!s.contains(ClientId(1)));
        assert_eq!(*s.or_default(ClientId(130)), 0, "clear must not leak old values");
    }

    #[test]
    fn bytes_resident_scales_with_max_id() {
        let mut s: ClientSlab<u64> = ClientSlab::new();
        s.insert(ClientId(999), 1);
        assert!(s.capacity() == 1000);
        // 1000 slots * 8B + ceil(1000/64) words * 8B.
        assert!(s.bytes_resident() >= 1000 * 8 + 16 * 8);
    }

    /// Random op sequences through the `ClientMap` trait must leave the
    /// slab and a `BTreeMap` observably identical — the unit-level form
    /// of the repo-wide zero-drift contract.
    #[test]
    fn slab_matches_btreemap_under_random_ops() {
        fn drive<M: ClientMap<u64>>(m: &mut M, rng: &mut Rng) -> Vec<(u32, u64)> {
            for step in 0..4000u64 {
                let id = ClientId(rng.below(300) as u32);
                match rng.below(8) {
                    0 => {
                        m.insert(id, step);
                    }
                    1 => {
                        *m.or_default(id) += step;
                    }
                    2 => {
                        m.or_insert_with(id, || step * 3);
                    }
                    3 => {
                        m.take(id);
                    }
                    4 => {
                        if let Some(v) = m.get_mut(id) {
                            *v ^= 0xa5;
                        }
                    }
                    5 => {
                        // retire only Default-equivalent values, per the
                        // slab contract.
                        if m.get(id) == Some(&0) {
                            m.retire(id);
                        }
                    }
                    6 => {
                        assert_eq!(m.contains(id), m.get(id).is_some());
                    }
                    _ => {
                        if rng.chance(0.01) {
                            m.clear();
                        }
                    }
                }
            }
            let mut out = Vec::new();
            m.for_each(&mut |c, &v| out.push((c.0, v)));
            assert_eq!(out.len(), m.len());
            out
        }
        let mut slab: ClientSlab<u64> = ClientSlab::new();
        let mut tree: BTreeMap<ClientId, u64> = BTreeMap::new();
        let a = drive(&mut slab, &mut Rng::new(0xfeed));
        let b = drive(&mut tree, &mut Rng::new(0xfeed));
        assert_eq!(a, b, "slab and BTreeMap diverged under identical ops");
    }

    #[test]
    fn with_capacity_presizes_without_membership() {
        let mut s: ClientSlab<u64> = ClientSlab::with_capacity(1 << 20);
        assert_eq!(s.len(), 0);
        assert_eq!(s.capacity(), 1 << 20);
        let bytes = s.bytes_resident();
        s.insert(ClientId((1 << 20) - 1), 5);
        assert_eq!(s.bytes_resident(), bytes, "in-range insert must not grow");
    }
}
