//! The request model: what flows from the frontend through the queues,
//! scheduler, batcher and engine.

/// Identifies a tenant (the paper's "client"/"user" f).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClientId(pub u32);

impl std::fmt::Display for ClientId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "c{}", self.0)
    }
}

/// Unique id assigned by the frontend at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

impl std::fmt::Display for RequestId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "r{}", self.0)
    }
}

/// Lifecycle of a request inside the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Validated, waiting in its client queue.
    Queued,
    /// Admitted to the running batch; prefill not yet complete.
    Prefilling,
    /// Prefill done; emitting output tokens.
    Decoding,
    /// All output tokens produced.
    Finished,
    /// Dropped by admission control or cancelled.
    Rejected,
}

/// A single inference request plus the measurements the schedulers and
/// metrics layers need. Times are in seconds on the experiment clock.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub client: ClientId,
    /// Prompt length in tokens (known at admission — prefill is parallel).
    pub input_tokens: u32,
    /// True output length. In simulation this is drawn by the workload
    /// generator; the schedulers must NOT read it (only predictors may,
    /// to model their error); the engine uses it as the stop condition.
    pub true_output_tokens: u32,
    /// Predictor's estimate of the output length (0 until predicted).
    pub predicted_output_tokens: u32,
    /// Predicted per-request metrics attached by `Predictor::map` —
    /// Algorithm 1 line 5.
    pub predicted_latency: f64,
    pub predicted_gpu_util: f64,
    pub predicted_tps: f64,
    /// Priority weight ω_f of the owning client, stamped by the workload
    /// generator from `ClientSpec::weight` (default 1.0). Carried on the
    /// request so it reaches admission without a side-channel client
    /// registry: the fairness counters read it at `charge_admission` /
    /// `update_ufc_on_admit` and store it per client. Entitlement
    /// semantics (weighted fair queuing / weighted VTC): a client with
    /// ω=2 is charged half per token, so counter equalisation delivers it
    /// ~2× the service of an ω=1 peer under contention.
    pub weight: f64,
    /// Arrival time at the server queue (Algorithm 1 line 6).
    pub arrival: f64,
    /// When the first output token was emitted (TTFT = first_token - arrival).
    pub first_token_at: Option<f64>,
    /// Completion time.
    pub finished_at: Option<f64>,
    /// Decode progress (output tokens emitted so far).
    pub generated: u32,
    pub state: RequestState,
    /// Prompt text; present only on the real-runtime path (simulator
    /// requests carry lengths only).
    pub prompt: Option<String>,
}

impl Request {
    pub fn new(id: RequestId, client: ClientId, input_tokens: u32, true_output_tokens: u32, arrival: f64) -> Self {
        Request {
            id,
            client,
            input_tokens,
            true_output_tokens,
            predicted_output_tokens: 0,
            predicted_latency: 0.0,
            predicted_gpu_util: 0.0,
            predicted_tps: 0.0,
            weight: 1.0,
            arrival,
            first_token_at: None,
            finished_at: None,
            generated: 0,
            state: RequestState::Queued,
            prompt: None,
        }
    }

    /// Weighted service for fairness accounting, matching the paper's UFC
    /// pricing weights: input + 4·output. VTC in the original paper uses
    /// the same form with provider pricing weights; we use 4 throughout so
    /// the schedulers compete on an identical service definition.
    pub fn weighted_tokens(&self) -> f64 {
        self.input_tokens as f64 + 4.0 * self.true_output_tokens as f64
    }

    /// Weighted service by *predicted* output (what the scheduler can see).
    pub fn predicted_weighted_tokens(&self) -> f64 {
        self.input_tokens as f64 + 4.0 * self.predicted_output_tokens as f64
    }

    /// Total context length at end of decode (KV footprint driver).
    pub fn max_context(&self) -> u32 {
        self.input_tokens + self.true_output_tokens
    }

    /// Time-to-first-token, if the request reached decode.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrival)
    }

    /// End-to-end latency, if finished.
    pub fn e2e(&self) -> Option<f64> {
        self.finished_at.map(|t| t - self.arrival)
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, RequestState::Finished | RequestState::Rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request::new(RequestId(1), ClientId(0), 100, 400, 10.0)
    }

    #[test]
    fn weighted_tokens_uses_4x_output() {
        let r = req();
        assert_eq!(r.weighted_tokens(), 100.0 + 4.0 * 400.0);
    }

    #[test]
    fn predicted_weighted_uses_prediction() {
        let mut r = req();
        r.predicted_output_tokens = 100;
        assert_eq!(r.predicted_weighted_tokens(), 500.0);
    }

    #[test]
    fn ttft_and_e2e() {
        let mut r = req();
        assert_eq!(r.ttft(), None);
        r.first_token_at = Some(12.5);
        r.finished_at = Some(20.0);
        assert_eq!(r.ttft(), Some(2.5));
        assert_eq!(r.e2e(), Some(10.0));
    }

    #[test]
    fn lifecycle_flags() {
        let mut r = req();
        assert!(!r.is_done());
        r.state = RequestState::Finished;
        assert!(r.is_done());
        r.state = RequestState::Rejected;
        assert!(r.is_done());
    }

    #[test]
    fn max_context_sums_phases() {
        assert_eq!(req().max_context(), 500);
    }

    #[test]
    fn display_ids() {
        assert_eq!(ClientId(3).to_string(), "c3");
        assert_eq!(RequestId(9).to_string(), "r9");
    }
}
