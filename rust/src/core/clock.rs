//! Clock abstraction so the same coordinator code runs against the
//! discrete-event simulator (virtual time) and the real engine (wall time).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Seconds since the experiment epoch.
pub trait Clock: Send + Sync {
    fn now(&self) -> f64;
}

/// Wall-clock time relative to construction. Used by the real runtime path.
pub struct SystemClock {
    start: Instant,
}

impl SystemClock {
    pub fn new() -> Self {
        SystemClock { start: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Manually-advanced clock for the simulator and tests. Stores seconds as
/// nanosecond ticks in an atomic so it is `Sync` without locks.
pub struct ManualClock {
    ns: AtomicU64,
}

impl ManualClock {
    pub fn new() -> Self {
        ManualClock { ns: AtomicU64::new(0) }
    }

    pub fn set(&self, t: f64) {
        debug_assert!(t >= 0.0);
        self.ns.store((t * 1e9) as u64, Ordering::Relaxed);
    }

    pub fn advance(&self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.ns.fetch_add((dt * 1e9) as u64, Ordering::Relaxed);
    }
}

impl Default for ManualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for ManualClock {
    fn now(&self) -> f64 {
        self.ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_set_and_advance() {
        let c = ManualClock::new();
        assert_eq!(c.now(), 0.0);
        c.set(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance(0.25);
        assert!((c.now() - 1.75).abs() < 1e-9);
    }

    #[test]
    fn system_clock_monotone() {
        let c = SystemClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }
}
