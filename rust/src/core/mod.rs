//! Core domain types shared by every layer: requests, clients, clocks.

pub mod clock;
pub mod request;

pub use clock::{Clock, ManualClock, SystemClock};
pub use request::{ClientId, Request, RequestId, RequestState};
