//! Core domain types shared by every layer: requests, clients, clocks,
//! and the dense per-client slab storage the hot paths run on.

pub mod clock;
pub mod request;
pub mod slab;

pub use clock::{Clock, ManualClock, SystemClock};
pub use request::{ClientId, Request, RequestId, RequestState};
pub use slab::{BTreeFamily, ClientMap, ClientMapFamily, ClientSlab, SlabFamily};
