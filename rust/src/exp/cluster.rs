//! Extra experiment: the multi-replica cluster rollup table — router
//! policies compared on the heterogeneous fleet under cluster-scale
//! heavy-hitter load (EXPERIMENTS.md §Cluster). This is the experiment
//! behind the subsystem's headline claim: fairness-aware routing keeps
//! the cluster-wide co-backlogged discrepancy bounded where count-blind
//! placement lets it grow with platform heterogeneity.

use super::{f, table, ExpOpts, PredKind, SchedKind};
use crate::cluster::{run_cluster, ClusterOpts, Fleet, RouterKind};
use crate::harness::cluster::cluster_trace;

pub fn cluster(opts: &ExpOpts) -> String {
    let mut out = String::new();
    for fleet in [Fleet::homogeneous(4), Fleet::hetero()] {
        let trace = cluster_trace("heavy_hitter", fleet.len(), opts.quick, opts.seed);
        let mut rows = Vec::new();
        for router in [
            RouterKind::RoundRobin,
            RouterKind::JoinShortestQueue,
            RouterKind::PredictedCost,
            RouterKind::FairShare,
        ] {
            let copts = ClusterOpts::new(opts.seed);
            let res = run_cluster(
                fleet.clone(),
                router.make(),
                SchedKind::Equinox,
                PredKind::Mope,
                &trace,
                &copts,
            );
            let lat = res.merged_latency();
            rows.push(vec![
                router.label().to_string(),
                format!("{}/{}", res.finished(), res.total_requests()),
                f(lat.ttft_mean()),
                f(lat.ttft_p(0.9)),
                f(res.weighted_tps()),
                f(res.mean_gpu_util()),
                f(res.max_co_backlogged_diff()),
                res.preemptions().to_string(),
                res.syncs.to_string(),
            ]);
        }
        out.push_str(&format!(
            "fleet {} — heavy_hitter at {}× single-engine load, Equinox+MoPE per replica\n",
            fleet.name,
            2 * fleet.len()
        ));
        out.push_str(&table(
            &[
                "router",
                "finished",
                "TTFT-avg",
                "TTFT-p90",
                "wtok/s",
                "util",
                "max-disc",
                "preempt",
                "syncs",
            ],
            &rows,
        ));
        out.push('\n');
    }
    out.push_str(
        "Reading: RoundRobin ignores that 40GB replicas drain slower, so co-backlogged\n\
         discrepancy grows with heterogeneity; FairShare balances predicted backlog\n\
         seconds under the global dual-counter plane and keeps it bounded.\n",
    );
    out
}
