//! Extra experiment: the multi-replica cluster rollup table — router
//! policies compared on the heterogeneous fleet under cluster-scale
//! heavy-hitter load (EXPERIMENTS.md §Cluster). This is the experiment
//! behind the subsystem's headline claim: fairness-aware routing keeps
//! the cluster-wide co-backlogged discrepancy bounded where count-blind
//! placement lets it grow with platform heterogeneity.

use super::{f, table, ExpOpts, PredKind, SchedKind};
use crate::cluster::{run_cluster, ClusterOpts, DriveMode, Fleet, RouterKind};
use crate::harness::autoscale::{autoscale_policy, AUTOSCALE_POLICIES};
use crate::harness::cluster::{cluster_scenario, cluster_trace};
use crate::util::json::Json;

/// All four routers, in registry order.
const ALL_ROUTERS: [RouterKind; 4] = [
    RouterKind::RoundRobin,
    RouterKind::JoinShortestQueue,
    RouterKind::PredictedCost,
    RouterKind::FairShare,
];

pub fn cluster(opts: &ExpOpts) -> String {
    let mut out = String::new();
    for fleet in [Fleet::homogeneous(4), Fleet::hetero()] {
        let trace = cluster_trace("heavy_hitter", fleet.len(), opts.quick, opts.seed);
        let mut rows = Vec::new();
        for router in ALL_ROUTERS {
            // Parallel drive: bit-exact vs serial (tests/parallel_driver.rs),
            // so experiment output is identical — just regenerated faster.
            let copts =
                ClusterOpts::new(opts.seed).with_drive(DriveMode::Parallel { threads: 0 });
            let res = run_cluster(
                fleet.clone(),
                router.make(),
                SchedKind::Equinox,
                PredKind::Mope,
                &trace,
                &copts,
            );
            let lat = res.merged_latency();
            rows.push(vec![
                router.label().to_string(),
                format!("{}/{}", res.finished(), res.total_requests()),
                f(lat.ttft_mean()),
                f(lat.ttft_p(0.9)),
                f(res.weighted_tps()),
                f(res.mean_gpu_util()),
                f(res.max_co_backlogged_diff()),
                res.preemptions().to_string(),
                res.syncs.to_string(),
            ]);
        }
        out.push_str(&format!(
            "fleet {} — heavy_hitter at {}× single-engine load, Equinox+MoPE per replica\n",
            fleet.name,
            2 * fleet.len()
        ));
        out.push_str(&table(
            &[
                "router",
                "finished",
                "TTFT-avg",
                "TTFT-p90",
                "wtok/s",
                "util",
                "max-disc",
                "preempt",
                "syncs",
            ],
            &rows,
        ));
        out.push('\n');
    }
    out.push_str(
        "Reading: RoundRobin ignores that 40GB replicas drain slower, so co-backlogged\n\
         discrepancy grows with heterogeneity; FairShare balances predicted backlog\n\
         seconds under the global dual-counter plane and keeps it bounded.\n",
    );
    out
}

/// The ROADMAP's sync-period sensitivity figure: how does global-counter
/// staleness degrade cross-replica fairness, per router? Sweeps the
/// plane's sync period over {0.25, 0.5, 1, 2, 5, 10} s on the
/// heterogeneous fleet under cluster-scale heavy-hitter load, recording
/// the final co-backlogged discrepancy and merged-HF spread per point.
/// Emits `EXP_sync_sweep.json` (discrepancy-vs-staleness, one series per
/// router) for plotting.
pub fn sync_sweep(opts: &ExpOpts) -> String {
    const PERIODS: [f64; 6] = [0.25, 0.5, 1.0, 2.0, 5.0, 10.0];
    let fleet = Fleet::hetero();
    let trace = cluster_trace("heavy_hitter", fleet.len(), opts.quick, opts.seed);
    let mut out = String::new();
    let mut series = Vec::new();
    for router in ALL_ROUTERS {
        let mut rows = Vec::new();
        let mut points = Vec::new();
        for &period in &PERIODS {
            let copts = ClusterOpts {
                sync_period: period,
                drive: DriveMode::Parallel { threads: 0 },
                ..ClusterOpts::new(opts.seed)
            };
            let res = run_cluster(
                fleet.clone(),
                router.make(),
                SchedKind::Equinox,
                PredKind::Mope,
                &trace,
                &copts,
            );
            let disc = res.max_co_backlogged_diff();
            let spread = res.global_hf_spread();
            rows.push(vec![
                f(period),
                res.syncs.to_string(),
                f(disc),
                f(spread),
                f(res.jain_over_service()),
                f(res.weighted_tps()),
            ]);
            points.push(
                Json::obj()
                    .set("sync_s", period)
                    .set("syncs", res.syncs)
                    .set("max_disc", disc)
                    .set("hf_spread", spread)
                    .set("jain_service", res.jain_over_service())
                    .set("weighted_tps", res.weighted_tps()),
            );
        }
        out.push_str(&format!(
            "router {} — fleet {}, heavy_hitter at {}× single-engine load\n",
            router.label(),
            fleet.name,
            2 * fleet.len()
        ));
        out.push_str(&table(
            &["sync s", "syncs", "max-disc", "hf-spread", "jain", "wtok/s"],
            &rows,
        ));
        out.push('\n');
        series.push(
            Json::obj().set("router", router.label()).set("points", Json::Arr(points)),
        );
    }
    let doc = Json::obj()
        .set("scenario", "heavy_hitter")
        .set("fleet", fleet.name.as_str())
        .set("quick", opts.quick)
        .set("seed", opts.seed)
        .set(
            "periods",
            Json::Arr(PERIODS.iter().map(|&s| Json::Num(s)).collect()),
        )
        .set("routers", Json::Arr(series));
    match std::fs::write("EXP_sync_sweep.json", doc.to_string()) {
        Ok(()) => out.push_str("wrote EXP_sync_sweep.json\n"),
        Err(e) => out.push_str(&format!("EXP_sync_sweep.json not written: {e}\n")),
    }
    out.push_str(
        "Reading: routing decisions read counters up to one sync period stale, so the\n\
         discrepancy/HF-spread columns grow with the period — fastest for count-blind\n\
         routers, slowest for FairShare, whose KV filter and backlog balancing do not\n\
         depend on the plane. The knee locates the cheapest sync period that still\n\
         preserves the bounded-discrepancy claim under heterogeneity.\n",
    );
    out
}

/// The autoscale elasticity table (EXPERIMENTS.md §Autoscale): the
/// minimal two-replica fleet under a flash crowd, compared across the
/// three scale policies — static (`off`), a pre-planned grow/drain
/// schedule, and the reactive backlog controller. Post-spike discrepancy
/// is measured from the end of the burst (3/4 of the horizon), the
/// window where a static fleet is still digesting its backlog while a
/// scaled fleet has already re-converged. Emits `EXP_autoscale.json`.
pub fn autoscale(opts: &ExpOpts) -> String {
    let fleet = Fleet::minimal();
    let scenario = "flash_crowd";
    let horizon = cluster_scenario(scenario, opts.quick)
        .expect("flash_crowd is a cluster scenario")
        .duration;
    let post_spike = 0.75 * horizon;
    let trace = cluster_trace(scenario, fleet.len(), opts.quick, opts.seed);

    let mut out = String::new();
    let mut rows = Vec::new();
    let mut arms = Vec::new();
    for policy_name in AUTOSCALE_POLICIES {
        let policy =
            autoscale_policy(policy_name, horizon).expect("registered autoscale policy");
        // Parallel drive: bit-exact vs serial under every policy
        // (tests/autoscale.rs), so output is identical — just faster.
        let copts = ClusterOpts::new(opts.seed)
            .with_drive(DriveMode::Parallel { threads: 0 })
            .with_autoscale(policy);
        let res = run_cluster(
            fleet.clone(),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &copts,
        );
        let lat = res.merged_latency();
        let disc_post = res.max_co_backlogged_diff_after(post_spike);
        let final_replicas =
            res.fleet_epochs.last().map(|(_, s)| s.len()).unwrap_or(fleet.len());
        rows.push(vec![
            policy_name.to_string(),
            format!("{}/{}", res.finished(), res.total_requests()),
            f(lat.ttft_p(0.9)),
            f(res.weighted_tps()),
            f(res.mean_gpu_util()),
            f(disc_post),
            res.scale_transitions.to_string(),
            final_replicas.to_string(),
        ]);
        arms.push(
            Json::obj()
                .set("policy", policy_name)
                .set("finished", res.finished())
                .set("total", res.total_requests())
                .set("ttft_p90", lat.ttft_p(0.9))
                .set("weighted_tps", res.weighted_tps())
                .set("mean_gpu_util", res.mean_gpu_util())
                .set("post_spike_disc", disc_post)
                .set("scale_transitions", res.scale_transitions)
                .set("final_replicas", final_replicas)
                .set("digest", format!("0x{:016x}", res.digest())),
        );
    }
    out.push_str(&format!(
        "fleet {} — {} at {}× single-engine load, FairShare + Equinox + MoPE,\n\
         post-spike discrepancy from t = {:.0}s (burst end)\n",
        fleet.name,
        scenario,
        2 * fleet.len(),
        post_spike
    ));
    out.push_str(&table(
        &[
            "policy",
            "finished",
            "TTFT-p90",
            "wtok/s",
            "util",
            "post-disc",
            "scale-ops",
            "final-N",
        ],
        &rows,
    ));
    out.push('\n');
    let doc = Json::obj()
        .set("scenario", scenario)
        .set("fleet", fleet.name.as_str())
        .set("quick", opts.quick)
        .set("seed", opts.seed)
        .set("post_spike_t0", post_spike)
        .set("policies", Json::Arr(arms));
    match std::fs::write("EXP_autoscale.json", doc.to_string()) {
        Ok(()) => out.push_str("wrote EXP_autoscale.json\n"),
        Err(e) => out.push_str(&format!("EXP_autoscale.json not written: {e}\n")),
    }
    out.push_str(
        "Reading: the static minimal fleet spends the burst hopelessly backlogged and\n\
         its post-spike co-backlogged discrepancy reflects the long drain; both scale\n\
         policies add an A100-80GB mid-burst, shortening the post-spike window, then\n\
         drain it back through orphan migration with service conserved exactly. The\n\
         epoch-weighted util column stays honest across fleet changes — busy time is\n\
         divided by replica-membership seconds, not final fleet size × wall-clock.\n",
    );
    out
}

/// The observability experiment (EXPERIMENTS.md §Observability): the
/// heavy-hitter cluster cell on the heterogeneous fleet run three ways —
/// recorder off, recorder on (serial), recorder on (parallel) — with
/// the event census by kind, the tracing wall-clock overhead, and the
/// three determinism checks: tracing is a pure observer (cluster digest
/// unchanged), and the trace digest is drive-mode invariant. Emits
/// `EXP_trace_overhead.json`.
pub fn trace_overhead(opts: &ExpOpts) -> String {
    use crate::obs::TraceCfg;
    let fleet = Fleet::hetero();
    let scenario = "heavy_hitter";
    let trace = cluster_trace(scenario, fleet.len(), opts.quick, opts.seed);
    let run = |tc: Option<TraceCfg>, drive: DriveMode| {
        let mut copts = ClusterOpts::new(opts.seed).with_drive(drive);
        if let Some(tc) = tc {
            copts = copts.with_trace(tc);
        }
        let t0 = std::time::Instant::now();
        let res = run_cluster(
            fleet.clone(),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &copts,
        );
        (t0.elapsed().as_secs_f64(), res)
    };
    let (wall_off, res_off) = run(None, DriveMode::Serial);
    let (wall_on, res_on) = run(Some(TraceCfg::default()), DriveMode::Serial);
    let (_, res_par) = run(Some(TraceCfg::default()), DriveMode::Parallel { threads: 2 });
    let log = res_on.trace.as_ref().expect("tracing enabled");
    let par_log = res_par.trace.as_ref().expect("tracing enabled");

    let mut census: std::collections::BTreeMap<&'static str, u64> = Default::default();
    for ev in &log.events {
        *census.entry(ev.kind.label()).or_insert(0) += 1;
    }
    let rows: Vec<Vec<String>> =
        census.iter().map(|(k, n)| vec![k.to_string(), n.to_string()]).collect();

    let overhead = wall_on / wall_off.max(1e-9);
    let observer_ok = res_off.digest() == res_on.digest();
    let drive_ok = log.digest() == par_log.digest();
    let score_label = crate::exp::make_sched(SchedKind::Equinox, 1.0).score_label();
    let mut out = format!(
        "fleet {} — {scenario} at {}× single-engine load, FairShare + Equinox + MoPE\n\
         {} events recorded ({} dropped), ring capacity {} per track; \
         pick/window scores are `{score_label}` (Scheduler::score_label)\n",
        fleet.name,
        2 * fleet.len(),
        log.events.len(),
        log.dropped,
        TraceCfg::default().capacity
    );
    out.push_str(&table(&["event", "count"], &rows));
    out.push('\n');
    out.push_str(&format!(
        "recorder off {:.3}s, on {:.3}s — {overhead:.3}x tracing overhead (bar: ≤1.05x)\n\
         observer check (cluster digest off == on): {}\n\
         drive check (trace digest serial == parallel2): {}\n",
        wall_off,
        wall_on,
        if observer_ok { "PASS" } else { "FAIL" },
        if drive_ok { "PASS" } else { "FAIL" }
    ));
    let doc = Json::obj()
        .set("scenario", scenario)
        .set("fleet", fleet.name.as_str())
        .set("quick", opts.quick)
        .set("seed", opts.seed)
        .set("events", log.events.len())
        .set("dropped", log.dropped)
        .set("wall_off_s", wall_off)
        .set("wall_on_s", wall_on)
        .set("overhead", overhead)
        .set("observer_ok", observer_ok)
        .set("drive_ok", drive_ok)
        .set("score_label", score_label)
        .set("trace_digest", format!("0x{:016x}", log.digest()))
        .set(
            "census",
            Json::Obj(
                census
                    .iter()
                    .map(|(k, &n)| (k.to_string(), Json::Num(n as f64)))
                    .collect(),
            ),
        );
    match std::fs::write("EXP_trace_overhead.json", doc.to_string()) {
        Ok(()) => out.push_str("wrote EXP_trace_overhead.json\n"),
        Err(e) => out.push_str(&format!("EXP_trace_overhead.json not written: {e}\n")),
    }
    out.push_str(
        "Reading: recording is a ring write per event behind one hoisted `enabled()`\n\
         check, so the overhead column should sit within noise of 1.0x; the digest\n\
         checks are the observability contract — tracing never perturbs the run, and\n\
         the merged (time, track, seq) event order is identical under both drivers.\n",
    );
    out
}
