//! Motivation experiments: Fig 1 (equal tokens ≠ equal service), Fig 2
//! (latency monotone / throughput non-monotone / util steps), Fig 16
//! (the same curves across host profiles).

use super::{f, run_sim, table, ExpOpts, PredKind, SchedKind};
use crate::sim::{HostProfile, SimConfig};
use crate::workload::{generate, Scenario, Trace};

/// Fig 1: equal aggregate token demand split as many-short vs few-long.
pub fn fig1(opts: &ExpOpts) -> String {
    let mut out = String::from(
        "Fig 1 — equal total tokens, different shapes (client0: 8 rps × (25,100); client1: 1 rps × (200,800))\n",
    );
    let trace = generate(&Scenario::equal_tokens_short_vs_long(opts.secs(120.0)), opts.seed);
    for (label, max_batch) in [("with batching", 256usize), ("no batching", 1usize)] {
        let mut cfg = SimConfig::a100_7b_vllm();
        cfg.host.max_batch = max_batch;
        let res = run_sim(&cfg, SchedKind::Fcfs, PredKind::Oracle, &trace, opts.seed);
        let mut rows = Vec::new();
        for c in res.service.clients() {
            let lat = res.per_client_latency.get(c).expect("served client has latency stats");
            rows.push(vec![
                format!("{c}"),
                f(lat.ttft_mean()),
                f(lat.e2e_mean()),
                f(res.service.total(c) / res.wall),
            ]);
        }
        out.push_str(&format!("\n[{label}] GPU util {:.2}, total {:.0} tok/s\n", res.gpu_util, res.output_tps));
        out.push_str(&table(&["client", "mean TTFT (s)", "mean e2e (s)", "service rate (wtok/s)"], &rows));
    }
    out.push_str(
        "\nEqual token totals give divergent latency/service — token count is not a fairness metric.\n",
    );
    out
}

/// Fig 2: sweep tokens/request with fixed total token supply, 1:1 in:out.
pub fn fig2(opts: &ExpOpts) -> String {
    fig2_curves(opts, HostProfile::VLLM, "Fig 2 — A100-80GB · Llama-2-7b (vllm-like host)")
}

/// Fig 16: identical sweep on the other host profiles.
pub fn fig16(opts: &ExpOpts) -> String {
    let mut out = String::new();
    out.push_str(&fig2_curves(opts, HostProfile::VLLM, "Fig 16 — vLLM profile"));
    out.push('\n');
    out.push_str(&fig2_curves(opts, HostProfile::SGLANG, "Fig 16 — SGLang profile"));
    out.push_str(
        "\nSame non-linear latency, non-monotone throughput and stepped util on both hosts —\nthe patterns are architectural, not implementation artifacts (paper Fig 16).\n",
    );
    out
}

fn fig2_curves(opts: &ExpOpts, host: HostProfile, title: &str) -> String {
    // Fixed total token supply: RPS × tokens-per-request = const.
    // 1:1 input:output. Saturating supply so measured throughput reflects
    // capacity, per the paper's setup notes under Fig 2.
    let supply = 6000.0; // tokens/s offered
    let sizes: &[u32] = if opts.quick {
        &[64, 256, 1024, 4096]
    } else {
        &[32, 64, 128, 256, 512, 1024, 2048, 4096]
    };
    let mut rows = Vec::new();
    for &size in sizes {
        let inp = size / 2;
        let outp = size - inp;
        let rps = supply / size as f64;
        let sc = Scenario {
            name: "fig2",
            clients: vec![crate::workload::ClientSpec::fixed(
                crate::workload::Arrival::Poisson,
                crate::workload::arrivals::ArrivalProcess::Constant(rps),
                inp,
                outp,
            )],
            duration: opts.secs(120.0),
        };
        let trace = generate(&sc, opts.seed);
        let cfg = SimConfig::a100_7b_vllm().with_host(host);
        let res = run_sim(&cfg, SchedKind::Fcfs, PredKind::Oracle, &trace, opts.seed);
        // Mean per-request e2e latency; throughput in total tokens/s;
        // util averaged over busy windows.
        let served = res.output_tps + prefill_tps(&trace, &res);
        rows.push(vec![
            size.to_string(),
            f(rps),
            f(res.latency.e2e_mean()),
            f(served),
            f(res.gpu_util),
        ]);
    }
    let mut out = format!("{title}\nfixed supply {supply} tok/s, 1:1 in:out, FCFS\n");
    out.push_str(&table(
        &["tokens/req", "rps", "mean e2e (s)", "served tok/s", "GPU util"],
        &rows,
    ));
    out.push_str("\nExpected shape: latency ↑ monotone; served tok/s rises then falls; util steps up.\n");
    out
}

/// Total served tokens/s (input + output) — the throughput the paper plots.
fn prefill_tps(trace: &Trace, res: &crate::sim::SimResult) -> f64 {
    let frac = res.finished as f64 / trace.len().max(1) as f64;
    let total_in: f64 = trace.requests.iter().map(|r| r.input_tokens as f64).sum();
    total_in * frac / res.wall
}

/// Fig 2a companion (single-request latency curve, used by tests).
pub fn latency_curve(sizes: &[u32]) -> Vec<(u32, f64)> {
    let gpu = crate::sim::GpuModel::a100_7b();
    sizes
        .iter()
        .map(|&s| {
            let half = (s / 2).max(1) as u64;
            let prefill = gpu.prefill(half).time;
            let decode: f64 = (0..half).map(|i| gpu.decode_step(1, half + i).time).sum();
            (s, prefill + decode)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_curve_monotone() {
        let c = latency_curve(&[64, 256, 1024, 4096]);
        for w in c.windows(2) {
            assert!(w[1].1 > w[0].1, "{c:?}");
        }
    }

    #[test]
    fn fig1_reports_divergent_clients() {
        let out = fig1(&ExpOpts::quick());
        assert!(out.contains("c0") && out.contains("c1"));
        assert!(out.contains("with batching") && out.contains("no batching"));
    }

    #[test]
    fn fig2_throughput_non_monotone() {
        let out = fig2(&ExpOpts::quick());
        // Parse the served tok/s column and check rise-then-fall.
        let vals: Vec<f64> = out
            .lines()
            .filter(|l| l.starts_with("| ") && !l.contains("tokens/req"))
            .filter_map(|l| {
                let cells: Vec<&str> = l.split('|').map(|c| c.trim()).collect();
                cells.get(4).and_then(|c| c.parse().ok())
            })
            .collect();
        assert!(vals.len() >= 4, "{out}");
        let max = vals.iter().cloned().fold(f64::MIN, f64::max);
        let max_idx = vals.iter().position(|&v| v == max).unwrap();
        assert!(max_idx > 0, "throughput should rise first: {vals:?}\n{out}");
        assert!(
            *vals.last().unwrap() < max,
            "throughput should fall at large sizes: {vals:?}\n{out}"
        );
    }
}
