//! `equinox exp conformance` — the scheduler × scenario × step-mode
//! conformance matrix as an experiment runner: one row per cell with the
//! invariant verdicts (see `crate::harness` and EXPERIMENTS.md
//! §Conformance matrix).

use super::{table, ExpOpts};
use crate::harness::{self, ConformanceOpts};

pub fn conformance(opts: &ExpOpts) -> String {
    let copts =
        ConformanceOpts { quick: opts.quick, base_seed: opts.seed, ..ConformanceOpts::default() };
    let cells = harness::run_matrix(&copts, &harness::MODES);
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.scenario.clone(),
                c.scheduler.clone(),
                c.mode.to_string(),
                format!("{}/{}", c.finished, c.total),
                c.preemptions.to_string(),
                format!("{:.0}", c.max_disc),
                format!("{:.0}", c.disc_bound),
                format!("{:.3}", c.jain_service),
                if c.passed() { "ok".into() } else { format!("FAIL ({})", c.violations.len()) },
            ]
        })
        .collect();
    let failed = cells.iter().filter(|c| !c.passed()).count();
    let mut out = table(
        &["scenario", "scheduler", "mode", "done", "preempt", "max-disc", "bound", "jain", "verdict"],
        &rows,
    );
    out.push_str(&format!(
        "\n{} cells, {} failed — invariants: completeness, conservation, bounded \
         discrepancy, no-starvation, receipts, macro≡micro, deterministic replay\n",
        cells.len(),
        failed
    ));
    for c in cells.iter().filter(|c| !c.passed()) {
        out.push_str(&format!("  {}: {}\n", c.key(), c.violations.join("; ")));
    }
    out
}
