//! Prediction experiments: Fig 4 (error comparison) and Fig 7 (MoPE
//! design analysis: expert count, resources, router training, overhead).

use super::{f, make_pred, table, ExpOpts, PredKind};
use crate::core::{ClientId, Request, RequestId};
use crate::predictor::{MoPE, MopeConfig, Predictor};
use crate::util::rng::Rng;
use crate::util::stats::percentile;
use crate::workload::tracegen::{LmsysLike, TraceGen};

/// Draw a sample of true output lengths from the LMSYS-like distribution.
fn sample_outputs(n: usize, seed: u64) -> Vec<u32> {
    let gen = LmsysLike::default();
    let mut rng = Rng::new(seed);
    (0..n).map(|_| gen.lengths(&mut rng).1).collect()
}

fn predictions(pred: &mut dyn Predictor, outs: &[u32]) -> Vec<u32> {
    outs.iter()
        .enumerate()
        .map(|(i, &o)| {
            let r = Request::new(RequestId(i as u64), ClientId(0), 50, o, 0.0);
            pred.predict_tokens(&r)
        })
        .collect()
}

fn mae(preds: &[u32], outs: &[u32]) -> f64 {
    preds
        .iter()
        .zip(outs)
        .map(|(&p, &o)| (p as f64 - o as f64).abs())
        .sum::<f64>()
        / outs.len() as f64
}

fn mapes(preds: &[u32], outs: &[u32]) -> Vec<f64> {
    preds
        .iter()
        .zip(outs)
        .map(|(&p, &o)| 100.0 * (p as f64 - o as f64).abs() / (o.max(1) as f64))
        .collect()
}

/// Fig 4: (a) MAPE CDF per predictor; (b) MAE/MAPE by output-length bucket.
pub fn fig4(opts: &ExpOpts) -> String {
    let n = opts.count(20_000);
    let outs = sample_outputs(n, opts.seed);
    let mut out = String::from("Fig 4a — prediction error CDF (MAPE percentiles, %):\n");
    let mut rows = Vec::new();
    for kind in [PredKind::Single, PredKind::MopeExperts(1), PredKind::Mope, PredKind::Oracle] {
        let mut p = make_pred(kind, opts.seed + 1);
        let preds = predictions(p.as_mut(), &outs);
        let mut m = mapes(&preds, &outs);
        m.sort_by(|a, b| a.partial_cmp(b).unwrap());
        rows.push(vec![
            kind.label(),
            f(percentile(&m, 0.5)),
            f(percentile(&m, 0.8)),
            f(percentile(&m, 0.95)),
            f(mae(&preds, &outs)),
        ]);
    }
    out.push_str(&table(&["predictor", "P50 MAPE", "P80 MAPE", "P95 MAPE", "L1/MAE"], &rows));

    out.push_str("\nFig 4b — MAE / MAPE by actual output tokens:\n");
    let buckets: &[(u32, u32)] = &[(1, 53), (53, 210), (210, 512), (512, 1025)];
    let mut rows = Vec::new();
    for kind in [PredKind::Single, PredKind::Mope] {
        let mut p = make_pred(kind, opts.seed + 2);
        let preds = predictions(p.as_mut(), &outs);
        for &(lo, hi) in buckets {
            let idx: Vec<usize> =
                (0..outs.len()).filter(|&i| outs[i] >= lo && outs[i] < hi).collect();
            if idx.is_empty() {
                continue;
            }
            let bp: Vec<u32> = idx.iter().map(|&i| preds[i]).collect();
            let bo: Vec<u32> = idx.iter().map(|&i| outs[i]).collect();
            let mp = mapes(&bp, &bo);
            rows.push(vec![
                kind.label(),
                format!("{lo}-{}", hi - 1),
                f(mae(&bp, &bo)),
                f(crate::util::stats::mean(&mp)),
            ]);
        }
    }
    out.push_str(&table(&["predictor", "output bucket", "MAE", "MAPE %"], &rows));
    out.push_str("\nSingle-proxy error compounds on long outputs; MoPE stays bounded (paper: L1 80 → 33).\n");
    out
}

/// Fig 7: expert count vs error/resources, router accuracy vs training
/// size, and the latency breakdown.
pub fn fig7(opts: &ExpOpts) -> String {
    let n = opts.count(20_000);
    let outs = sample_outputs(n, opts.seed);

    // (a) L1 error by expert count.
    let mut out = String::from("Fig 7a — L1 prediction error vs number of experts:\n");
    let mut rows = Vec::new();
    for experts in [1usize, 3, 5] {
        let mut p = make_pred(PredKind::MopeExperts(experts), opts.seed + 3);
        let preds = predictions(p.as_mut(), &outs);
        rows.push(vec![experts.to_string(), f(mae(&preds, &outs))]);
    }
    out.push_str(&table(&["experts", "L1 error (tokens)"], &rows));

    // (b) resource usage.
    out.push_str("\nFig 7b — resource usage (BF16 experts):\n");
    let mut rows = Vec::new();
    for experts in [1usize, 3, 5, 7] {
        let cfg = MopeConfig { n_experts: experts, ..MopeConfig::default() };
        rows.push(vec![
            experts.to_string(),
            f(cfg.memory_gb()),
            f(cfg.latency_s() * 1e3),
        ]);
    }
    out.push_str(&table(&["experts", "memory (GB)", "latency (ms)"], &rows));

    // (c) router accuracy vs training size. Training size improves the
    // boundary-zone classifier; the saturating map below matches the
    // paper's measured curve (≈74% at 50k, peak ≈80% at 110k).
    out.push_str("\nFig 7c — router accuracy vs training samples:\n");
    let sample: Vec<u32> = sample_outputs(opts.count(30_000), opts.seed + 4);
    let mut rows = Vec::new();
    for nk in [10u64, 30, 50, 70, 90, 110, 120] {
        let acc_cfg = 0.50 + 0.30 * (1.0 - (-(nk as f64) / 32.0).exp());
        let mut m = MoPE::with_config(
            opts.seed + 5,
            MopeConfig { router_accuracy: acc_cfg, ..MopeConfig::default() },
        );
        let measured = m.measure_router_accuracy(&sample);
        rows.push(vec![format!("{nk}k"), f(measured * 100.0)]);
    }
    out.push_str(&table(&["training samples", "router accuracy (%)"], &rows));

    // (d) latency breakdown.
    out.push_str("\nFig 7d — end-to-end latency breakdown:\n");
    let rows = vec![
        vec!["router".into(), "0.02".into()],
        vec!["expert forward".into(), "4.48".into()],
        vec!["MoPE total".into(), "4.50".into()],
        vec!["mean prompt inference".into(), "2400".into()],
        vec!["MoPE overhead".into(), "<1%".into()],
    ];
    out.push_str(&table(&["component", "latency (ms)"], &rows));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_orders_predictors() {
        let out = fig4(&ExpOpts::quick());
        assert!(out.contains("Single") && out.contains("MoPE") && out.contains("Oracle"));
    }

    #[test]
    fn fig7_expert_error_decreases() {
        let opts = ExpOpts::quick();
        let outs = sample_outputs(8_000, opts.seed);
        let maes: Vec<f64> = [1usize, 3, 5]
            .iter()
            .map(|&e| {
                let mut p = make_pred(PredKind::MopeExperts(e), 9);
                mae(&predictions(p.as_mut(), &outs), &outs)
            })
            .collect();
        assert!(maes[0] > maes[1] && maes[1] > maes[2], "{maes:?}");
    }

    #[test]
    fn fig7c_accuracy_increases_with_training() {
        let out = fig7(&ExpOpts::quick());
        let accs: Vec<f64> = out
            .lines()
            .filter(|l| l.contains("k ") && l.starts_with("| 1") || l.starts_with("| 9") || l.starts_with("| 5"))
            .filter_map(|l| l.split('|').nth(2).and_then(|c| c.trim().parse().ok()))
            .collect();
        if accs.len() >= 2 {
            assert!(accs.last().unwrap() >= accs.first().unwrap());
        }
    }
}
