//! Design-choice ablations beyond the paper's Table 1 — the knobs
//! DESIGN.md §Deviations documents, each isolated on the overload
//! workload where they matter. `equinox exp ablations`.

use super::{f, run_sim, table, ExpOpts, PredKind, SchedKind};
use crate::core::ClientId;
use crate::metrics::fairness::summarize_diffs;
use crate::predictor::MoPE;
use crate::sched::counters::HfParams;
use crate::sched::EquinoxSched;
use crate::sim::{HostProfile, SimConfig, Simulation};
use crate::workload::{generate, Scenario, Trace};

fn cfg() -> SimConfig {
    SimConfig::a100_7b_vllm().with_host(HostProfile::SLORA)
}

fn run_with_params(params: HfParams, trace: &Trace, seed: u64) -> crate::sim::SimResult {
    let peak = cfg().gpu.peak_decode_tps(64, 512);
    let mut sched = EquinoxSched::new(params, peak);
    let mut pred = MoPE::new(seed);
    let mut sim = Simulation::new(cfg(), &mut sched, &mut pred);
    sim.run(trace)
}

pub fn ablations(opts: &ExpOpts) -> String {
    let dur = opts.secs(90.0);
    let trace = generate(&Scenario::constant_overload(dur), opts.seed);
    let mut out = String::from("Ablations — Equinox design choices under constant overload\n");

    // (a) β sweep: RFC contribution on/off.
    out.push_str("\n(a) RFC contribution (β) — efficiency nudge vs pure UFC:\n");
    let mut rows = Vec::new();
    for beta in [0.0, 0.15, 0.3, 0.5] {
        let params = HfParams { alpha: 1.0 - beta, beta, ..HfParams::default() };
        let res = run_with_params(params, &trace, opts.seed);
        let s = summarize_diffs(&res.backlogged_diff_series(ClientId(0), ClientId(1)));
        rows.push(vec![
            f(beta),
            f(res.weighted_tps),
            f(res.latency.ttft_mean()),
            f(s.avg),
        ]);
    }
    out.push_str(&table(&["β", "wtok/s", "TTFT mean (s)", "avg diff"], &rows));

    // (b) latency-compensation cap.
    out.push_str("\n(b) compensation cap — bounded vs degenerate discounting:\n");
    let mut rows = Vec::new();
    for cap in [1.0, 2.0, 4.0, 1e9] {
        let params = HfParams { comp_cap: cap, ..HfParams::default() };
        let res = run_with_params(params, &trace, opts.seed);
        let s = summarize_diffs(&res.backlogged_diff_series(ClientId(0), ClientId(1)));
        rows.push(vec![
            if cap > 1e6 { "∞ (paper literal)".into() } else { f(cap) },
            f(res.latency.ttft_p(0.9)),
            f(s.max),
            f(s.avg),
        ]);
    }
    out.push_str(&table(&["cap", "TTFT P90 (s)", "max diff", "avg diff"], &rows));

    // (c) predictor quality under the Equinox policy (stall-free depends
    // on predictions being roughly right).
    out.push_str("\n(c) predictor quality → preemptions and throughput:\n");
    let mut rows = Vec::new();
    for pred in [PredKind::Single, PredKind::Mope, PredKind::Oracle] {
        let res = run_sim(&cfg(), SchedKind::Equinox, pred, &trace, opts.seed);
        rows.push(vec![
            pred.label(),
            res.preemptions.to_string(),
            f(res.weighted_tps),
            f(res.latency.ttft_mean()),
        ]);
    }
    out.push_str(&table(&["predictor", "preemptions", "wtok/s", "TTFT mean (s)"], &rows));

    // (d) system optimizations gate: Equinox policy without its engine
    // optimizations ≈ VTC+pred with HF ordering.
    out.push_str("\n(d) scheduler policy alone vs policy + system optimisations:\n");
    let vtc_pred = run_sim(&cfg(), SchedKind::VtcPred, PredKind::Mope, &trace, opts.seed);
    let eqx = run_sim(&cfg(), SchedKind::Equinox, PredKind::Mope, &trace, opts.seed);
    let rows = vec![
        vec![
            "VTC+MoPE (no sys-opt)".to_string(),
            f(vtc_pred.weighted_tps),
            f(vtc_pred.latency.ttft_mean()),
            vtc_pred.preemptions.to_string(),
        ],
        vec![
            "Equinox (policy+sys-opt)".to_string(),
            f(eqx.weighted_tps),
            f(eqx.latency.ttft_mean()),
            eqx.preemptions.to_string(),
        ],
    ];
    out.push_str(&table(&["variant", "wtok/s", "TTFT mean (s)", "preemptions"], &rows));
    out.push_str(
        "\nTakeaways: β>0 trades a bounded fairness band for throughput; capping the\n\
         compensation denominator is what keeps the band bounded; prediction quality\n\
         drives preemption avoidance; a large share of Equinox's throughput edge is\n\
         the prediction-gated engine optimisations, as §4 claims.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_produce_all_four_tables() {
        let out = ablations(&ExpOpts::quick());
        for marker in ["(a)", "(b)", "(c)", "(d)"] {
            assert!(out.contains(marker), "missing {marker}:\n{out}");
        }
    }
}
