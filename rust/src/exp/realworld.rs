//! Real-world-trace experiments: Fig 11 (SGLang/ShareGPT), Fig 12
//! (vLLM/ShareGPT), Fig 13 (cross-system Jain), Fig 14 (GPU scaling),
//! Fig 15 (α/β sweep), Fig 19 (LMSYS dynamics).

use super::{f, run_sim, table, ExpOpts, PredKind, SchedKind};
use crate::core::ClientId;
use crate::metrics::jain_index;
use crate::sim::{GpuKind, GpuModel, HostProfile, ModelSpec, SimConfig};
use crate::workload::tracegen::{
    lmsys_trace, mixed_tenants_trace, sharegpt_per_client_trace, sharegpt_trace,
};

/// The paper's real-trace testbed: 8×A100-40GB, Llama-2-70b, TP=8.
fn cluster_cfg(host: HostProfile) -> SimConfig {
    SimConfig::a100_7b_vllm()
        .with_gpu(GpuModel::new(GpuKind::A100_40G, ModelSpec::LLAMA2_70B, 8))
        .with_host(host)
}

/// Fig 11: SGLang + ShareGPT; 256 clients, RPS sweep, 1280 prompts.
pub fn fig11(opts: &ExpOpts) -> String {
    let mut out = String::from(
        "Fig 11 — SGLang + ShareGPT (256 clients, 1280 prompts, Llama-2-70b TP8)\n",
    );
    let rps_list: &[f64] = if opts.quick { &[4.0, 16.0] } else { &[1.0, 2.0, 4.0, 8.0, 16.0] };
    let prompts = opts.count(1280);
    let mut rows = Vec::new();
    for &rps in rps_list {
        let trace = sharegpt_trace(256, rps, prompts, opts.seed);
        for kind in [SchedKind::Fcfs, SchedKind::Vtc, SchedKind::Equinox] {
            let pred = if kind == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
            let res = run_sim(&cluster_cfg(HostProfile::SGLANG), kind, pred, &trace, opts.seed);
            rows.push(vec![
                format!("{rps}"),
                kind.label(),
                f(res.latency.ttft_p(0.5)),
                f(res.latency.ttft_p(0.9)),
                f(res.finished as f64 / res.wall),
                f(res.output_tps),
            ]);
        }
    }
    out.push_str(&table(
        &["RPS", "scheduler", "P50 TTFT (s)", "P90 TTFT (s)", "req/s", "out tok/s"],
        &rows,
    ));
    out.push_str("\nAt high RPS Equinox cuts P50/P90 TTFT (paper: up to 30%) with mildly higher throughput (≤25%).\n");
    out
}

/// Fig 12: vLLM + ShareGPT; 1–8 clients × 3.5 rps Poisson, 1000 req each.
pub fn fig12(opts: &ExpOpts) -> String {
    let mut out =
        String::from("Fig 12 — vLLM + ShareGPT (per-client 3.5 rps Poisson, Llama-2-70b TP8)\n");
    let clients_list: &[usize] = if opts.quick { &[2, 8] } else { &[1, 2, 4, 8] };
    let per_client = opts.count(1000);
    let mut rows = Vec::new();
    for &nc in clients_list {
        let trace = sharegpt_per_client_trace(nc, 3.5, per_client, opts.seed);
        for kind in [SchedKind::Fcfs, SchedKind::Vtc, SchedKind::Equinox] {
            let pred = if kind == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
            let res = run_sim(&cluster_cfg(HostProfile::VLLM), kind, pred, &trace, opts.seed);
            let service_rate = res.service.grand_total() / res.wall / nc as f64;
            rows.push(vec![
                nc.to_string(),
                kind.label(),
                f(res.windowed_jain_until(10.0, trace.horizon)),
                f(res.latency.ttft_mean()),
                f(service_rate),
                f(res.latency.e2e_mean()),
            ]);
        }
    }
    out.push_str(&table(
        &["clients", "scheduler", "Jain (10s windows)", "avg TTFT (s)", "per-client rate", "avg e2e (s)"],
        &rows,
    ));
    out.push_str("\nEquinox: higher, more stable Jain (paper: up to +33%), slightly lower TTFT/e2e (~5%).\n");
    out
}

/// Fig 13: Jain's index across S-LoRA / vLLM / SGLang.
pub fn fig13(opts: &ExpOpts) -> String {
    let mut out = String::from("Fig 13 — Jain fairness (over HF) across serving systems\n");
    let mut rows = Vec::new();
    for host in [HostProfile::SLORA, HostProfile::VLLM, HostProfile::SGLANG] {
        // S-LoRA runs the 27-client LMSYS workload (App B); vLLM/SGLang
        // run heterogeneous equal-demand tenants (prefill-heavy vs
        // decode-heavy) — the regime where token fairness and holistic
        // fairness diverge. Homogeneous tenants would score Jain ≈ 1
        // under every scheduler.
        let trace = if host.name == "slora" {
            lmsys_trace(27, opts.secs(300.0), 8.0, opts.seed)
        } else {
            mixed_tenants_trace(4, opts.secs(300.0), opts.seed)
        };
        let cfg = SimConfig::a100_7b_vllm().with_host(host);
        let mut jains = Vec::new();
        for kind in [SchedKind::Fcfs, SchedKind::Vtc, SchedKind::Equinox] {
            let pred = if kind == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
            let res = run_sim(&cfg, kind, pred, &trace, opts.seed);
            // Windowed service-rate Jain during the contended phase —
            // the discriminating fairness view (see fig12); end-of-run
            // Jain over HF is also available via res.jain_over_hf().
            jains.push((kind, res.windowed_jain_until(10.0, trace.horizon)));
        }
        let eqx = jains.iter().find(|(k, _)| *k == SchedKind::Equinox).unwrap().1;
        let best_base = jains
            .iter()
            .filter(|(k, _)| *k != SchedKind::Equinox)
            .map(|(_, j)| *j)
            .fold(f64::MIN, f64::max);
        rows.push(vec![
            host.name.to_string(),
            f(jains[0].1),
            f(jains[1].1),
            f(jains[2].1),
            format!("+{:.0}%", 100.0 * (eqx / best_base - 1.0)),
        ]);
    }
    out.push_str(&table(&["system", "FCFS", "VTC", "Equinox", "Equinox gain"], &rows));
    out.push_str("\nEquinox leads on every host (paper: ~13%); VTC's Jain over HF is no better than FCFS.\n");
    out
}

/// Fig 14: fairness vs GPU count (TP 1–8).
pub fn fig14(opts: &ExpOpts) -> String {
    let mut out = String::from("Fig 14 — Jain fairness scaling GPUs 1→8 (Llama-2-7b, TP=n)\n");
    let gpus: &[u32] = if opts.quick { &[1, 8] } else { &[1, 2, 4, 8] };
    let mut rows = Vec::new();
    for host in [HostProfile::VLLM, HostProfile::SGLANG] {
        for &tp in gpus {
            let cfg = SimConfig::a100_7b_vllm()
                .with_gpu(GpuModel::new(GpuKind::A100_40G, ModelSpec::LLAMA2_7B, tp))
                .with_host(host);
            // Demand scales with the cluster (heterogeneous tenants, see
            // fig13), keeping the utilization point constant across TP —
            // 2 tenant pairs per GPU ≈ 1.2× capacity.
            let trace = mixed_tenants_trace(2 * tp as usize, opts.secs(240.0), opts.seed);
            let mut cells = vec![host.name.to_string(), tp.to_string()];
            for kind in [SchedKind::Fcfs, SchedKind::Vtc, SchedKind::Equinox] {
                let pred =
                    if kind == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
                let res = run_sim(&cfg, kind, pred, &trace, opts.seed);
                cells.push(f(res.windowed_jain_until(10.0, trace.horizon)));
            }
            rows.push(cells);
        }
    }
    out.push_str(&table(&["system", "GPUs", "FCFS", "VTC", "Equinox"], &rows));
    out.push_str("\nEquinox's lead is setup-agnostic across TP degrees (paper §7.5).\n");
    out
}

/// Fig 15: α/β sensitivity at RPS=16 on the SGLang profile.
pub fn fig15(opts: &ExpOpts) -> String {
    let mut out = String::from("Fig 15 — α/β trade-off (SGLang profile, RPS 16)\n");
    let trace = sharegpt_trace(64, 16.0, opts.count(1280), opts.seed);
    let alphas: &[f64] = if opts.quick { &[0.5, 0.7, 0.9] } else { &[0.5, 0.6, 0.7, 0.8, 0.9] };
    let mut samples = Vec::new();
    for &a in alphas {
        let res = run_sim(
            &cluster_cfg(HostProfile::SGLANG),
            SchedKind::EquinoxAlpha(a),
            PredKind::Mope,
            &trace,
            opts.seed,
        );
        // Fairness over per-client P90 TTFT (paper's Fig 15 metric).
        let mut p90s = Vec::new();
        for (_, lat) in res.per_client_latency.iter() {
            if lat.count() >= 3 {
                p90s.push(lat.ttft_p(0.9));
            }
        }
        let fairness = jain_index(&p90s);
        let thr = res.finished as f64 / res.wall;
        samples.push((a, fairness, thr));
    }
    let max_fair = samples.iter().map(|s| s.1).fold(f64::MIN, f64::max);
    let max_thr = samples.iter().map(|s| s.2).fold(f64::MIN, f64::max);
    let rows: Vec<Vec<String>> = samples
        .iter()
        .map(|(a, fair, thr)| {
            vec![
                f(*a),
                f(fair / max_fair),
                f(thr / max_thr),
            ]
        })
        .collect();
    out.push_str(&table(&["α", "norm. fairness (Jain of P90 TTFT)", "norm. throughput"], &rows));
    out.push_str("\nHigher α favours latency fairness, lower α favours throughput; α=0.7 is the knee (paper: 97%/90%).\n");
    out
}

/// Fig 19: LMSYS 27-client workload dynamics on the S-LoRA profile.
pub fn fig19(opts: &ExpOpts) -> String {
    let dur = opts.secs(300.0);
    let trace = lmsys_trace(27, dur, 8.0, opts.seed);
    let cfg = SimConfig::a100_7b_vllm().with_host(HostProfile::SLORA);
    let res = run_sim(&cfg, SchedKind::Equinox, PredKind::Mope, &trace, opts.seed);

    let mut counts: Vec<(ClientId, usize)> = Vec::new();
    for c in 0..27u32 {
        let n = trace.requests.iter().filter(|r| r.client == ClientId(c)).count();
        counts.push((ClientId(c), n));
    }
    counts.sort_by_key(|(_, n)| *n);
    let mut out = format!(
        "Fig 19 — LMSYS-like trace in S-LoRA: {} clients, {} requests over {:.0}s (total rate {:.1} rps)\n",
        trace.num_clients(),
        trace.len(),
        dur,
        trace.len() as f64 / dur
    );
    // Following the paper (and VTC), report the 13/14th and 26/27th
    // clients by request volume.
    let picks = [13usize.min(counts.len() - 1), 14usize.min(counts.len() - 1), counts.len() - 2, counts.len() - 1];
    let mut rows = Vec::new();
    for &i in picks.iter() {
        let (c, n) = counts[i];
        let lat = res.per_client_latency.get(c);
        rows.push(vec![
            format!("{c}"),
            n.to_string(),
            f(lat.map(|l| l.ttft_mean()).unwrap_or(0.0)),
            f(lat.map(|l| l.e2e_mean()).unwrap_or(0.0)),
            f(res.service.total(c) / res.wall),
        ]);
    }
    out.push_str(&table(
        &["client (by volume)", "requests", "mean TTFT (s)", "mean e2e (s)", "service rate"],
        &rows,
    ));
    out.push_str("\nPer-client rates fluctuate with the bursty trace; response times track instantaneous load.\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_reports_three_hosts() {
        let out = fig13(&ExpOpts::quick());
        assert!(out.contains("slora") && out.contains("vllm") && out.contains("sglang"));
    }

    #[test]
    fn fig15_alpha_tradeoff_direction() {
        let out = fig15(&ExpOpts::quick());
        // throughput at α=0.5 should be >= throughput at α=0.9.
        let grab = |alpha: &str| -> Option<f64> {
            out.lines()
                .find(|l| l.starts_with(&format!("| {alpha}")))
                .and_then(|l| l.split('|').nth(3))
                .and_then(|c| c.trim().parse().ok())
        };
        if let (Some(t05), Some(t09)) = (grab("0.500"), grab("0.900")) {
            assert!(t05 >= t09 * 0.95, "throughput α=0.5 {t05} vs α=0.9 {t09}\n{out}");
        }
    }
}
