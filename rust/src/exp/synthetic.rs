//! Synthetic-workload experiments: Fig 5 (worked example), Fig 9
//! (balanced), Fig 10 (stochastic), Table 1 (ablation), Fig 17
//! (overload), Fig 18 (dynamic load).

use super::{f, run_sim, table, ExpOpts, PredKind, SchedKind};
use crate::core::ClientId;
use crate::metrics::fairness::summarize_diffs;
use crate::sim::{SimConfig, SimResult};
use crate::workload::{generate, Scenario, Trace};

/// Fig 5: the worked example — VTC would pick user0 (fewer tokens);
/// Equinox's HF picks user1 (worse latency).
pub fn fig5(_opts: &ExpOpts) -> String {
    use crate::core::{Request, RequestId};
    use crate::sched::{EquinoxSched, Scheduler, Vtc};

    let mk = |id: u64, client: u32, inp: u32, out: u32| {
        let mut r = Request::new(RequestId(id), ClientId(client), inp, out, 0.0);
        r.predicted_output_tokens = out;
        r.predicted_latency = 1.0;
        r.predicted_tps = 1000.0;
        r.predicted_gpu_util = 0.8;
        r
    };
    // History: user0 consumed fewer tokens but was served with low
    // latency; user1 consumed more tokens but waited long.
    let mut vtc = Vtc::new();
    let mut eqx = EquinoxSched::default_params(2600.0);
    for s in [&mut vtc as &mut dyn Scheduler, &mut eqx as &mut dyn Scheduler] {
        s.enqueue(mk(0, 0, 50, 100), 0.0);
        s.enqueue(mk(1, 1, 80, 150), 0.0);
        let a = s.pick(0.0, &mut |_| true).unwrap(); // user0, served promptly
        let b = s.pick(60.0, &mut |_| true).unwrap(); // user1, after 60 s
        s.on_complete(&a, &crate::sched::Actuals { latency: 1.0, gpu_util: 0.8, tps: 1000.0, output_tokens: 100 }, 1.0);
        s.on_complete(&b, &crate::sched::Actuals { latency: 1.5, gpu_util: 0.8, tps: 900.0, output_tokens: 150 }, 61.5);
        // Fresh round, both queue again.
        s.enqueue(mk(3, 1, 80, 150), 62.0);
        s.enqueue(mk(2, 0, 50, 100), 62.0);
    }
    let vtc_pick = vtc.pick(62.0, &mut |_| true).unwrap().client;
    let eqx_pick = eqx.pick(62.0, &mut |_| true).unwrap().client;
    let (hf0, hf1) = (eqx.hf(ClientId(0)), eqx.hf(ClientId(1)));
    let mut out = String::from("Fig 5 — worked example (user0: fewer tokens, low latency; user1: more tokens, 60 s wait)\n");
    out.push_str(&table(
        &["scheduler", "next pick", "why"],
        &[
            vec!["VTC".into(), format!("{vtc_pick}"), "fewer accumulated tokens".into()],
            vec![
                "Equinox".into(),
                format!("{eqx_pick}"),
                format!("HF(user0)={} > HF(user1)={}", f(hf0), f(hf1)),
            ],
        ],
    ));
    out
}

/// Common per-scheduler summary rows for a 2-client scenario.
/// §7.2's synthetic experiments mirror VTC's setup: A100-80GB, Llama-2-7b
/// under S-LoRA — so the S-LoRA host profile applies.
fn scenario_matrix(opts: &ExpOpts, trace: &Trace, title: &str, horizon: f64) -> (String, Vec<(SchedKind, SimResult)>) {
    let cfg = SimConfig::a100_7b_vllm().with_host(crate::sim::HostProfile::SLORA);
    let mut results = Vec::new();
    for kind in [SchedKind::Fcfs, SchedKind::Vtc, SchedKind::Equinox] {
        let pred = if kind == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
        let res = run_sim(&cfg, kind, pred, trace, opts.seed);
        results.push((kind, res));
    }
    let _ = horizon;
    let mut rows = Vec::new();
    for (kind, res) in &results {
        // Bounded-discrepancy metric: service difference accumulated only
        // while both clients are backlogged (the fairness guarantee's
        // domain — VTC §4.2, mirrored by the paper's Figs 9d/10d/17d).
        let diffs = res.backlogged_diff_series(ClientId(0), ClientId(1));
        let s = summarize_diffs(&diffs);
        rows.push(vec![
            kind.label(),
            f(res.latency.ttft_mean()),
            f(res.latency.ttft_p(0.9)),
            f(res.gpu_util),
            f(res.weighted_tps),
            f(res.service.total(ClientId(0)) / res.wall),
            f(res.service.total(ClientId(1)) / res.wall),
            f(s.max),
            f(s.avg),
        ]);
    }
    let mut out = format!("{title}\n");
    out.push_str(&table(
        &[
            "scheduler",
            "TTFT mean (s)",
            "TTFT P90 (s)",
            "GPU util",
            "total rate (wtok/s)",
            "c0 rate",
            "c1 rate",
            "max diff",
            "avg diff",
        ],
        &rows,
    ));
    (out, results)
}

/// Fig 9: balanced load.
pub fn fig9(opts: &ExpOpts) -> String {
    let dur = opts.secs(300.0);
    let trace = generate(&Scenario::balanced_load(dur), opts.seed);
    let (mut out, results) = scenario_matrix(
        opts,
        &trace,
        "Fig 9 — balanced load (C1: 2 rps (100,400); C2: 1 rps (100,900))",
        dur,
    );
    let vtc = results.iter().find(|(k, _)| *k == SchedKind::Vtc).unwrap();
    let eqx = results.iter().find(|(k, _)| *k == SchedKind::Equinox).unwrap();
    out.push_str(&format!(
        "\nEquinox vs VTC: throughput ×{:.2} (paper: up to 1.3×), TTFT {:.0}% lower (paper: up to 60%)\n",
        eqx.1.weighted_tps / vtc.1.weighted_tps,
        100.0 * (1.0 - eqx.1.latency.ttft_mean() / vtc.1.latency.ttft_mean()),
    ));
    out
}

/// Fig 10: Poisson arrivals, prefill-heavy vs decode-heavy clients.
pub fn fig10(opts: &ExpOpts) -> String {
    let dur = opts.secs(120.0);
    let trace = generate(&Scenario::stochastic_arrivals(dur), opts.seed);
    let c0 = trace.requests.iter().filter(|r| r.client == ClientId(0)).count();
    let c1 = trace.len() - c0;
    let (mut out, _) = scenario_matrix(
        opts,
        &trace,
        "Fig 10 — Poisson arrivals (C1: 16 rps prefill-heavy (512,32); C2: 3 rps decode-heavy (32,512))",
        dur,
    );
    out.insert_str(0, &format!("arrivals: c0={c0} c1={c1} over {dur:.0}s\n"));
    out.push_str("\nVTC undervalues C2's long decodes; Equinox's MoPE corrects the bias (smaller diffs).\n");
    out
}

/// Fig 17 (App A): constant extreme overload.
pub fn fig17(opts: &ExpOpts) -> String {
    let dur = opts.secs(120.0);
    let trace = generate(&Scenario::constant_overload(dur), opts.seed);
    let (mut out, results) = scenario_matrix(
        opts,
        &trace,
        "Fig 17 — constant overload (C1: 20 rps (20,180); C2: 2 rps (200,1800))",
        dur,
    );
    for (kind, res) in &results {
        out.push_str(&format!(
            "{}: finished {}/{} preemptions {}\n",
            kind.label(),
            res.finished,
            res.total_requests,
            res.preemptions
        ));
    }
    out.push_str("\nFCFS fails isolation; VTC and Equinox both bound the service gap, Equinox at higher service rate.\n");
    out
}

/// Fig 18 (App A): dynamic load increase at the midpoint.
pub fn fig18(opts: &ExpOpts) -> String {
    let dur = opts.secs(240.0);
    let trace = generate(&Scenario::dynamic_load(dur), opts.seed);
    let cfg = SimConfig::a100_7b_vllm().with_host(crate::sim::HostProfile::SLORA);
    let res = run_sim(&cfg, SchedKind::Equinox, PredKind::Mope, &trace, opts.seed);
    let mut out = String::from(
        "Fig 18 — dynamic load (C1: 1 rps; C2: 1→4 rps at midpoint; both (100,400))\n",
    );
    let mut rows = Vec::new();
    for phase in [(0.25, "before step"), (0.75, "after step")] {
        let t = dur * phase.0;
        let rates = res.service.rates_at(t, dur * 0.2);
        let util = res
            .util_timeline
            .iter()
            .filter(|(tt, _)| (*tt - t).abs() < dur * 0.1)
            .map(|(_, u)| u)
            .sum::<f64>()
            / res
                .util_timeline
                .iter()
                .filter(|(tt, _)| (*tt - t).abs() < dur * 0.1)
                .count()
                .max(1) as f64;
        rows.push(vec![
            phase.1.into(),
            f(*rates.get(&ClientId(0)).unwrap_or(&0.0)),
            f(*rates.get(&ClientId(1)).unwrap_or(&0.0)),
            f(util),
        ]);
    }
    out.push_str(&table(&["phase", "c0 rate (wtok/s)", "c1 rate (wtok/s)", "GPU util"], &rows));
    out.push_str("\nC2's rate rises with its demand while C1 keeps its fair share; util climbs with load.\n");
    out
}

/// Table 1: scheduler × predictor ablation on the stochastic workload.
pub fn table1(opts: &ExpOpts) -> String {
    let dur = opts.secs(120.0);
    let trace = generate(&Scenario::stochastic_arrivals(dur), opts.seed);
    let cfg = SimConfig::a100_7b_vllm().with_host(crate::sim::HostProfile::SLORA);
    let combos: Vec<(&str, SchedKind, PredKind)> = vec![
        ("FCFS", SchedKind::Fcfs, PredKind::Oracle),
        ("VTC", SchedKind::Vtc, PredKind::Oracle),
        ("VTC + Single", SchedKind::VtcPred, PredKind::Single),
        ("VTC + MoPE", SchedKind::VtcPred, PredKind::Mope),
        ("VTC + Oracle", SchedKind::VtcPred, PredKind::Oracle),
        ("Equinox + Single", SchedKind::Equinox, PredKind::Single),
        ("Equinox + MoPE", SchedKind::Equinox, PredKind::Mope),
        ("Equinox + Oracle", SchedKind::Equinox, PredKind::Oracle),
    ];
    let mut rows = Vec::new();
    let mut summaries = Vec::new();
    for (label, sched, pred) in &combos {
        let res = run_sim(&cfg, *sched, *pred, &trace, opts.seed);
        let diffs = res.backlogged_diff_series(ClientId(0), ClientId(1));
        let s = summarize_diffs(&diffs);
        summaries.push((label.to_string(), s));
        rows.push(vec![label.to_string(), f(s.max), f(s.avg), f(s.var)]);
    }
    let mut out = String::from("Table 1 — fairness ablation (service difference, lower is better)\n");
    out.push_str(&table(&["Scheduler Variant", "Max Diff", "Avg Diff", "Diff Var"], &rows));
    out.push_str("\nExpected ordering: FCFS ≥ VTC > VTC+MoPE ≈ VTC+Oracle > Equinox+MoPE ≈ Equinox+Oracle.\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_vtc_and_equinox_disagree() {
        let out = fig5(&ExpOpts::quick());
        assert!(out.contains("VTC") && out.contains("Equinox"));
        // VTC picks c0, Equinox picks c1 (the paper's point).
        let vtc_line = out.lines().find(|l| l.contains("VTC")).unwrap();
        let eqx_line = out.lines().find(|l| l.contains("Equinox")).unwrap();
        assert!(vtc_line.contains("c0"), "{out}");
        assert!(eqx_line.contains("c1"), "{out}");
    }

    #[test]
    fn table1_equinox_mope_beats_vtc() {
        let out = table1(&ExpOpts::quick());
        let grab = |label: &str| -> f64 {
            out.lines()
                .find(|l| l.contains(label))
                .and_then(|l| l.split('|').nth(3))
                .and_then(|c| c.trim().parse().ok())
                .unwrap_or(f64::NAN)
        };
        let vtc = grab("| VTC ");
        let eqx_mope = grab("Equinox + MoPE");
        let eqx_oracle = grab("Equinox + Oracle");
        assert!(eqx_mope < vtc, "Equinox+MoPE avg diff {eqx_mope} !< VTC {vtc}\n{out}");
        assert!(
            eqx_mope < 2.5 * eqx_oracle + 1.0,
            "MoPE should approach Oracle: {eqx_mope} vs {eqx_oracle}\n{out}"
        );
    }

    #[test]
    fn fig9_all_requests_complete() {
        let out = fig9(&ExpOpts::quick());
        assert!(out.contains("Equinox vs VTC"));
    }
}
