//! Experiment harness: one runner per table/figure of the paper's
//! evaluation (§7, Appendices A–B). `equinox exp <id>` regenerates the
//! corresponding rows; `cargo bench --bench paper_tables` runs them all.
//! DESIGN.md's per-experiment index maps ids to workloads and modules.

pub mod ablations;
pub mod cluster;
pub mod conformance;
pub mod mispredict;
pub mod motivation;
pub mod prediction;
pub mod realworld;
pub mod synthetic;

use crate::predictor::{MoPE, MopeConfig, Oracle, Predictor, SingleProxy};
use crate::sched::{EquinoxSched, Fcfs, GuardPolicy, HfParams, Rpm, Scheduler, Vtc};
use crate::sim::{SimConfig, SimResult, Simulation, StepMode};
use crate::workload::Trace;

/// Shared experiment options.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    pub seed: u64,
    /// Shrink durations/sweeps for CI runs.
    pub quick: bool,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts { seed: 42, quick: false }
    }
}

impl ExpOpts {
    pub fn quick() -> Self {
        ExpOpts { seed: 42, quick: true }
    }

    /// Scale a duration: full length normally, 1/5 in quick mode.
    pub fn secs(&self, full: f64) -> f64 {
        if self.quick {
            (full / 5.0).max(10.0)
        } else {
            full
        }
    }

    pub fn count(&self, full: usize) -> usize {
        if self.quick {
            (full / 5).max(8)
        } else {
            full
        }
    }
}

/// Scheduler selection for experiment matrices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedKind {
    Fcfs,
    Rpm,
    Vtc,
    /// VTC charging by predicted output at admission (Table 1 rows).
    VtcPred,
    /// VTC+pred with the online calibration guard attached.
    VtcPredGuarded(GuardPolicy),
    Equinox,
    EquinoxAlpha(f64),
    /// Equinox with the online calibration guard attached.
    EquinoxGuarded(GuardPolicy),
}

impl SchedKind {
    pub fn label(&self) -> String {
        match self {
            SchedKind::Fcfs => "FCFS".into(),
            SchedKind::Rpm => "RPM".into(),
            SchedKind::Vtc => "VTC".into(),
            SchedKind::VtcPred => "VTC+pred".into(),
            SchedKind::VtcPredGuarded(p) => format!("VTC+pred+{}", p.label()),
            SchedKind::Equinox => "Equinox".into(),
            SchedKind::EquinoxAlpha(a) => format!("Equinox(α={a})"),
            SchedKind::EquinoxGuarded(p) => format!("Equinox+{}", p.label()),
        }
    }
}

/// Predictor selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PredKind {
    Oracle,
    Single,
    Mope,
    MopeExperts(usize),
    MopeRouterAcc(f64),
}

impl PredKind {
    pub fn label(&self) -> String {
        match self {
            PredKind::Oracle => "Oracle".into(),
            PredKind::Single => "Single".into(),
            PredKind::Mope => "MoPE".into(),
            PredKind::MopeExperts(n) => format!("MoPE-{n}"),
            PredKind::MopeRouterAcc(a) => format!("MoPE(acc={a})"),
        }
    }
}

pub fn make_sched(kind: SchedKind, peak_tps: f64) -> Box<dyn Scheduler> {
    match kind {
        SchedKind::Fcfs => Box::new(Fcfs::new()),
        SchedKind::Rpm => Box::new(Rpm::new(120, 60.0)),
        SchedKind::Vtc => Box::new(Vtc::new()),
        SchedKind::VtcPred => Box::new(Vtc::with_predictions()),
        SchedKind::VtcPredGuarded(p) => Box::new(Vtc::with_predictions_guarded(p)),
        SchedKind::Equinox => Box::new(EquinoxSched::default_params(peak_tps)),
        SchedKind::EquinoxAlpha(a) => Box::new(EquinoxSched::new(
            crate::sched::counters::HfParams::with_alpha(a),
            peak_tps,
        )),
        SchedKind::EquinoxGuarded(p) => {
            Box::new(EquinoxSched::with_guard(HfParams::default(), peak_tps, p))
        }
    }
}

pub fn make_pred(kind: PredKind, seed: u64) -> Box<dyn Predictor> {
    match kind {
        PredKind::Oracle => Box::new(Oracle::new()),
        PredKind::Single => Box::new(SingleProxy::new(seed)),
        PredKind::Mope => Box::new(MoPE::new(seed)),
        PredKind::MopeExperts(n) => Box::new(MoPE::with_config(
            seed,
            MopeConfig { n_experts: n, ..MopeConfig::default() },
        )),
        PredKind::MopeRouterAcc(a) => Box::new(MoPE::with_config(
            seed,
            MopeConfig { router_accuracy: a, ..MopeConfig::default() },
        )),
    }
}

/// Run one (scheduler, predictor, trace) combination. Uses the config's
/// step mode — macro-stepping by default, which is why full paper-table
/// regenerations are O(events) rather than O(tokens) in engine work.
pub fn run_sim(cfg: &SimConfig, sched: SchedKind, pred: PredKind, trace: &Trace, seed: u64) -> SimResult {
    let peak = cfg.gpu.peak_decode_tps(64, 512);
    let mut scheduler = make_sched(sched, peak);
    let mut predictor = make_pred(pred, seed);
    let mut sim = Simulation::new(cfg.clone(), scheduler.as_mut(), predictor.as_mut());
    sim.run(trace)
}

/// `run_sim` under an explicit step mode — the macro/micro differential
/// harness (`tests/macro_stepping.rs`, `benches/simulator.rs`) pins both
/// sides of the comparison through this.
pub fn run_sim_stepped(
    cfg: &SimConfig,
    mode: StepMode,
    sched: SchedKind,
    pred: PredKind,
    trace: &Trace,
    seed: u64,
) -> SimResult {
    run_sim(&cfg.clone().with_step_mode(mode), sched, pred, trace, seed)
}

/// An experiment: id, paper artifact, runner.
pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub run: fn(&ExpOpts) -> String,
}

/// The registry, in paper order.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment { id: "fig1", paper_ref: "Fig 1 — token-count unfairness motivation", run: motivation::fig1 },
        Experiment { id: "fig2", paper_ref: "Fig 2 — latency/throughput/util vs token count", run: motivation::fig2 },
        Experiment { id: "fig4", paper_ref: "Fig 4 — prediction error: single vs MoPE", run: prediction::fig4 },
        Experiment { id: "fig5", paper_ref: "Fig 5 — HF worked example (VTC vs Equinox pick)", run: synthetic::fig5 },
        Experiment { id: "fig7", paper_ref: "Fig 7 — MoPE design analysis", run: prediction::fig7 },
        Experiment { id: "fig9", paper_ref: "Fig 9 — balanced load scenario", run: synthetic::fig9 },
        Experiment { id: "fig10", paper_ref: "Fig 10 — Poisson arrivals scenario", run: synthetic::fig10 },
        Experiment { id: "fig11", paper_ref: "Fig 11 — SGLang + ShareGPT (TTFT, throughput)", run: realworld::fig11 },
        Experiment { id: "fig12", paper_ref: "Fig 12 — vLLM + ShareGPT (Jain, TTFT, service)", run: realworld::fig12 },
        Experiment { id: "fig13", paper_ref: "Fig 13 — cross-system fairness", run: realworld::fig13 },
        Experiment { id: "fig14", paper_ref: "Fig 14 — fairness scalability (1–8 GPUs)", run: realworld::fig14 },
        Experiment { id: "fig15", paper_ref: "Fig 15 — α/β sensitivity", run: realworld::fig15 },
        Experiment { id: "table1", paper_ref: "Table 1 — scheduler × predictor ablation", run: synthetic::table1 },
        Experiment { id: "fig16", paper_ref: "Fig 16 — cross-host motivation curves", run: motivation::fig16 },
        Experiment { id: "fig17", paper_ref: "Fig 17 — constant overload (App A)", run: synthetic::fig17 },
        Experiment { id: "fig18", paper_ref: "Fig 18 — dynamic load increase (App A)", run: synthetic::fig18 },
        Experiment { id: "fig19", paper_ref: "Fig 19 — LMSYS trace dynamics (App B)", run: realworld::fig19 },
        Experiment { id: "ablations", paper_ref: "Extra — design-choice ablations (DESIGN.md §Deviations)", run: ablations::ablations },
        Experiment { id: "conformance", paper_ref: "Extra — scheduler×scenario conformance matrix (EXPERIMENTS.md §Conformance)", run: conformance::conformance },
        Experiment { id: "cluster", paper_ref: "Extra — multi-replica fleet: router policy rollups (EXPERIMENTS.md §Cluster)", run: cluster::cluster },
        Experiment { id: "sync-sweep", paper_ref: "Extra — sync-period sensitivity: discrepancy vs counter staleness per router (EXPERIMENTS.md §Parallel driver)", run: cluster::sync_sweep },
        Experiment { id: "autoscale", paper_ref: "Extra — replica autoscaling: static vs scheduled vs reactive under a flash crowd (EXPERIMENTS.md §Autoscale)", run: cluster::autoscale },
        Experiment { id: "trace-overhead", paper_ref: "Extra — flight recorder: tracing overhead, event census, cross-drive trace determinism (EXPERIMENTS.md §Observability)", run: cluster::trace_overhead },
        Experiment { id: "mispredict", paper_ref: "Extra — misprediction resilience: degradation × mitigation table (EXPERIMENTS.md §Misprediction)", run: mispredict::mispredict },
    ]
}

pub fn find(id: &str) -> Option<Experiment> {
    registry().into_iter().find(|e| e.id == id)
}

/// Markdown-ish table formatting helper used by all runners.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        line.trim_end().to_string()
    };
    let hdr: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&format!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    ));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

pub fn f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else if x.abs() >= 0.1 {
        format!("{x:.3}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_paper_artifact() {
        let ids: Vec<&str> = registry().iter().map(|e| e.id).collect();
        for want in [
            "fig1", "fig2", "fig4", "fig5", "fig7", "fig9", "fig10", "fig11", "fig12",
            "fig13", "fig14", "fig15", "table1", "fig16", "fig17", "fig18", "fig19",
        ] {
            assert!(ids.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn table_formats_aligned() {
        let t = table(&["a", "bb"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a"));
        assert!(t.lines().count() == 3);
    }

    #[test]
    fn find_returns_experiment() {
        assert!(find("fig9").is_some());
        assert!(find("nope").is_none());
    }
}
