//! The misprediction-resilience experiment (EXPERIMENTS.md
//! §Misprediction): the degradation × mitigation table. Every
//! prediction-fault plan runs against raw Equinox, always-debiased
//! Equinox, and the full hysteresis ladder on the heavy-hitter cluster
//! cell, reporting the whole-run co-backlogged discrepancy, guard
//! transitions, and final guard mode per replica. Emits
//! `EXP_mispredict.json`.

use super::{f, table, ExpOpts, PredKind};
use crate::cluster::{run_cluster, ClusterOpts, DriveMode, Fleet, RouterKind};
use crate::harness::mispredict::{
    mispredict_horizon, mispredict_plan, mispredict_trace, mitigation_sched,
    MISPREDICT_MITIGATIONS, MISPREDICT_PLANS,
};
use crate::obs::{EventKind, TraceCfg};
use crate::sched::GuardMode;
use crate::util::json::Json;

pub fn mispredict(opts: &ExpOpts) -> String {
    let fleet = Fleet::homogeneous(2);
    let scenario = "heavy_hitter";
    let trace = mispredict_trace(scenario, fleet.len(), opts.quick, opts.seed);
    let horizon = mispredict_horizon(scenario, opts.quick);

    let mut rows = Vec::new();
    let mut arms = Vec::new();
    for plan_name in MISPREDICT_PLANS {
        let plan = mispredict_plan(plan_name, horizon, opts.seed)
            .expect("registered mispredict plan");
        for mitigation in MISPREDICT_MITIGATIONS {
            let sched = mitigation_sched(mitigation).expect("registered mitigation");
            // Parallel drive: bit-exact vs serial under every plan
            // (harness/mispredict.rs pins this), so output is identical
            // — just faster.
            let copts = ClusterOpts::new(opts.seed)
                .with_drive(DriveMode::Parallel { threads: 0 })
                .with_pred_faults(plan.clone())
                .with_trace(TraceCfg::default());
            let res = run_cluster(
                fleet.clone(),
                RouterKind::FairShare.make(),
                sched,
                PredKind::Mope,
                &trace,
                &copts,
            );
            let log = res.trace.as_ref().expect("tracing enabled");
            let guard_transitions = log
                .events
                .iter()
                .filter(|e| matches!(e.kind, EventKind::GuardTransition { .. }))
                .count() as u64;
            let modes: Vec<String> = res
                .guard_health
                .iter()
                .map(|h| match h {
                    Some(h) => h.mode.label().to_string(),
                    None => "—".to_string(),
                })
                .collect();
            let disc = res.max_co_backlogged_diff();
            let lat = res.merged_latency();
            rows.push(vec![
                plan_name.to_string(),
                mitigation.to_string(),
                format!("{}/{}", res.finished(), res.total_requests()),
                f(disc),
                f(lat.ttft_p(0.9)),
                guard_transitions.to_string(),
                modes.join(","),
            ]);
            arms.push(
                Json::obj()
                    .set("plan", plan_name)
                    .set("mitigation", mitigation)
                    .set("finished", res.finished())
                    .set("total", res.total_requests())
                    .set("max_disc", disc)
                    .set("ttft_p90", lat.ttft_p(0.9))
                    .set("guard_transitions", guard_transitions)
                    .set(
                        "final_modes",
                        Json::Arr(
                            res.guard_health
                                .iter()
                                .map(|h| match h {
                                    Some(h) => Json::Str(h.mode.label().into()),
                                    None => Json::Str("unguarded".into()),
                                })
                                .collect(),
                        ),
                    )
                    .set("digest", format!("0x{:016x}", res.digest())),
            );
        }
    }

    let mut out = format!(
        "fleet {} — {scenario} at {}× single-engine load, FairShare + MoPE;\n\
         guard modes: {}/{}/{} (per replica, end of run)\n",
        fleet.name,
        2 * fleet.len(),
        GuardMode::Predictive.label(),
        GuardMode::Debiased.label(),
        GuardMode::ActualOnly.label()
    );
    out.push_str(&table(
        &["plan", "mitigation", "finished", "max-disc", "TTFT-p90", "guard-trans", "final-modes"],
        &rows,
    ));
    out.push('\n');
    let doc = Json::obj()
        .set("scenario", scenario)
        .set("fleet", fleet.name.as_str())
        .set("quick", opts.quick)
        .set("seed", opts.seed)
        .set("cells", Json::Arr(arms));
    match std::fs::write("EXP_mispredict.json", doc.to_string()) {
        Ok(()) => out.push_str("wrote EXP_mispredict.json\n"),
        Err(e) => out.push_str(&format!("EXP_mispredict.json not written: {e}\n")),
    }
    out.push_str(
        "Reading: the clean rows are the control — all three mitigations track each\n\
         other and the guard stays silent. Under the 2× bias storm the raw scheduler's\n\
         admission charges are systematically inflated against output-heavy tenants and\n\
         its co-backlogged gap widens; the debiased column cancels the bias online and\n\
         lands strictly lower. The blackout row shows the ladder stepping down to\n\
         actual-only charging while one MoPE regime returns garbage, then climbing back\n\
         to predictive once calibration returns — every move is a GuardTransition event\n\
         in the flight-recorder trace.\n",
    );
    out
}
