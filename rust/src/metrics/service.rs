//! Per-client service accounting: weighted-token service curves, windowed
//! service rates (Fig 9c/10c/17c), and the accumulated absolute service
//! difference between clients (Fig 9d/10d/17d, Table 1).

use crate::core::ClientId;
use std::collections::BTreeMap;

/// A single client's cumulative weighted-token service over time.
#[derive(Debug, Clone, Default)]
pub struct ServiceCurve {
    /// (time, cumulative weighted tokens), non-decreasing in both fields.
    pub points: Vec<(f64, f64)>,
}

impl ServiceCurve {
    pub fn record(&mut self, t: f64, delta: f64) {
        let prev = self.points.last().map(|p| p.1).unwrap_or(0.0);
        self.points.push((t, prev + delta));
    }

    pub fn total(&self) -> f64 {
        self.points.last().map(|p| p.1).unwrap_or(0.0)
    }

    /// Cumulative service at time t (step interpolation).
    pub fn at(&self, t: f64) -> f64 {
        match self.points.binary_search_by(|p| p.0.partial_cmp(&t).unwrap()) {
            Ok(i) => self.points[i].1,
            Err(0) => 0.0,
            Err(i) => self.points[i - 1].1,
        }
    }

    /// Service rate over [t-window, t].
    pub fn rate(&self, t: f64, window: f64) -> f64 {
        if window <= 0.0 {
            return 0.0;
        }
        (self.at(t) - self.at(t - window)) / window
    }
}

/// Tracks service for all clients plus the pairwise difference series.
#[derive(Debug, Default)]
pub struct ServiceTracker {
    curves: BTreeMap<ClientId, ServiceCurve>,
}

impl ServiceTracker {
    pub fn new() -> Self {
        ServiceTracker { curves: BTreeMap::new() }
    }

    pub fn record(&mut self, client: ClientId, t: f64, weighted_tokens: f64) {
        self.curves.entry(client).or_default().record(t, weighted_tokens);
    }

    pub fn clients(&self) -> Vec<ClientId> {
        self.curves.keys().cloned().collect()
    }

    pub fn curve(&self, client: ClientId) -> Option<&ServiceCurve> {
        self.curves.get(&client)
    }

    pub fn total(&self, client: ClientId) -> f64 {
        self.curves.get(&client).map(|c| c.total()).unwrap_or(0.0)
    }

    /// Total service across all clients.
    pub fn grand_total(&self) -> f64 {
        self.curves.values().map(|c| c.total()).sum()
    }

    /// Sampled |service_a - service_b| series between two clients, at
    /// `samples` uniform times over [0, horizon]. This is the quantity the
    /// paper plots as "accumulated service difference".
    pub fn diff_series(&self, a: ClientId, b: ClientId, horizon: f64, samples: usize) -> Vec<f64> {
        let ca = self.curves.get(&a);
        let cb = self.curves.get(&b);
        (1..=samples)
            .map(|i| {
                let t = horizon * i as f64 / samples as f64;
                let va = ca.map(|c| c.at(t)).unwrap_or(0.0);
                let vb = cb.map(|c| c.at(t)).unwrap_or(0.0);
                (va - vb).abs()
            })
            .collect()
    }

    /// Max pairwise diff series across ALL client pairs (multi-tenant
    /// generalisation used for >2-client workloads).
    pub fn max_pairwise_diff_series(&self, horizon: f64, samples: usize) -> Vec<f64> {
        let ids = self.clients();
        (1..=samples)
            .map(|i| {
                let t = horizon * i as f64 / samples as f64;
                let vals: Vec<f64> =
                    ids.iter().map(|id| self.curves[id].at(t)).collect();
                let max = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
                if vals.is_empty() {
                    0.0
                } else {
                    max - min
                }
            })
            .collect()
    }

    /// Per-client service rates over a trailing window at time t.
    pub fn rates_at(&self, t: f64, window: f64) -> BTreeMap<ClientId, f64> {
        self.curves.iter().map(|(id, c)| (*id, c.rate(t, window))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_accumulates() {
        let mut c = ServiceCurve::default();
        c.record(1.0, 10.0);
        c.record(2.0, 5.0);
        assert_eq!(c.total(), 15.0);
        assert_eq!(c.at(0.5), 0.0);
        assert_eq!(c.at(1.0), 10.0);
        assert_eq!(c.at(1.5), 10.0);
        assert_eq!(c.at(3.0), 15.0);
    }

    #[test]
    fn rate_is_windowed_delta() {
        let mut c = ServiceCurve::default();
        c.record(1.0, 10.0);
        c.record(2.0, 10.0);
        // Over [0,2]: 20 tokens / 2 s.
        assert!((c.rate(2.0, 2.0) - 10.0).abs() < 1e-12);
        // Over [1.5, 2.0]: 10 tokens / 0.5 s.
        assert!((c.rate(2.0, 0.5) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn diff_series_tracks_gap() {
        let mut t = ServiceTracker::new();
        t.record(ClientId(0), 1.0, 100.0);
        t.record(ClientId(1), 1.0, 60.0);
        t.record(ClientId(1), 2.0, 40.0);
        let d = t.diff_series(ClientId(0), ClientId(1), 2.0, 2);
        assert!((d[0] - 40.0).abs() < 1e-12); // at t=1
        assert!((d[1] - 0.0).abs() < 1e-12); // at t=2
    }

    #[test]
    fn max_pairwise_covers_three_clients() {
        let mut t = ServiceTracker::new();
        t.record(ClientId(0), 1.0, 100.0);
        t.record(ClientId(1), 1.0, 50.0);
        t.record(ClientId(2), 1.0, 10.0);
        let d = t.max_pairwise_diff_series(1.0, 1);
        assert!((d[0] - 90.0).abs() < 1e-12);
    }

    #[test]
    fn missing_client_is_zero() {
        let t = ServiceTracker::new();
        assert_eq!(t.total(ClientId(9)), 0.0);
    }
}
