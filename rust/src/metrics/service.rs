//! Per-client service accounting: weighted-token service curves, windowed
//! service rates (Fig 9c/10c/17c), and the accumulated absolute service
//! difference between clients (Fig 9d/10d/17d, Table 1).

use crate::core::{ClientId, ClientSlab};
use std::collections::BTreeMap;

/// A single client's cumulative weighted-token service over time.
///
/// Two record shapes share one knot vector: point records (`record`) are
/// steps — service jumps at the knot time, exactly as the per-token
/// engine delivers it — and ramp records (`record_ramp`) accrue linearly
/// over an interval, which is how the macro-stepping engine represents a
/// whole event-horizon window of tokens in O(1) knots while windowed
/// rates stay token-granular in value (a linear ramp is within one
/// token's weight of the true staircase at every instant).
#[derive(Debug, Clone, Default)]
pub struct ServiceCurve {
    /// (time, cumulative weighted tokens), non-decreasing in both fields.
    pub points: Vec<(f64, f64)>,
    /// Per-knot accrual start: knot `i`'s delta accrues linearly over
    /// `[ramp_from[i], points[i].0]`. Point records have
    /// `ramp_from[i] == points[i].0` (a pure step).
    ramp_from: Vec<f64>,
}

impl ServiceCurve {
    pub fn record(&mut self, t: f64, delta: f64) {
        let prev = self.total();
        self.points.push((t, prev + delta));
        self.ramp_from.push(t);
    }

    /// Record `delta` weighted tokens accrued linearly over `[t0, t1]`.
    pub fn record_ramp(&mut self, t0: f64, t1: f64, delta: f64) {
        let prev = self.total();
        self.points.push((t1, prev + delta));
        self.ramp_from.push(t0.min(t1));
    }

    pub fn total(&self) -> f64 {
        self.points.last().map(|p| p.1).unwrap_or(0.0)
    }

    /// Cumulative service at time t: everything ending at or before `t`
    /// in full, plus the pro-rata share of every ramp already begun but
    /// not yet ended. Multiple ramps may share one end time (one macro
    /// window crediting several of a client's running requests) — each
    /// contributes its own partial accrual.
    pub fn at(&self, t: f64) -> f64 {
        let ub = self.points.partition_point(|p| p.0 <= t);
        let mut v = if ub == 0 { 0.0 } else { self.points[ub - 1].1 };
        if ub == self.points.len() {
            return v;
        }
        // Partially-accrued ramps: recording is append-in-time-order and
        // accrual windows never span a later knot's end (one engine
        // window's ramps all share its end time; the next window starts
        // there), so every ramp still open at `t` lives in the first
        // unended end-time group. Scan that whole group — ramp STARTS
        // within it are in arbitrary order (e.g. a prorated
        // post-preemption ramp recorded before a full-window one), so
        // each knot is tested individually, no early break.
        let group_end = self.points[ub].0;
        for j in ub..self.points.len() {
            let (t_end, v_end) = self.points[j];
            if t_end > group_end {
                break;
            }
            let r0 = self.ramp_from[j];
            if r0 < t {
                let prev = if j == 0 { 0.0 } else { self.points[j - 1].1 };
                v += (v_end - prev) * (t - r0) / (t_end - r0);
            }
        }
        v
    }

    /// Service rate over [t-window, t].
    pub fn rate(&self, t: f64, window: f64) -> f64 {
        if window <= 0.0 {
            return 0.0;
        }
        (self.at(t) - self.at(t - window)) / window
    }
}

/// Tracks service for all clients plus the pairwise difference series.
///
/// Per-client curves live in a dense [`ClientSlab`]: recording a token
/// delta indexes a contiguous slot instead of descending a `BTreeMap`,
/// and `clients()` / the diff series iterate the occupancy bitset in
/// the same ascending-id order the map gave — fingerprints that fold
/// per-client totals in `clients()` order are unchanged.
#[derive(Debug, Default)]
pub struct ServiceTracker {
    curves: ClientSlab<ServiceCurve>,
}

impl ServiceTracker {
    pub fn new() -> Self {
        ServiceTracker { curves: ClientSlab::new() }
    }

    pub fn record(&mut self, client: ClientId, t: f64, weighted_tokens: f64) {
        self.curves.or_default(client).record(t, weighted_tokens);
    }

    /// Record `weighted_tokens` accrued linearly over `[t0, t1]` — one
    /// call per macro-step per client instead of one per token; totals
    /// are exact, in-window values within one token of the staircase.
    pub fn record_bulk(&mut self, client: ClientId, t0: f64, t1: f64, weighted_tokens: f64) {
        self.curves.or_default(client).record_ramp(t0, t1, weighted_tokens);
    }

    pub fn clients(&self) -> Vec<ClientId> {
        self.curves.iter().map(|(c, _)| c).collect()
    }

    pub fn curve(&self, client: ClientId) -> Option<&ServiceCurve> {
        self.curves.get(client)
    }

    pub fn total(&self, client: ClientId) -> f64 {
        self.curves.get(client).map(|c| c.total()).unwrap_or(0.0)
    }

    /// Total service across all clients.
    pub fn grand_total(&self) -> f64 {
        self.curves.iter().map(|(_, c)| c.total()).sum()
    }

    /// Sampled |service_a - service_b| series between two clients, at
    /// `samples` uniform times over [0, horizon]. This is the quantity the
    /// paper plots as "accumulated service difference".
    pub fn diff_series(&self, a: ClientId, b: ClientId, horizon: f64, samples: usize) -> Vec<f64> {
        let ca = self.curves.get(a);
        let cb = self.curves.get(b);
        (1..=samples)
            .map(|i| {
                let t = horizon * i as f64 / samples as f64;
                let va = ca.map(|c| c.at(t)).unwrap_or(0.0);
                let vb = cb.map(|c| c.at(t)).unwrap_or(0.0);
                (va - vb).abs()
            })
            .collect()
    }

    /// Max pairwise diff series across ALL client pairs (multi-tenant
    /// generalisation used for >2-client workloads).
    pub fn max_pairwise_diff_series(&self, horizon: f64, samples: usize) -> Vec<f64> {
        (1..=samples)
            .map(|i| {
                let t = horizon * i as f64 / samples as f64;
                let vals: Vec<f64> = self.curves.iter().map(|(_, c)| c.at(t)).collect();
                let max = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
                if vals.is_empty() {
                    0.0
                } else {
                    max - min
                }
            })
            .collect()
    }

    /// Per-client service rates over a trailing window at time t.
    pub fn rates_at(&self, t: f64, window: f64) -> BTreeMap<ClientId, f64> {
        self.curves.iter().map(|(id, c)| (id, c.rate(t, window))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_accumulates() {
        let mut c = ServiceCurve::default();
        c.record(1.0, 10.0);
        c.record(2.0, 5.0);
        assert_eq!(c.total(), 15.0);
        assert_eq!(c.at(0.5), 0.0);
        assert_eq!(c.at(1.0), 10.0);
        assert_eq!(c.at(1.5), 10.0);
        assert_eq!(c.at(3.0), 15.0);
    }

    #[test]
    fn ramp_interpolates_linearly_and_totals_exactly() {
        let mut c = ServiceCurve::default();
        c.record(1.0, 10.0);
        // 40 tokens over [2, 6]: linear in between, exact at the ends.
        c.record_ramp(2.0, 6.0, 40.0);
        assert_eq!(c.total(), 50.0);
        assert_eq!(c.at(1.5), 10.0); // before the ramp starts
        assert_eq!(c.at(2.0), 10.0); // ramp start: nothing accrued yet
        assert!((c.at(4.0) - 30.0).abs() < 1e-12); // halfway
        assert_eq!(c.at(6.0), 50.0);
        assert_eq!(c.at(7.0), 50.0);
    }

    #[test]
    fn ramp_matches_per_token_staircase_within_one_token() {
        // 64 tokens of weight 4 over one second: the ramp must stay
        // within one token's weight of the per-token step curve.
        let mut ramp = ServiceCurve::default();
        ramp.record_ramp(10.0, 11.0, 64.0 * 4.0);
        let mut stair = ServiceCurve::default();
        for i in 1..=64 {
            stair.record(10.0 + i as f64 / 64.0, 4.0);
        }
        assert_eq!(ramp.total(), stair.total());
        let mut t = 10.0;
        while t <= 11.0 {
            assert!(
                (ramp.at(t) - stair.at(t)).abs() <= 4.0 + 1e-9,
                "ramp {} vs stair {} at t={t}",
                ramp.at(t),
                stair.at(t)
            );
            t += 0.01;
        }
    }

    #[test]
    fn overlapping_ramps_all_accrue() {
        // One macro window crediting two co-resident requests of the
        // same client: two ramp knots share an end time, and BOTH must
        // accrue mid-window (regression: the first knot used to shadow
        // the rest).
        let mut c = ServiceCurve::default();
        c.record_ramp(0.0, 2.0, 40.0);
        c.record_ramp(0.0, 2.0, 40.0);
        assert_eq!(c.total(), 80.0);
        assert!((c.at(1.0) - 40.0).abs() < 1e-12, "both ramps accrue: {}", c.at(1.0));
        assert_eq!(c.at(2.0), 80.0);
        assert_eq!(c.at(3.0), 80.0);
    }

    #[test]
    fn tracker_record_bulk_feeds_rates() {
        let mut tr = ServiceTracker::new();
        tr.record_bulk(ClientId(0), 0.0, 2.0, 100.0);
        assert_eq!(tr.total(ClientId(0)), 100.0);
        // Rate over the first half of the ramp: 50 tokens / 1 s.
        let r = tr.curve(ClientId(0)).unwrap().rate(1.0, 1.0);
        assert!((r - 50.0).abs() < 1e-9, "rate={r}");
    }

    #[test]
    fn rate_is_windowed_delta() {
        let mut c = ServiceCurve::default();
        c.record(1.0, 10.0);
        c.record(2.0, 10.0);
        // Over [0,2]: 20 tokens / 2 s.
        assert!((c.rate(2.0, 2.0) - 10.0).abs() < 1e-12);
        // Over [1.5, 2.0]: 10 tokens / 0.5 s.
        assert!((c.rate(2.0, 0.5) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn diff_series_tracks_gap() {
        let mut t = ServiceTracker::new();
        t.record(ClientId(0), 1.0, 100.0);
        t.record(ClientId(1), 1.0, 60.0);
        t.record(ClientId(1), 2.0, 40.0);
        let d = t.diff_series(ClientId(0), ClientId(1), 2.0, 2);
        assert!((d[0] - 40.0).abs() < 1e-12); // at t=1
        assert!((d[1] - 0.0).abs() < 1e-12); // at t=2
    }

    #[test]
    fn max_pairwise_covers_three_clients() {
        let mut t = ServiceTracker::new();
        t.record(ClientId(0), 1.0, 100.0);
        t.record(ClientId(1), 1.0, 50.0);
        t.record(ClientId(2), 1.0, 10.0);
        let d = t.max_pairwise_diff_series(1.0, 1);
        assert!((d[0] - 90.0).abs() < 1e-12);
    }

    #[test]
    fn missing_client_is_zero() {
        let t = ServiceTracker::new();
        assert_eq!(t.total(ClientId(9)), 0.0);
    }
}
