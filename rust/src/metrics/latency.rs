//! Latency accounting: TTFT (response time) and end-to-end latency with
//! percentile summaries — the quantities in Fig 9a, 11a/b, 12b/d.

use crate::core::Request;
use crate::util::stats;

/// Collects per-request latency samples.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    pub ttft: Vec<f64>,
    pub e2e: Vec<f64>,
    /// (arrival time, ttft) pairs for time-series plots.
    pub ttft_timeline: Vec<(f64, f64)>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, req: &Request) {
        if let Some(t) = req.ttft() {
            self.ttft.push(t);
            self.ttft_timeline.push((req.arrival, t));
        }
        if let Some(t) = req.e2e() {
            self.e2e.push(t);
        }
    }

    pub fn count(&self) -> usize {
        self.e2e.len()
    }

    pub fn ttft_mean(&self) -> f64 {
        stats::mean(&self.ttft)
    }

    pub fn ttft_p(&self, q: f64) -> f64 {
        stats::percentile(&self.ttft, q)
    }

    pub fn e2e_mean(&self) -> f64 {
        stats::mean(&self.e2e)
    }

    pub fn e2e_p(&self, q: f64) -> f64 {
        stats::percentile(&self.e2e, q)
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.ttft.extend_from_slice(&other.ttft);
        self.e2e.extend_from_slice(&other.e2e);
        self.ttft_timeline.extend_from_slice(&other.ttft_timeline);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, Request, RequestId};

    fn finished(arrival: f64, first: f64, done: f64) -> Request {
        let mut r = Request::new(RequestId(0), ClientId(0), 10, 10, arrival);
        r.first_token_at = Some(first);
        r.finished_at = Some(done);
        r
    }

    #[test]
    fn observes_both_latencies() {
        let mut s = LatencyStats::new();
        s.observe(&finished(0.0, 0.5, 2.0));
        s.observe(&finished(1.0, 2.0, 5.0));
        assert_eq!(s.count(), 2);
        assert!((s.ttft_mean() - 0.75).abs() < 1e-12);
        assert!((s.e2e_mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn unfinished_request_contributes_nothing() {
        let mut s = LatencyStats::new();
        let r = Request::new(RequestId(0), ClientId(0), 10, 10, 0.0);
        s.observe(&r);
        assert_eq!(s.count(), 0);
        assert!(s.ttft.is_empty());
    }

    #[test]
    fn percentiles_are_ordered() {
        let mut s = LatencyStats::new();
        for i in 0..100 {
            s.observe(&finished(0.0, i as f64 / 100.0, i as f64 / 10.0));
        }
        assert!(s.ttft_p(0.5) <= s.ttft_p(0.9));
        assert!(s.e2e_p(0.5) <= s.e2e_p(0.99));
    }

    #[test]
    fn merge_concatenates() {
        let mut a = LatencyStats::new();
        let mut b = LatencyStats::new();
        a.observe(&finished(0.0, 1.0, 2.0));
        b.observe(&finished(0.0, 3.0, 4.0));
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
