//! Jain's fairness index (§7.1, Eq. 1) and service-difference summaries
//! (Table 1's Max/Avg/Var columns).

/// Jain's index over per-client allocations: (Σx)² / (n·Σx²).
/// Ranges from 1/n (one client monopolises) to 1 (equal allocation).
pub fn jain_index(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n == 0 {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0; // all-zero allocation is (vacuously) equal
    }
    (sum * sum) / (n as f64 * sq)
}

/// Summary of a pairwise service-difference time series: the paper's
/// Table 1 reports Max / Avg / Var of the accumulated absolute difference.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiffSummary {
    pub max: f64,
    pub avg: f64,
    pub var: f64,
}

pub fn summarize_diffs(series: &[f64]) -> DiffSummary {
    if series.is_empty() {
        return DiffSummary { max: 0.0, avg: 0.0, var: 0.0 };
    }
    let max = series.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let avg = series.iter().sum::<f64>() / series.len() as f64;
    let var = series.iter().map(|x| (x - avg).powi(2)).sum::<f64>() / series.len() as f64;
    DiffSummary { max, avg, var }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jain_equal_allocation_is_one() {
        assert!((jain_index(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jain_monopoly_is_one_over_n() {
        let j = jain_index(&[10.0, 0.0, 0.0, 0.0]);
        assert!((j - 0.25).abs() < 1e-12);
    }

    #[test]
    fn jain_scale_invariant() {
        let a = jain_index(&[1.0, 2.0, 3.0]);
        let b = jain_index(&[10.0, 20.0, 30.0]);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn jain_edge_cases() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn diff_summary_basic() {
        let s = summarize_diffs(&[1.0, 3.0, 2.0]);
        assert_eq!(s.max, 3.0);
        assert!((s.avg - 2.0).abs() < 1e-12);
        assert!((s.var - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn diff_summary_empty() {
        let s = summarize_diffs(&[]);
        assert_eq!(s.max, 0.0);
    }
}
