//! Fairness and performance metrics (§7.1): per-client service rate,
//! accumulated service difference, TTFT / e2e latency, Jain's index, and
//! GPU-utilization accounting.

pub mod fairness;
pub mod latency;
pub mod service;

pub use fairness::jain_index;
pub use latency::LatencyStats;
pub use service::{ServiceCurve, ServiceTracker};
