//! The paper's named synthetic scenarios, parameterised exactly as in
//! §7.2 and Appendix A — plus the adversarial scenario library: hostile
//! traffic shapes (heavy hitters, flash crowds, diurnal load, tenant
//! churn, weighted tiers, prefill/decode duels) that the conformance
//! harness (`crate::harness`) runs every scheduler against. The paper
//! scenarios are benign by construction; these are built to break
//! fairness bookkeeping.

use super::arrivals::{Arrival, ArrivalProcess};
use crate::util::rng::Rng;

/// Per-client request shape specification.
#[derive(Debug, Clone)]
pub struct ClientSpec {
    pub arrival: Arrival,
    pub rate: ArrivalProcess,
    /// Fixed or jittered token lengths.
    pub input_tokens: u32,
    pub output_tokens: u32,
    /// Multiplicative jitter (geometric std dev) on lengths; 1.0 = fixed.
    pub length_jitter: f64,
    /// Priority weight ω_f (1.0 for all paper experiments).
    pub weight: f64,
    /// Activity window: the client sends requests only in `[start, stop)`
    /// — tenant churn (joining/leaving mid-run). Defaults to the whole
    /// run (`0.0..∞`).
    pub start: f64,
    pub stop: f64,
}

impl ClientSpec {
    pub fn fixed(arrival: Arrival, rate: ArrivalProcess, input: u32, output: u32) -> Self {
        ClientSpec {
            arrival,
            rate,
            input_tokens: input,
            output_tokens: output,
            length_jitter: 1.0,
            weight: 1.0,
            start: 0.0,
            stop: f64::INFINITY,
        }
    }

    /// Restrict the client's activity to `[start, stop)`.
    pub fn with_window(mut self, start: f64, stop: f64) -> Self {
        self.start = start;
        self.stop = stop;
        self
    }

    /// Set the priority weight ω_f.
    pub fn with_weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    /// Set the multiplicative length jitter (geometric std dev).
    pub fn with_jitter(mut self, gsd: f64) -> Self {
        self.length_jitter = gsd;
        self
    }

    /// Instantaneous (rate, input, output) at time t. Outside the
    /// activity window the rate is zero.
    pub fn at(&self, t: f64, rng: &mut Rng) -> (f64, u32, u32) {
        let rate =
            if (self.start..self.stop).contains(&t) { self.rate.rate_at(t) } else { 0.0 };
        let (inp, out) = if self.length_jitter > 1.0 {
            let i = crate::util::dist::log_normal_median(rng, self.input_tokens as f64, self.length_jitter);
            let o = crate::util::dist::log_normal_median(rng, self.output_tokens as f64, self.length_jitter);
            (i.round().max(1.0) as u32, o.round().max(1.0) as u32)
        } else {
            (self.input_tokens, self.output_tokens)
        };
        (rate, inp, out)
    }
}

/// A named experiment scenario: a set of clients plus a duration.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub clients: Vec<ClientSpec>,
    pub duration: f64,
}

impl Scenario {
    /// Every client's arrival intensity multiplied by `factor` — request
    /// shapes, weights, and activity windows unchanged. The cluster
    /// conformance cells use this to scale single-engine scenarios up to
    /// fleet-level offered load (N replicas want ~N× the traffic one
    /// engine saturates on).
    pub fn scale_rates(mut self, factor: f64) -> Scenario {
        for c in &mut self.clients {
            c.rate = c.rate.scaled(factor);
        }
        self
    }

    /// Resize the tenant population to exactly `n` clients while holding
    /// aggregate offered load roughly fixed: the existing specs are tiled
    /// cyclically and every rate is scaled by `old/n`, with a per-tenant
    /// floor of ~2 expected requests over the tenant's activity window so
    /// every tenant actually materialises in the trace. This is the
    /// million-tenant knob for the scale benches —
    /// `heavy_hitter(9, d).with_clients(100_000)` keeps the one-in-ten
    /// hitter pattern and near-constant token demand, so population
    /// stresses per-client bookkeeping rather than the host model.
    pub fn with_clients(mut self, n: usize) -> Scenario {
        let n = n.max(1);
        let old = self.clients.len().max(1);
        let factor = old as f64 / n as f64;
        let base = std::mem::take(&mut self.clients);
        self.clients = (0..n)
            .map(|i| {
                let mut c = base[i % old].clone();
                let w0 = c.start.max(0.0);
                let span = (c.stop.min(self.duration) - w0).max(1e-9);
                let floor = 2.0 / span;
                // Judge the clamp from the window-MEAN rate: a
                // time-varying tenant (flash-crowd spike, diurnal
                // sinusoid) read at the single instant `start` can look
                // loud while its window mean is (almost) silent, or vice
                // versa. And when the floor does bind, rescale the
                // existing process so its mean lands on the floor — the
                // profile keeps its shape (diurnal stays diurnal) instead
                // of flattening to a constant.
                let mean = c.rate.mean_rate(w0, w0 + span);
                if mean * factor <= floor {
                    if mean > 0.0 {
                        c.rate = c.rate.scaled(floor / mean);
                    } else {
                        // Nothing to rescale (an all-quiet profile has no
                        // shape): the constant floor is the only option.
                        c.rate = ArrivalProcess::Constant(floor);
                    }
                } else {
                    c.rate = c.rate.scaled(factor);
                }
                c
            })
            .collect();
        self
    }

    /// §7.2.1: C1 2 req/s (100,400) deterministic; C2 1 req/s (100,900).
    pub fn balanced_load(duration: f64) -> Scenario {
        Scenario {
            name: "balanced_load",
            clients: vec![
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(2.0), 100, 400),
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(1.0), 100, 900),
            ],
            duration,
        }
    }

    /// §7.2.2: Poisson; C1 16 req/s prefill-heavy (512,32); C2 3 req/s
    /// decode-heavy (32,512).
    pub fn stochastic_arrivals(duration: f64) -> Scenario {
        Scenario {
            name: "stochastic_arrivals",
            clients: vec![
                ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(16.0), 512, 32),
                ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(3.0), 32, 512),
            ],
            duration,
        }
    }

    /// App A: constant extreme overload; C1 20 req/s (20,180); C2 2 req/s
    /// (200,1800).
    pub fn constant_overload(duration: f64) -> Scenario {
        Scenario {
            name: "constant_overload",
            clients: vec![
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(20.0), 20, 180),
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(2.0), 200, 1800),
            ],
            duration,
        }
    }

    /// App A: dynamic load increase; C1 1 req/s (100,400); C2 1→4 req/s at
    /// the midpoint.
    pub fn dynamic_load(duration: f64) -> Scenario {
        Scenario {
            name: "dynamic_load",
            clients: vec![
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(1.0), 100, 400),
                ClientSpec::fixed(
                    Arrival::Deterministic,
                    ArrivalProcess::Step { before: 1.0, after: 4.0, at: duration / 2.0 },
                    100,
                    400,
                ),
            ],
            duration,
        }
    }

    /// Fig 1 motivation: equal total tokens — many short vs few long.
    /// `short` client: high rate, small requests; `long` client: low rate,
    /// large requests; identical aggregate token demand.
    pub fn equal_tokens_short_vs_long(duration: f64) -> Scenario {
        Scenario {
            name: "equal_tokens_short_vs_long",
            clients: vec![
                // 8 req/s * (25 in + 100 out) = 8*125 = 1000 tok/s
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(8.0), 25, 100),
                // 1 req/s * (200 in + 800 out) = 1000 tok/s
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(1.0), 200, 800),
            ],
            duration,
        }
    }

    // ---- adversarial scenario library ----

    /// One tenant floods at 100× the per-victim rate with identical
    /// request shapes. VTC's bounded-discrepancy claim is exactly about
    /// this shape: the hitter's backlog must not starve the trickle
    /// tenants (FairBatching's "aggressive client" case).
    pub fn heavy_hitter(victims: usize, duration: f64) -> Scenario {
        let mut clients =
            vec![ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(15.0), 32, 64)];
        for _ in 0..victims {
            clients.push(ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(0.15), 32, 64));
        }
        Scenario { name: "heavy_hitter", clients, duration }
    }

    /// Flash crowd: two steady tenants plus one whose rate spikes ~30×
    /// for the third quarter of the run (a Piecewise burst). The spike
    /// arrives mid-decode for the steady tenants, the batch composition
    /// flips in one window — the case most likely to break event-horizon
    /// bookkeeping and windowed fairness.
    pub fn flash_crowd(duration: f64) -> Scenario {
        let window = duration / 4.0;
        Scenario {
            name: "flash_crowd",
            clients: vec![
                ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(1.0), 64, 128),
                ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(1.0), 64, 128),
                ClientSpec::fixed(
                    Arrival::Poisson,
                    ArrivalProcess::Piecewise { window, rates: vec![0.3, 0.3, 9.0, 0.3] },
                    48,
                    96,
                ),
            ],
            duration,
        }
    }

    /// Diurnal sinusoid: `tenants` clients whose rates follow the same
    /// sinusoid phase-shifted so peaks rotate across tenants (time-zone
    /// offset traffic). Total load is near-constant; per-tenant load is
    /// anything but.
    pub fn diurnal(tenants: usize, duration: f64) -> Scenario {
        let period = duration / 2.0;
        let clients = (0..tenants.max(1))
            .map(|k| {
                let phase = 2.0 * std::f64::consts::PI * k as f64 / tenants.max(1) as f64;
                ClientSpec::fixed(
                    Arrival::Poisson,
                    ArrivalProcess::Sinusoid { base: 1.2, amplitude: 1.0, period, phase },
                    48,
                    96,
                )
            })
            .collect();
        Scenario { name: "diurnal", clients, duration }
    }

    /// Tenant churn: `tenants` clients with staggered half-run activity
    /// windows — clients join and leave mid-run. Exercises the
    /// (re)activation lift paths: a returning tenant must not bank idle
    /// time, and a leaver must drop out of the active index cleanly.
    pub fn tenant_churn(tenants: usize, duration: f64) -> Scenario {
        let n = tenants.max(2);
        // Starts spread evenly over the first half of the run; every
        // window lasts half the run, so the first tenant leaves at the
        // midpoint and the last one joins there.
        let step = duration / 2.0 / (n - 1) as f64;
        let clients = (0..n)
            .map(|k| {
                let start = k as f64 * step;
                let stop = start + duration / 2.0;
                ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(1.5), 64, 96)
                    .with_window(start, stop)
            })
            .collect();
        Scenario { name: "tenant_churn", clients, duration }
    }

    /// Weighted tier mix: three service tiers with ω_f ∈ {1, 2, 4} and
    /// request rates scaled with the tier (paid tiers send more). Two
    /// tenants per tier so within-tier fairness is still checkable.
    ///
    /// The spec weights are stamped onto every generated `Request` by
    /// `workload::generate` and consumed at admission by the fairness
    /// counters (`charge_admission` / `update_ufc_on_admit`), so the
    /// scenario exercises ω∈{1,2,4} end to end: under contention a fair
    /// scheduler delivers service roughly proportional to ω (entitlement
    /// semantics — see `Request::weight`).
    pub fn weighted_tiers(duration: f64) -> Scenario {
        let mut clients = Vec::new();
        for (w, rate) in [(1.0, 0.5), (2.0, 1.0), (4.0, 2.0)] {
            for _ in 0..2 {
                clients.push(
                    ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(rate), 96, 160)
                        .with_weight(w),
                );
            }
        }
        Scenario { name: "weighted_tiers", clients, duration }
    }

    /// Prefill-flood vs decode-flood duel: one tenant sends huge prompts
    /// with tiny outputs, the other tiny prompts with huge outputs, at
    /// near-equal weighted-token demand. Token-count fairness sees them
    /// as equals; the compute/memory cost asymmetry (the paper's Fig 3
    /// bifurcation) is maximal.
    pub fn prefill_decode_duel(duration: f64) -> Scenario {
        Scenario {
            name: "prefill_decode_duel",
            clients: vec![
                // 1.2 req/s · (1536 + 4·16) = 1920 wtok/s, compute-bound.
                ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(1.2), 1536, 16),
                // 0.6 req/s · (16 + 4·768) = 1853 wtok/s, memory-bound.
                ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(0.6), 16, 768),
            ],
            duration,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_parameters_are_exact() {
        let s = Scenario::balanced_load(10.0);
        assert_eq!(s.clients[0].input_tokens, 100);
        assert_eq!(s.clients[0].output_tokens, 400);
        assert_eq!(s.clients[1].output_tokens, 900);
        let s = Scenario::stochastic_arrivals(10.0);
        assert_eq!(s.clients[0].input_tokens, 512);
        assert_eq!(s.clients[1].output_tokens, 512);
        let s = Scenario::constant_overload(10.0);
        assert_eq!(s.clients[1].input_tokens, 200);
        assert_eq!(s.clients[1].output_tokens, 1800);
    }

    #[test]
    fn equal_tokens_scenario_has_equal_demand() {
        let s = Scenario::equal_tokens_short_vs_long(10.0);
        let demand = |c: &ClientSpec| {
            c.rate.rate_at(0.0) * (c.input_tokens + c.output_tokens) as f64
        };
        assert_eq!(demand(&s.clients[0]), demand(&s.clients[1]));
    }

    #[test]
    fn scale_rates_multiplies_intensity_only() {
        let s = Scenario::heavy_hitter(2, 10.0).scale_rates(4.0);
        assert!((s.clients[0].rate.rate_at(0.0) - 60.0).abs() < 1e-12);
        assert!((s.clients[1].rate.rate_at(0.0) - 0.6).abs() < 1e-12);
        assert_eq!(s.clients[0].input_tokens, 32, "shapes unchanged");
        let w = Scenario::weighted_tiers(10.0).scale_rates(2.0);
        assert_eq!(w.clients[5].weight, 4.0, "weights unchanged");
    }

    #[test]
    fn with_clients_resizes_population_and_preserves_load() {
        let s = Scenario::heavy_hitter(9, 100.0).with_clients(40);
        assert_eq!(s.clients.len(), 40);
        // The one-in-ten hitter pattern tiles: clients 0, 10, 20, 30 are
        // hitters, everyone else a victim.
        assert!(s.clients[10].rate.rate_at(0.0) > 10.0 * s.clients[1].rate.rate_at(0.0));
        // Aggregate offered rate matches the 10-client base.
        let base: f64 =
            Scenario::heavy_hitter(9, 100.0).clients.iter().map(|c| c.rate.rate_at(0.0)).sum();
        let scaled: f64 = s.clients.iter().map(|c| c.rate.rate_at(0.0)).sum();
        assert!((scaled / base - 1.0).abs() < 0.05, "base={base} scaled={scaled}");
    }

    #[test]
    fn with_clients_floors_rates_so_every_tenant_appears() {
        let s = Scenario::heavy_hitter(9, 100.0).with_clients(100_000);
        assert_eq!(s.clients.len(), 100_000);
        // A victim's load-preserving rate would be ~1.5e-5 req/s; the
        // floor guarantees ~2 expected requests over the run instead.
        assert!(s.clients[1].rate.rate_at(0.0) >= 2.0 / 100.0 - 1e-12);
        // Churn windows survive the resize (tiled, still staggered).
        let c = Scenario::tenant_churn(4, 40.0).with_clients(16);
        assert_eq!(c.clients.len(), 16);
        assert!(c.clients[1].start > c.clients[0].start);
        assert!(c.clients[4].start == c.clients[0].start, "pattern tiles every 4");
        for spec in &c.clients {
            let span = spec.stop.min(40.0) - spec.start;
            assert!(spec.rate.rate_at(spec.start) * span >= 2.0 - 1e-9);
        }
    }

    #[test]
    fn with_clients_keeps_time_varying_shapes_at_the_floor() {
        // Diurnal tenants resized to 100k: the floor binds, but the
        // sinusoid must survive as a sinusoid — the old code judged the
        // clamp from rate_at(start) and replaced the profile with
        // Constant(floor), flattening every time-varying tenant.
        let s = Scenario::diurnal(4, 40.0).with_clients(100_000);
        assert_eq!(s.clients.len(), 100_000);
        let c = &s.clients[0];
        let peak = c.rate.rate_at(5.0); // quarter of period 20: the sin peak
        let trough = c.rate.rate_at(15.0);
        assert!(peak > trough * 1.5, "profile flattened: peak={peak} trough={trough}");
        // The rescale lands the window mean on the floor: ~2 expected
        // requests over the run, same guarantee the old clamp gave.
        let mean = c.rate.mean_rate(0.0, 40.0);
        assert!((mean * 40.0 - 2.0).abs() < 0.05, "expected ~2 requests, got {}", mean * 40.0);
        // A flash-crowd spiky tenant keeps its ~30× burst ratio too.
        let f = Scenario::flash_crowd(40.0).with_clients(50_000);
        let spiky = &f.clients[2];
        let quiet = spiky.rate.rate_at(5.0);
        let spike = spiky.rate.rate_at(25.0);
        assert!(spike / quiet >= 20.0, "spike ratio lost: quiet={quiet} spike={spike}");
    }

    #[test]
    fn jitter_produces_varying_lengths() {
        let mut c = ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(1.0), 100, 200);
        c.length_jitter = 2.0;
        let mut rng = Rng::new(1);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..32 {
            let (_, i, o) = c.at(0.0, &mut rng);
            assert!(i >= 1 && o >= 1);
            distinct.insert((i, o));
        }
        assert!(distinct.len() > 10);
    }

    #[test]
    fn activity_window_masks_rate() {
        let c = ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(3.0), 10, 10)
            .with_window(5.0, 10.0);
        let mut rng = Rng::new(1);
        assert_eq!(c.at(4.9, &mut rng).0, 0.0, "before start");
        assert_eq!(c.at(5.0, &mut rng).0, 3.0, "start is inclusive");
        assert_eq!(c.at(9.9, &mut rng).0, 3.0);
        assert_eq!(c.at(10.0, &mut rng).0, 0.0, "stop is exclusive");
    }

    #[test]
    fn heavy_hitter_rate_ratio_is_100x() {
        let s = Scenario::heavy_hitter(4, 10.0);
        assert_eq!(s.clients.len(), 5);
        let hog = s.clients[0].rate.rate_at(0.0);
        let victim = s.clients[1].rate.rate_at(0.0);
        assert!((hog / victim - 100.0).abs() < 1e-9, "hog={hog} victim={victim}");
    }

    #[test]
    fn flash_crowd_spikes_in_third_quarter() {
        let s = Scenario::flash_crowd(40.0);
        let spiky = &s.clients[2];
        let quiet = spiky.rate.rate_at(5.0);
        let spike = spiky.rate.rate_at(25.0);
        assert!(spike / quiet >= 20.0, "quiet={quiet} spike={spike}");
        assert_eq!(spiky.rate.rate_at(35.0), quiet, "spike ends");
    }

    #[test]
    fn diurnal_peaks_rotate() {
        let s = Scenario::diurnal(4, 40.0);
        assert_eq!(s.clients.len(), 4);
        // At any instant some tenant is near peak while its antiphase
        // twin is near trough.
        let r0 = s.clients[0].rate.rate_at(5.0);
        let r2 = s.clients[2].rate.rate_at(5.0);
        assert!((r0 - r2).abs() > 1.0, "r0={r0} r2={r2}");
    }

    #[test]
    fn churn_windows_are_staggered_and_partial() {
        let s = Scenario::tenant_churn(6, 30.0);
        assert_eq!(s.clients.len(), 6);
        for (k, c) in s.clients.iter().enumerate() {
            assert!(c.stop - c.start <= 30.0 * 0.5 + 1e-9, "client {k} window too long");
            if k > 0 {
                assert!(c.start > s.clients[k - 1].start, "windows must stagger");
            }
        }
        // The last client is still active at the end half; the first has
        // left well before the run ends.
        assert!(s.clients[0].stop < 30.0);
        assert!(s.clients[5].stop > 15.0);
    }

    #[test]
    fn weighted_tiers_cover_1_2_4() {
        let s = Scenario::weighted_tiers(10.0);
        let mut weights: Vec<f64> = s.clients.iter().map(|c| c.weight).collect();
        weights.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(weights, vec![1.0, 1.0, 2.0, 2.0, 4.0, 4.0]);
    }

    #[test]
    fn duel_demands_are_near_equal() {
        let s = Scenario::prefill_decode_duel(10.0);
        let wdemand = |c: &ClientSpec| {
            c.rate.rate_at(0.0) * (c.input_tokens as f64 + 4.0 * c.output_tokens as f64)
        };
        let a = wdemand(&s.clients[0]);
        let b = wdemand(&s.clients[1]);
        assert!((a / b - 1.0).abs() < 0.1, "a={a} b={b}");
        // And the shapes are maximally opposed.
        assert!(s.clients[0].input_tokens > 50 * s.clients[1].input_tokens);
        assert!(s.clients[1].output_tokens > 40 * s.clients[0].output_tokens);
    }
}
