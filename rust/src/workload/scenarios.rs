//! The paper's named synthetic scenarios, parameterised exactly as in
//! §7.2 and Appendix A.

use super::arrivals::{Arrival, ArrivalProcess};
use crate::util::rng::Rng;

/// Per-client request shape specification.
#[derive(Debug, Clone)]
pub struct ClientSpec {
    pub arrival: Arrival,
    pub rate: ArrivalProcess,
    /// Fixed or jittered token lengths.
    pub input_tokens: u32,
    pub output_tokens: u32,
    /// Multiplicative jitter (geometric std dev) on lengths; 1.0 = fixed.
    pub length_jitter: f64,
    /// Priority weight ω_f (1.0 for all paper experiments).
    pub weight: f64,
}

impl ClientSpec {
    pub fn fixed(arrival: Arrival, rate: ArrivalProcess, input: u32, output: u32) -> Self {
        ClientSpec {
            arrival,
            rate,
            input_tokens: input,
            output_tokens: output,
            length_jitter: 1.0,
            weight: 1.0,
        }
    }

    /// Instantaneous (rate, input, output) at time t.
    pub fn at(&self, t: f64, rng: &mut Rng) -> (f64, u32, u32) {
        let rate = self.rate.rate_at(t);
        let (inp, out) = if self.length_jitter > 1.0 {
            let i = crate::util::dist::log_normal_median(rng, self.input_tokens as f64, self.length_jitter);
            let o = crate::util::dist::log_normal_median(rng, self.output_tokens as f64, self.length_jitter);
            (i.round().max(1.0) as u32, o.round().max(1.0) as u32)
        } else {
            (self.input_tokens, self.output_tokens)
        };
        (rate, inp, out)
    }
}

/// A named experiment scenario: a set of clients plus a duration.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub clients: Vec<ClientSpec>,
    pub duration: f64,
}

impl Scenario {
    /// §7.2.1: C1 2 req/s (100,400) deterministic; C2 1 req/s (100,900).
    pub fn balanced_load(duration: f64) -> Scenario {
        Scenario {
            name: "balanced_load",
            clients: vec![
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(2.0), 100, 400),
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(1.0), 100, 900),
            ],
            duration,
        }
    }

    /// §7.2.2: Poisson; C1 16 req/s prefill-heavy (512,32); C2 3 req/s
    /// decode-heavy (32,512).
    pub fn stochastic_arrivals(duration: f64) -> Scenario {
        Scenario {
            name: "stochastic_arrivals",
            clients: vec![
                ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(16.0), 512, 32),
                ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(3.0), 32, 512),
            ],
            duration,
        }
    }

    /// App A: constant extreme overload; C1 20 req/s (20,180); C2 2 req/s
    /// (200,1800).
    pub fn constant_overload(duration: f64) -> Scenario {
        Scenario {
            name: "constant_overload",
            clients: vec![
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(20.0), 20, 180),
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(2.0), 200, 1800),
            ],
            duration,
        }
    }

    /// App A: dynamic load increase; C1 1 req/s (100,400); C2 1→4 req/s at
    /// the midpoint.
    pub fn dynamic_load(duration: f64) -> Scenario {
        Scenario {
            name: "dynamic_load",
            clients: vec![
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(1.0), 100, 400),
                ClientSpec::fixed(
                    Arrival::Deterministic,
                    ArrivalProcess::Step { before: 1.0, after: 4.0, at: duration / 2.0 },
                    100,
                    400,
                ),
            ],
            duration,
        }
    }

    /// Fig 1 motivation: equal total tokens — many short vs few long.
    /// `short` client: high rate, small requests; `long` client: low rate,
    /// large requests; identical aggregate token demand.
    pub fn equal_tokens_short_vs_long(duration: f64) -> Scenario {
        Scenario {
            name: "equal_tokens_short_vs_long",
            clients: vec![
                // 8 req/s * (25 in + 100 out) = 8*125 = 1000 tok/s
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(8.0), 25, 100),
                // 1 req/s * (200 in + 800 out) = 1000 tok/s
                ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(1.0), 200, 800),
            ],
            duration,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_parameters_are_exact() {
        let s = Scenario::balanced_load(10.0);
        assert_eq!(s.clients[0].input_tokens, 100);
        assert_eq!(s.clients[0].output_tokens, 400);
        assert_eq!(s.clients[1].output_tokens, 900);
        let s = Scenario::stochastic_arrivals(10.0);
        assert_eq!(s.clients[0].input_tokens, 512);
        assert_eq!(s.clients[1].output_tokens, 512);
        let s = Scenario::constant_overload(10.0);
        assert_eq!(s.clients[1].input_tokens, 200);
        assert_eq!(s.clients[1].output_tokens, 1800);
    }

    #[test]
    fn equal_tokens_scenario_has_equal_demand() {
        let s = Scenario::equal_tokens_short_vs_long(10.0);
        let demand = |c: &ClientSpec| {
            c.rate.rate_at(0.0) * (c.input_tokens + c.output_tokens) as f64
        };
        assert_eq!(demand(&s.clients[0]), demand(&s.clients[1]));
    }

    #[test]
    fn jitter_produces_varying_lengths() {
        let mut c = ClientSpec::fixed(Arrival::Poisson, ArrivalProcess::Constant(1.0), 100, 200);
        c.length_jitter = 2.0;
        let mut rng = Rng::new(1);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..32 {
            let (_, i, o) = c.at(0.0, &mut rng);
            assert!(i >= 1 && o >= 1);
            distinct.insert((i, o));
        }
        assert!(distinct.len() > 10);
    }
}
