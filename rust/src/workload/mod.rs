//! Workload generation: the paper's synthetic scenarios (§7.2, App A) and
//! trace-like generators fit to the published ShareGPT / LMSYS length
//! statistics (§7.3, App B). Real traces are not redistributable offline;
//! DESIGN.md's substitution ledger documents why distribution-matched
//! synthetics preserve the fairness phenomena under study.

pub mod adversarial;
pub mod arrivals;
pub mod scenarios;
pub mod tracegen;

pub use adversarial::AdvScenario;
pub use arrivals::{Arrival, ArrivalProcess};
pub use scenarios::{ClientSpec, Scenario};
pub use tracegen::{LmsysLike, ShareGptLike, TraceGen};

use crate::core::{ClientId, Request, RequestId};
use crate::util::rng::Rng;
use std::sync::Arc;

/// A fully materialised trace: requests sorted by arrival time.
///
/// Requests live behind an `Arc<[Request]>` so a trace is shared by
/// reference across simulation runs — `Simulation::new` used to deep-copy
/// the full request vector per run (per scheduler × per seed × per
/// replica), which at million-tenant scale dominated setup time. Cloning
/// a `Trace` is now a refcount bump; the slice derefs everywhere a
/// `Vec` did.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Arc<[Request]>,
    /// Wall-clock horizon of the trace (seconds).
    pub horizon: f64,
}

impl Trace {
    /// Build a trace from per-client streams of (arrival, in, out).
    pub fn from_events(mut events: Vec<(f64, ClientId, u32, u32)>, horizon: f64) -> Trace {
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let requests: Arc<[Request]> = events
            .into_iter()
            .enumerate()
            .map(|(i, (t, c, inp, out))| Request::new(RequestId(i as u64), c, inp, out, t))
            .collect();
        Trace { requests, horizon }
    }

    pub fn num_clients(&self) -> usize {
        let mut ids: Vec<u32> = self.requests.iter().map(|r| r.client.0).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Total weighted tokens in the trace (service demand).
    pub fn total_weighted_tokens(&self) -> f64 {
        self.requests.iter().map(|r| r.weighted_tokens()).sum()
    }
}

/// Generate a trace for a scenario with a seed.
pub fn generate(scenario: &Scenario, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut events = Vec::new();
    for (idx, client) in scenario.clients.iter().enumerate() {
        let mut crng = rng.fork(idx as u64 + 1);
        // Per-client activity window (tenant churn): the stream starts at
        // `start` and ends at the earlier of `stop` and the scenario
        // horizon.
        let mut t = client.start.max(0.0);
        let end = scenario.duration.min(client.stop);
        while t < end {
            let (rate, input, output) = client.at(t, &mut crng);
            if rate <= 0.0 {
                t += 0.25;
                continue;
            }
            let gap = match client.arrival {
                Arrival::Deterministic => 1.0 / rate,
                Arrival::Poisson => crate::util::dist::exponential(&mut crng, rate),
            };
            t += gap;
            if t >= end {
                break;
            }
            events.push((t, ClientId(idx as u32), input, output));
        }
    }
    let mut trace = Trace::from_events(events, scenario.duration);
    // Stamp the per-client priority weight ω_f onto every request so it
    // reaches admission (the counters read `Request::weight` when
    // charging) — this is what makes `weighted_tiers` exercise ω∈{1,2,4}
    // end to end instead of recording weights nobody delivers. The Arc
    // is uniquely owned right after construction, so this is in-place.
    let requests =
        Arc::get_mut(&mut trace.requests).expect("freshly built trace is uniquely owned");
    for r in requests {
        r.weight = scenario.clients[r.client.0 as usize].weight;
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_sorted_by_arrival() {
        let sc = Scenario::balanced_load(60.0);
        let tr = generate(&sc, 7);
        for w in tr.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert_eq!(tr.num_clients(), 2);
    }

    #[test]
    fn deterministic_rate_matches() {
        let sc = Scenario::balanced_load(100.0);
        let tr = generate(&sc, 1);
        // Client 0 sends 2 req/s for 100 s → ~200 requests.
        let c0 = tr.requests.iter().filter(|r| r.client == ClientId(0)).count();
        assert!((190..=210).contains(&c0), "c0={c0}");
    }

    #[test]
    fn poisson_rate_statistically_matches() {
        let sc = Scenario::stochastic_arrivals(50.0);
        let tr = generate(&sc, 2);
        let c0 = tr.requests.iter().filter(|r| r.client == ClientId(0)).count() as f64;
        // 16 req/s * 50 s = 800 expected; allow 4 sigma.
        assert!((c0 - 800.0).abs() < 4.0 * 800.0f64.sqrt(), "c0={c0}");
    }

    #[test]
    fn churn_windows_bound_arrivals() {
        let sc = Scenario::tenant_churn(4, 40.0);
        let tr = generate(&sc, 3);
        assert!(!tr.is_empty());
        for r in tr.requests.iter() {
            let spec = &sc.clients[r.client.0 as usize];
            assert!(
                r.arrival >= spec.start && r.arrival < spec.stop.min(sc.duration),
                "{} arrived at {} outside [{}, {})",
                r.client,
                r.arrival,
                spec.start,
                spec.stop
            );
        }
        // Every tenant actually sends something inside its window.
        assert_eq!(tr.num_clients(), 4);
    }

    #[test]
    fn generated_requests_carry_client_weights() {
        let sc = Scenario::weighted_tiers(20.0);
        let tr = generate(&sc, 11);
        assert!(!tr.is_empty());
        for r in tr.requests.iter() {
            let want = sc.clients[r.client.0 as usize].weight;
            assert_eq!(r.weight, want, "{} weight {} != spec {}", r.client, r.weight, want);
        }
        // All three tiers actually appear in the trace.
        let mut weights: Vec<f64> = tr.requests.iter().map(|r| r.weight).collect();
        weights.sort_by(f64::total_cmp);
        weights.dedup();
        assert_eq!(weights, vec![1.0, 2.0, 4.0]);
    }

    #[test]
    fn same_seed_same_trace() {
        let sc = Scenario::stochastic_arrivals(20.0);
        let a = generate(&sc, 42);
        let b = generate(&sc, 42);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.requests.iter().zip(b.requests.iter()) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.input_tokens, y.input_tokens);
            assert_eq!(x.true_output_tokens, y.true_output_tokens);
        }
    }
}
