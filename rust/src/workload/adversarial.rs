//! The adversarial scenario registry: every named workload the
//! conformance harness (`crate::harness`) runs the scheduler matrix
//! against — the paper's five synthetic scenarios plus the hostile
//! shapes from `scenarios.rs`/`tracegen.rs` (heavy hitters, flash
//! crowds, diurnal load, churn, tier mixes, multi-turn sessions,
//! prefill/decode duels, trace-mix composites).
//!
//! Each entry materialises a [`Trace`] from `(duration, seed)` alone, so
//! the whole matrix is reproducible from one base seed and a cell name
//! (see `harness::derive_seed`). `quick_secs` is tuned so a full
//! scheduler × scenario × step-mode sweep stays affordable in debug-mode
//! `cargo test`; `full_secs` is the CI/CLI release-mode depth.

use super::scenarios::Scenario;
use super::{generate, tracegen, Trace};

/// A named adversarial workload for the conformance matrix.
#[derive(Clone, Copy)]
pub struct AdvScenario {
    pub name: &'static str,
    /// Materialise the trace at `duration` seconds with `seed`.
    pub build: fn(f64, u64) -> Trace,
    /// Duration used by quick (tier-1 test / CI) conformance runs.
    pub quick_secs: f64,
    /// Duration used by full (release CLI) conformance runs.
    pub full_secs: f64,
}

impl AdvScenario {
    pub fn trace(&self, quick: bool, seed: u64) -> Trace {
        let secs = if quick { self.quick_secs } else { self.full_secs };
        (self.build)(secs, seed)
    }
}

impl std::fmt::Debug for AdvScenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AdvScenario").field("name", &self.name).finish()
    }
}

/// The full registry, paper scenarios first. Order is stable — goldens
/// and verdict files key cells by name, not position, but a stable order
/// keeps diffs readable.
pub fn registry() -> Vec<AdvScenario> {
    vec![
        AdvScenario {
            name: "balanced_load",
            build: |d, s| generate(&Scenario::balanced_load(d), s),
            quick_secs: 12.0,
            full_secs: 60.0,
        },
        AdvScenario {
            name: "stochastic_arrivals",
            build: |d, s| generate(&Scenario::stochastic_arrivals(d), s),
            quick_secs: 8.0,
            full_secs: 40.0,
        },
        AdvScenario {
            name: "constant_overload",
            build: |d, s| generate(&Scenario::constant_overload(d), s),
            quick_secs: 10.0,
            full_secs: 40.0,
        },
        AdvScenario {
            name: "dynamic_load",
            build: |d, s| generate(&Scenario::dynamic_load(d), s),
            quick_secs: 14.0,
            full_secs: 60.0,
        },
        AdvScenario {
            name: "equal_tokens",
            build: |d, s| generate(&Scenario::equal_tokens_short_vs_long(d), s),
            quick_secs: 10.0,
            full_secs: 60.0,
        },
        AdvScenario {
            name: "heavy_hitter",
            build: |d, s| generate(&Scenario::heavy_hitter(4, d), s),
            quick_secs: 14.0,
            full_secs: 60.0,
        },
        AdvScenario {
            name: "flash_crowd",
            build: |d, s| generate(&Scenario::flash_crowd(d), s),
            quick_secs: 16.0,
            full_secs: 80.0,
        },
        AdvScenario {
            name: "diurnal",
            build: |d, s| generate(&Scenario::diurnal(4, d), s),
            quick_secs: 16.0,
            full_secs: 120.0,
        },
        AdvScenario {
            name: "tenant_churn",
            build: |d, s| generate(&Scenario::tenant_churn(6, d), s),
            quick_secs: 16.0,
            full_secs: 90.0,
        },
        AdvScenario {
            name: "weighted_tiers",
            build: |d, s| generate(&Scenario::weighted_tiers(d), s),
            quick_secs: 12.0,
            full_secs: 60.0,
        },
        AdvScenario {
            name: "prefill_decode_duel",
            build: |d, s| generate(&Scenario::prefill_decode_duel(d), s),
            quick_secs: 12.0,
            full_secs: 60.0,
        },
        AdvScenario {
            name: "multi_turn",
            build: |d, s| tracegen::multi_turn_trace(4, d, s),
            quick_secs: 16.0,
            full_secs: 90.0,
        },
        AdvScenario {
            name: "trace_mix",
            build: |d, s| tracegen::trace_mix(3, 0.8, d, s),
            quick_secs: 14.0,
            full_secs: 90.0,
        },
        AdvScenario {
            name: "mixed_tenants",
            build: |d, s| tracegen::mixed_tenants_trace(2, d, s),
            quick_secs: 12.0,
            full_secs: 60.0,
        },
    ]
}

pub fn find(name: &str) -> Option<AdvScenario> {
    registry().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_large_and_uniquely_named() {
        let reg = registry();
        assert!(reg.len() >= 12, "conformance matrix needs ≥12 scenarios, have {}", reg.len());
        let mut names: Vec<&str> = reg.iter().map(|s| s.name).collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate scenario names");
    }

    #[test]
    fn every_scenario_builds_a_nonempty_deterministic_trace() {
        for sc in registry() {
            let a = sc.trace(true, 7);
            let b = sc.trace(true, 7);
            assert!(!a.is_empty(), "{}: empty trace", sc.name);
            assert!(a.num_clients() >= 2, "{}: needs ≥2 tenants for fairness", sc.name);
            assert_eq!(a.len(), b.len(), "{}: nondeterministic length", sc.name);
            for (x, y) in a.requests.iter().zip(b.requests.iter()) {
                assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "{}", sc.name);
                assert_eq!(x.input_tokens, y.input_tokens, "{}", sc.name);
                assert_eq!(x.true_output_tokens, y.true_output_tokens, "{}", sc.name);
            }
        }
    }

    #[test]
    fn quick_traces_stay_affordable() {
        // The conformance matrix runs every scenario through the
        // per-token Micro engine in debug tests: keep the token volume
        // bounded so the suite stays fast.
        for sc in registry() {
            let tr = sc.trace(true, 42);
            let out_tokens: u64 = tr.requests.iter().map(|r| r.true_output_tokens as u64).sum();
            assert!(
                out_tokens < 120_000,
                "{}: {} output tokens is too heavy for quick mode",
                sc.name,
                out_tokens
            );
            assert!(tr.len() < 2_000, "{}: {} requests is too many for quick mode", sc.name, tr.len());
        }
    }

    #[test]
    fn find_resolves_names() {
        assert!(find("flash_crowd").is_some());
        assert!(find("nope").is_none());
    }
}
