//! Arrival processes for client request streams.

/// How a client's inter-arrival gaps are drawn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Fixed-rate: gap = 1/rate. Used by the paper's §7.2.1 balanced-load
    /// and App A overload scenarios.
    Deterministic,
    /// Poisson process: exponential gaps. §7.2.2 and the vLLM runs.
    Poisson,
}

/// A time-varying arrival intensity, for the App A dynamic-load scenario,
/// the LMSYS-like bursty traces, and the adversarial scenario library
/// (flash crowds, diurnal load).
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    Constant(f64),
    /// rate_before until t_switch, then rate_after.
    Step { before: f64, after: f64, at: f64 },
    /// Piecewise-constant rate over equal-width half-open windows:
    /// window `i` covers `[i·window, (i+1)·window)`. Times before the
    /// first window clamp to the first rate; times at or past the end of
    /// the last window clamp to the last rate.
    Piecewise { window: f64, rates: Vec<f64> },
    /// Diurnal-style sinusoid: `base + amplitude·sin(2π·t/period + phase)`,
    /// clamped at zero (the trough of an oversized amplitude is a quiet
    /// period, not a negative rate).
    Sinusoid { base: f64, amplitude: f64, period: f64, phase: f64 },
}

impl ArrivalProcess {
    /// The same process with every intensity multiplied by `factor` —
    /// used to scale single-engine scenarios up to cluster-level offered
    /// load (N replicas want ~N× the traffic of one).
    pub fn scaled(&self, factor: f64) -> ArrivalProcess {
        match self {
            ArrivalProcess::Constant(r) => ArrivalProcess::Constant(r * factor),
            ArrivalProcess::Step { before, after, at } => {
                ArrivalProcess::Step { before: before * factor, after: after * factor, at: *at }
            }
            ArrivalProcess::Piecewise { window, rates } => ArrivalProcess::Piecewise {
                window: *window,
                rates: rates.iter().map(|r| r * factor).collect(),
            },
            ArrivalProcess::Sinusoid { base, amplitude, period, phase } => {
                ArrivalProcess::Sinusoid {
                    base: base * factor,
                    amplitude: amplitude * factor,
                    period: *period,
                    phase: *phase,
                }
            }
        }
    }

    /// Mean intensity over `[start, stop]` — the quantity population
    /// rescaling (`Scenario::with_clients`) must judge a tenant by. A
    /// time-varying tenant (flash-crowd spike, diurnal sinusoid) can sit
    /// far above its window mean at any single instant, so clamping
    /// decisions taken from `rate_at(start)` misclassify it; this
    /// integrates the profile instead. Constant/Step/Piecewise use exact
    /// closed forms; Sinusoid uses a fixed 256-point midpoint rule (a
    /// deterministic pure function of the inputs, so every caller agrees
    /// bit-for-bit). Degenerate windows (`stop <= start`, non-finite
    /// span) fall back to the instantaneous rate at `start`.
    pub fn mean_rate(&self, start: f64, stop: f64) -> f64 {
        let span = stop - start;
        if !(span.is_finite() && span > 0.0) {
            return self.rate_at(start);
        }
        match self {
            ArrivalProcess::Constant(r) => *r,
            ArrivalProcess::Step { before, after, at } => {
                let before_span = (at.min(stop) - start).clamp(0.0, span);
                (before * before_span + after * (span - before_span)) / span
            }
            ArrivalProcess::Piecewise { window, rates } => {
                if rates.is_empty() {
                    return 0.0;
                }
                if window.is_nan() || *window <= 0.0 {
                    return rates[rates.len() - 1];
                }
                // Walk the piecewise-constant segments covering the
                // window, mirroring rate_at's clamp-to-first /
                // clamp-to-last indexing.
                let mut acc = 0.0;
                let mut t = start;
                while t < stop {
                    let idx = ((t / window).floor().max(0.0) as usize).min(rates.len() - 1);
                    let next = if idx + 1 < rates.len() {
                        ((idx as f64 + 1.0) * window).min(stop)
                    } else {
                        stop
                    };
                    acc += rates[idx] * (next - t);
                    t = next;
                }
                acc / span
            }
            ArrivalProcess::Sinusoid { .. } => {
                let n = 256;
                let h = span / n as f64;
                let mut acc = 0.0;
                for k in 0..n {
                    acc += self.rate_at(start + (k as f64 + 0.5) * h);
                }
                acc / n as f64
            }
        }
    }

    pub fn rate_at(&self, t: f64) -> f64 {
        match self {
            ArrivalProcess::Constant(r) => *r,
            ArrivalProcess::Step { before, after, at } => {
                if t < *at {
                    *before
                } else {
                    *after
                }
            }
            ArrivalProcess::Piecewise { window, rates } => {
                if rates.is_empty() {
                    return 0.0;
                }
                // Degenerate window (zero, negative, NaN): no meaningful
                // subdivision — the whole axis is the last window.
                if window.is_nan() || *window <= 0.0 {
                    return rates[rates.len() - 1];
                }
                // Half-open windows [i·w, (i+1)·w). `t/window as usize`
                // saturates at 0 for negative t (clamp-to-first) and the
                // min() clamps past-end to the last rate. An exact
                // boundary t = i·w lands in window i (the one it opens).
                let idx = ((t / window) as usize).min(rates.len() - 1);
                rates[idx]
            }
            ArrivalProcess::Sinusoid { base, amplitude, period, phase } => {
                if period.is_nan() || *period <= 0.0 {
                    return base.max(0.0);
                }
                let w = 2.0 * std::f64::consts::PI * t / period + phase;
                (base + amplitude * w.sin()).max(0.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_multiplies_every_shape() {
        let shapes = vec![
            ArrivalProcess::Constant(2.0),
            ArrivalProcess::Step { before: 1.0, after: 4.0, at: 10.0 },
            ArrivalProcess::Piecewise { window: 2.0, rates: vec![1.0, 3.0] },
            ArrivalProcess::Sinusoid { base: 2.0, amplitude: 1.0, period: 8.0, phase: 0.0 },
        ];
        for p in shapes {
            let s = p.scaled(3.0);
            for t in [0.0, 2.0, 5.0, 11.0] {
                assert!(
                    (s.rate_at(t) - 3.0 * p.rate_at(t)).abs() < 1e-12,
                    "{p:?} at t={t}"
                );
            }
        }
    }

    #[test]
    fn mean_rate_closed_forms_are_exact() {
        // Constant: the mean is the rate, any window.
        let c = ArrivalProcess::Constant(2.5);
        assert_eq!(c.mean_rate(0.0, 10.0), 2.5);
        // Step straddling the switch: overlap-weighted average.
        let s = ArrivalProcess::Step { before: 1.0, after: 5.0, at: 10.0 };
        assert!((s.mean_rate(0.0, 20.0) - 3.0).abs() < 1e-12);
        assert_eq!(s.mean_rate(0.0, 10.0), 1.0, "window entirely before");
        assert_eq!(s.mean_rate(10.0, 20.0), 5.0, "window entirely after");
        // Piecewise over exact windows: plain average of the rates.
        let p = ArrivalProcess::Piecewise { window: 2.0, rates: vec![1.0, 3.0, 5.0] };
        assert!((p.mean_rate(0.0, 6.0) - 3.0).abs() < 1e-12);
        // Partial overlap: [1, 3] covers half of window 0 and half of 1.
        assert!((p.mean_rate(1.0, 3.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_rate_honours_piecewise_clamping() {
        let p = ArrivalProcess::Piecewise { window: 1.0, rates: vec![2.0, 8.0] };
        // Before t=0 the first rate holds (clamp-to-first): [-1, 1] is
        // two seconds of rate 2.
        assert!((p.mean_rate(-1.0, 1.0) - 2.0).abs() < 1e-12);
        // Past the last window the last rate holds forever.
        assert!((p.mean_rate(2.0, 100.0) - 8.0).abs() < 1e-12);
        // Straddling everything: 2s at 2.0 (t in [-1,1)), 1s at 8.0,
        // then 2s more at 8.0.
        assert!((p.mean_rate(-1.0, 4.0) - (2.0 * 2.0 + 3.0 * 8.0) / 5.0).abs() < 1e-12);
        // Degenerate shapes defer to rate_at's conventions.
        let empty = ArrivalProcess::Piecewise { window: 1.0, rates: vec![] };
        assert_eq!(empty.mean_rate(0.0, 5.0), 0.0);
        let degen = ArrivalProcess::Piecewise { window: 0.0, rates: vec![1.0, 9.0] };
        assert_eq!(degen.mean_rate(0.0, 5.0), 9.0);
    }

    #[test]
    fn mean_rate_integrates_the_sinusoid() {
        // Full periods with base >= amplitude: the sine integrates away
        // and the mean is the base.
        let p = ArrivalProcess::Sinusoid { base: 1.2, amplitude: 1.0, period: 20.0, phase: 0.0 };
        assert!((p.mean_rate(0.0, 40.0) - 1.2).abs() < 1e-9);
        // Half-period over the positive hump: base + amp·2/π.
        let expect = 1.2 + 1.0 * 2.0 / std::f64::consts::PI;
        assert!((p.mean_rate(0.0, 10.0) - expect).abs() < 1e-3);
        // Zero-clamped trough pulls the mean above base − would-be
        // negative lobes don't cancel the peaks.
        let deep = ArrivalProcess::Sinusoid { base: 0.5, amplitude: 2.0, period: 8.0, phase: 0.0 };
        assert!(deep.mean_rate(0.0, 8.0) > 0.5);
    }

    #[test]
    fn mean_rate_degenerate_window_is_instantaneous_rate() {
        let s = ArrivalProcess::Step { before: 1.0, after: 5.0, at: 10.0 };
        assert_eq!(s.mean_rate(3.0, 3.0), 1.0);
        assert_eq!(s.mean_rate(12.0, 11.0), 5.0, "inverted window");
        assert_eq!(s.mean_rate(0.0, f64::INFINITY), 1.0, "non-finite span");
    }

    #[test]
    fn step_switches() {
        let p = ArrivalProcess::Step { before: 1.0, after: 4.0, at: 10.0 };
        assert_eq!(p.rate_at(5.0), 1.0);
        assert_eq!(p.rate_at(10.0), 4.0);
        assert_eq!(p.rate_at(99.0), 4.0);
    }

    #[test]
    fn piecewise_indexes_and_clamps() {
        let p = ArrivalProcess::Piecewise { window: 2.0, rates: vec![1.0, 3.0, 5.0] };
        assert_eq!(p.rate_at(0.5), 1.0);
        assert_eq!(p.rate_at(2.5), 3.0);
        assert_eq!(p.rate_at(100.0), 5.0);
    }

    #[test]
    fn piecewise_windows_are_half_open() {
        let p = ArrivalProcess::Piecewise { window: 2.0, rates: vec![1.0, 3.0, 5.0] };
        // An exact boundary belongs to the window it OPENS.
        assert_eq!(p.rate_at(0.0), 1.0);
        assert_eq!(p.rate_at(2.0), 3.0);
        assert_eq!(p.rate_at(4.0), 5.0);
        // Just below a boundary still reads the earlier window.
        assert_eq!(p.rate_at(2.0 - 1e-9), 1.0);
        assert_eq!(p.rate_at(4.0 - 1e-9), 3.0);
    }

    #[test]
    fn piecewise_clamps_before_start_and_past_end() {
        let p = ArrivalProcess::Piecewise { window: 1.0, rates: vec![2.0, 7.0] };
        // Negative times clamp to the first window (float→usize cast
        // saturates at zero) — a trace generator probing t slightly
        // before zero must not panic or wrap.
        assert_eq!(p.rate_at(-0.5), 2.0);
        assert_eq!(p.rate_at(-1e9), 2.0);
        // At and past the end of the last window: last rate, forever.
        assert_eq!(p.rate_at(2.0), 7.0);
        assert_eq!(p.rate_at(1e9), 7.0);
    }

    #[test]
    fn empty_piecewise_is_zero() {
        let p = ArrivalProcess::Piecewise { window: 1.0, rates: vec![] };
        assert_eq!(p.rate_at(1.0), 0.0);
        assert_eq!(p.rate_at(-1.0), 0.0);
    }

    #[test]
    fn degenerate_window_is_last_rate() {
        // Zero / negative / NaN windows have no subdivision to index —
        // the clamp-to-last rule degenerates to "always the last rate"
        // instead of dividing by zero.
        for w in [0.0, -3.0, f64::NAN] {
            let p = ArrivalProcess::Piecewise { window: w, rates: vec![1.0, 9.0] };
            assert_eq!(p.rate_at(0.0), 9.0, "window={w}");
            assert_eq!(p.rate_at(5.0), 9.0, "window={w}");
        }
    }

    #[test]
    fn sinusoid_oscillates_and_clamps_at_zero() {
        let p = ArrivalProcess::Sinusoid { base: 1.0, amplitude: 2.0, period: 4.0, phase: 0.0 };
        assert!((p.rate_at(0.0) - 1.0).abs() < 1e-12);
        assert!((p.rate_at(1.0) - 3.0).abs() < 1e-9, "peak at quarter period");
        // Trough would be -1.0 — clamped to a quiet period.
        assert_eq!(p.rate_at(3.0), 0.0);
        // Periodic.
        assert!((p.rate_at(5.0) - p.rate_at(1.0)).abs() < 1e-9);
    }

    #[test]
    fn sinusoid_phase_shifts_the_peak() {
        let a = ArrivalProcess::Sinusoid { base: 2.0, amplitude: 1.0, period: 8.0, phase: 0.0 };
        let b = ArrivalProcess::Sinusoid {
            base: 2.0,
            amplitude: 1.0,
            period: 8.0,
            phase: std::f64::consts::PI,
        };
        // Half-period phase offset: one tenant peaks while the other dips.
        assert!((a.rate_at(2.0) - 3.0).abs() < 1e-9);
        assert!((b.rate_at(2.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sinusoid_degenerate_period_is_base() {
        let p = ArrivalProcess::Sinusoid { base: 1.5, amplitude: 4.0, period: 0.0, phase: 1.0 };
        assert_eq!(p.rate_at(3.0), 1.5);
    }
}
