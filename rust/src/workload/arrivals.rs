//! Arrival processes for client request streams.

/// How a client's inter-arrival gaps are drawn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Fixed-rate: gap = 1/rate. Used by the paper's §7.2.1 balanced-load
    /// and App A overload scenarios.
    Deterministic,
    /// Poisson process: exponential gaps. §7.2.2 and the vLLM runs.
    Poisson,
}

/// A time-varying arrival intensity, for the App A dynamic-load scenario
/// and the LMSYS-like bursty traces.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    Constant(f64),
    /// rate_before until t_switch, then rate_after.
    Step { before: f64, after: f64, at: f64 },
    /// Piecewise-constant rate over equal-width windows.
    Piecewise { window: f64, rates: Vec<f64> },
}

impl ArrivalProcess {
    pub fn rate_at(&self, t: f64) -> f64 {
        match self {
            ArrivalProcess::Constant(r) => *r,
            ArrivalProcess::Step { before, after, at } => {
                if t < *at {
                    *before
                } else {
                    *after
                }
            }
            ArrivalProcess::Piecewise { window, rates } => {
                if rates.is_empty() {
                    return 0.0;
                }
                let idx = ((t / window) as usize).min(rates.len() - 1);
                rates[idx]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_switches() {
        let p = ArrivalProcess::Step { before: 1.0, after: 4.0, at: 10.0 };
        assert_eq!(p.rate_at(5.0), 1.0);
        assert_eq!(p.rate_at(10.0), 4.0);
        assert_eq!(p.rate_at(99.0), 4.0);
    }

    #[test]
    fn piecewise_indexes_and_clamps() {
        let p = ArrivalProcess::Piecewise { window: 2.0, rates: vec![1.0, 3.0, 5.0] };
        assert_eq!(p.rate_at(0.5), 1.0);
        assert_eq!(p.rate_at(2.5), 3.0);
        assert_eq!(p.rate_at(100.0), 5.0);
    }

    #[test]
    fn empty_piecewise_is_zero() {
        let p = ArrivalProcess::Piecewise { window: 1.0, rates: vec![] };
        assert_eq!(p.rate_at(1.0), 0.0);
    }
}
