//! Distribution-matched substitutes for the ShareGPT and LMSYS-Chat-1M
//! traces used in §7.3 and Appendix B.
//!
//! We cannot ship the datasets, but the fairness results depend on the
//! *length and arrival distributions*, which are published: both corpora
//! have heavy-tailed (approximately log-normal) input/output lengths, and
//! the paper's own MoPE boundaries put the 33rd/66th percentiles of LMSYS
//! output lengths at 53 and 210 tokens. The generators below are fit to
//! those quantiles, and `python/compile/corpus.py` mirrors the same
//! distributions for MoPE training so the rust and python sides agree.

use super::Trace;
use crate::core::ClientId;
use crate::util::dist;
use crate::util::rng::Rng;

/// Common interface for trace-like generators.
pub trait TraceGen {
    /// Draw one request's (input_tokens, output_tokens).
    fn lengths(&self, rng: &mut Rng) -> (u32, u32);
}

/// LMSYS-Chat-1M-like lengths. Output: log-normal fit to the paper's §7.1
/// MoPE boundaries — P33 = 53 and P66 = 210 tokens. Solving
/// `ln 53 = ln m + σ·z₀.₃₃` and `ln 210 = ln m + σ·z₀.₆₆`
/// (z₀.₃₃ = −0.440, z₀.₆₆ = +0.412) gives median m ≈ 108, gsd ≈ 5.0.
/// Input: log-normal median 55, gsd 3.2 (chat prompts skew short).
#[derive(Debug, Clone)]
pub struct LmsysLike {
    pub in_median: f64,
    pub in_gsd: f64,
    pub out_median: f64,
    pub out_gsd: f64,
    pub max_len: u32,
    /// Generation cap: LMSYS-arena models were served with ~1k max new
    /// tokens, so the output tail is clamped (matters for MoPE error
    /// calibration).
    pub out_max: u32,
}

impl Default for LmsysLike {
    fn default() -> Self {
        LmsysLike { in_median: 55.0, in_gsd: 3.2, out_median: 108.0, out_gsd: 5.0, max_len: 4096, out_max: 1024 }
    }
}

impl TraceGen for LmsysLike {
    fn lengths(&self, rng: &mut Rng) -> (u32, u32) {
        let i = dist::log_normal_median(rng, self.in_median, self.in_gsd);
        let o = dist::log_normal_median(rng, self.out_median, self.out_gsd);
        (
            (i.round() as u32).clamp(1, self.max_len),
            (o.round() as u32).clamp(1, self.out_max),
        )
    }
}

/// ShareGPT-like lengths: longer prompts and longer answers than LMSYS
/// (multi-turn conversations pasted as single prompts). Medians from the
/// commonly reported ShareGPT serving-benchmark statistics.
#[derive(Debug, Clone)]
pub struct ShareGptLike {
    pub in_median: f64,
    pub in_gsd: f64,
    pub out_median: f64,
    pub out_gsd: f64,
    pub max_len: u32,
    pub out_max: u32,
}

impl Default for ShareGptLike {
    fn default() -> Self {
        ShareGptLike { in_median: 180.0, in_gsd: 3.0, out_median: 200.0, out_gsd: 2.5, max_len: 4096, out_max: 1024 }
    }
}

impl TraceGen for ShareGptLike {
    fn lengths(&self, rng: &mut Rng) -> (u32, u32) {
        let i = dist::log_normal_median(rng, self.in_median, self.in_gsd);
        let o = dist::log_normal_median(rng, self.out_median, self.out_gsd);
        (
            (i.round() as u32).clamp(1, self.max_len),
            (o.round() as u32).clamp(1, self.out_max),
        )
    }
}

/// §7.3.1 SGLang/ShareGPT workload: `clients` tenants, total-arrival rate
/// `rps`, `total_prompts` requests, Poisson arrivals, Zipf-skewed client
/// popularity (real multi-tenant traffic is never uniform).
pub fn sharegpt_trace(clients: usize, rps: f64, total_prompts: usize, seed: u64) -> Trace {
    let gen = ShareGptLike::default();
    let mut rng = Rng::new(seed);
    let mut events = Vec::with_capacity(total_prompts);
    let mut t = 0.0f64;
    for _ in 0..total_prompts {
        t += dist::exponential(&mut rng, rps);
        let c = dist::zipf(&mut rng, clients, 0.9) as u32;
        let (i, o) = gen.lengths(&mut rng);
        events.push((t, ClientId(c), i, o));
    }
    let horizon = t;
    Trace::from_events(events, horizon)
}

/// §7.3.2 vLLM/ShareGPT workload: `clients` tenants each at `per_client_rps`
/// Poisson, `per_client_requests` requests each.
pub fn sharegpt_per_client_trace(
    clients: usize,
    per_client_rps: f64,
    per_client_requests: usize,
    seed: u64,
) -> Trace {
    let mut root = Rng::new(seed);
    let mut events = Vec::new();
    let mut horizon = 0.0f64;
    for c in 0..clients {
        let mut rng = root.fork(c as u64 + 1);
        // Mild per-client heterogeneity: real tenants replay different
        // ShareGPT slices, so their length profiles differ somewhat.
        let gen = ShareGptLike {
            in_median: 180.0 * dist::log_normal_median(&mut rng, 1.0, 1.25),
            out_median: 200.0 * dist::log_normal_median(&mut rng, 1.0, 1.25),
            ..ShareGptLike::default()
        };
        let mut t = 0.0f64;
        for _ in 0..per_client_requests {
            t += dist::exponential(&mut rng, per_client_rps);
            let (i, o) = gen.lengths(&mut rng);
            events.push((t, ClientId(c as u32), i, o));
        }
        horizon = horizon.max(t);
    }
    Trace::from_events(events, horizon)
}

/// Heterogeneous multi-tenant workload: half the tenants send frequent
/// short prefill-heavy requests, half send rare long decode-heavy ones,
/// with equal nominal weighted-token demand. This is the regime where
/// token-count fairness and holistic fairness diverge (Fig 13/14's
/// cross-system comparison): identical-demand homogeneous tenants would
/// make every scheduler look perfectly fair.
pub fn mixed_tenants_trace(pairs: usize, duration: f64, seed: u64) -> Trace {
    let mut root = Rng::new(seed);
    let mut events = Vec::new();
    for p in 0..pairs {
        // Short/prefill-heavy tenant: 4 rps of (256 in, 48 out) → weighted
        // 4·(256+192) ≈ 1792/s.
        let mut rng = root.fork(2 * p as u64 + 1);
        let mut t = 0.0;
        loop {
            t += dist::exponential(&mut rng, 4.0);
            if t >= duration {
                break;
            }
            let i = dist::log_normal_median(&mut rng, 256.0, 1.6).round().clamp(1.0, 2048.0) as u32;
            let o = dist::log_normal_median(&mut rng, 48.0, 1.6).round().clamp(1.0, 512.0) as u32;
            events.push((t, ClientId(2 * p as u32), i, o));
        }
        // Long/decode-heavy tenant: 0.55 rps of (64 in, 760 out) → weighted
        // ≈ 1707/s.
        let mut rng = root.fork(2 * p as u64 + 2);
        let mut t = 0.0;
        loop {
            t += dist::exponential(&mut rng, 0.55);
            if t >= duration {
                break;
            }
            let i = dist::log_normal_median(&mut rng, 64.0, 1.6).round().clamp(1.0, 2048.0) as u32;
            let o = dist::log_normal_median(&mut rng, 760.0, 1.4).round().clamp(1.0, 1024.0) as u32;
            events.push((t, ClientId(2 * p as u32 + 1), i, o));
        }
    }
    Trace::from_events(events, duration)
}

/// App B LMSYS/S-LoRA workload: `clients` tenants with bursty
/// piecewise-constant rates (real chatbot-arena traffic fluctuates), over
/// `duration` seconds. Per-client mean rates are Zipf-skewed.
pub fn lmsys_trace(clients: usize, duration: f64, mean_total_rps: f64, seed: u64) -> Trace {
    let gen = LmsysLike::default();
    let mut root = Rng::new(seed);
    // Zipf-ish weights for per-client mean rates.
    let weights: Vec<f64> = (1..=clients).map(|k| (k as f64).powf(-0.8)).collect();
    let wsum: f64 = weights.iter().sum();
    let window = (duration / 12.0).max(1.0);
    let nwin = (duration / window).ceil() as usize;
    let mut events = Vec::new();
    for c in 0..clients {
        let mut rng = root.fork(c as u64 + 1);
        let mean_rate = mean_total_rps * weights[c] / wsum;
        // Bursty: per-window rate = mean * lognormal(1, 1.8).
        let rates: Vec<f64> = (0..nwin)
            .map(|_| mean_rate * dist::log_normal_median(&mut rng, 1.0, 1.8))
            .collect();
        let mut t = 0.0f64;
        loop {
            let idx = ((t / window) as usize).min(nwin - 1);
            let r = rates[idx].max(1e-6);
            t += dist::exponential(&mut rng, r);
            if t >= duration {
                break;
            }
            let (i, o) = gen.lengths(&mut rng);
            events.push((t, ClientId(c as u32), i, o));
        }
    }
    Trace::from_events(events, duration)
}

/// Multi-turn chat sessions with growing prefixes: every turn's prompt
/// re-sends the conversation so far (user turns + model answers), so the
/// per-request input length ratchets up within a session until a reset.
/// This is the workload where prefill cost grows superlinearly per tenant
/// while output stays flat — token-count fairness undercharges it badly.
pub fn multi_turn_trace(clients: usize, duration: f64, seed: u64) -> Trace {
    let mut root = Rng::new(seed);
    let mut events = Vec::new();
    for c in 0..clients {
        let mut rng = root.fork(c as u64 + 1);
        let mut t = 0.0f64;
        // Conversation prefix carried into the next turn's prompt.
        let mut prefix = 0u32;
        loop {
            // Think time between turns.
            t += dist::exponential(&mut rng, 0.5);
            if t >= duration {
                break;
            }
            let user = dist::log_normal_median(&mut rng, 40.0, 2.0).round().clamp(1.0, 512.0) as u32;
            let out = dist::log_normal_median(&mut rng, 96.0, 2.0).round().clamp(1.0, 512.0) as u32;
            let input = (prefix + user).min(3072);
            events.push((t, ClientId(c as u32), input, out));
            prefix = (prefix + user + out).min(2816);
            // Session ends; the next turn starts a fresh conversation.
            if rng.chance(0.15) {
                prefix = 0;
            }
        }
    }
    Trace::from_events(events, duration)
}

/// Trace-mix composite: half the tenants draw LMSYS-like lengths, half
/// ShareGPT-like, all Poisson at `per_client_rps`. Mixing the two length
/// regimes in one run is what real multi-tenant serving looks like —
/// no single length distribution describes the batch.
pub fn trace_mix(pairs: usize, per_client_rps: f64, duration: f64, seed: u64) -> Trace {
    let lmsys = LmsysLike::default();
    let sharegpt = ShareGptLike::default();
    let mut root = Rng::new(seed);
    let mut events = Vec::new();
    for c in 0..2 * pairs {
        let mut rng = root.fork(c as u64 + 1);
        let gen: &dyn TraceGen = if c % 2 == 0 { &lmsys } else { &sharegpt };
        let mut t = 0.0f64;
        loop {
            t += dist::exponential(&mut rng, per_client_rps);
            if t >= duration {
                break;
            }
            let (i, o) = gen.lengths(&mut rng);
            events.push((t, ClientId(c as u32), i, o));
        }
    }
    Trace::from_events(events, duration)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quantile_of(gen: &dyn TraceGen, q: f64, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut outs: Vec<f64> = (0..n).map(|_| gen.lengths(&mut rng).1 as f64).collect();
        outs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        outs[(q * (n - 1) as f64) as usize]
    }

    #[test]
    fn lmsys_output_quantiles_match_mope_boundaries() {
        // Paper §7.1: boundaries at the 33rd/66th percentiles are 53 / 210.
        let gen = LmsysLike::default();
        let p33 = quantile_of(&gen, 0.33, 60_000, 1);
        let p66 = quantile_of(&gen, 0.66, 60_000, 2);
        assert!((p33 - 53.0).abs() / 53.0 < 0.25, "p33={p33}");
        assert!((p66 - 210.0).abs() / 210.0 < 0.25, "p66={p66}");
    }

    #[test]
    fn sharegpt_trace_counts_and_rate() {
        let tr = sharegpt_trace(256, 8.0, 1280, 3);
        assert_eq!(tr.len(), 1280);
        // Mean arrival rate ≈ 8 rps.
        let rate = tr.len() as f64 / tr.horizon;
        assert!((rate - 8.0).abs() < 1.0, "rate={rate}");
        // Many distinct clients get traffic.
        assert!(tr.num_clients() > 100);
    }

    #[test]
    fn per_client_trace_has_all_clients() {
        let tr = sharegpt_per_client_trace(4, 3.5, 100, 5);
        assert_eq!(tr.num_clients(), 4);
        assert_eq!(tr.len(), 400);
    }

    #[test]
    fn lmsys_trace_is_skewed_and_bursty() {
        let tr = lmsys_trace(27, 300.0, 6.0, 7);
        assert!(tr.num_clients() >= 20);
        let mut counts = vec![0usize; 27];
        for r in tr.requests.iter() {
            counts[r.client.0 as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().filter(|&&c| c > 0).min().unwrap();
        assert!(max > 3 * min.max(1), "max={max} min={min}");
    }

    #[test]
    fn mixed_tenants_have_equalish_demand() {
        let tr = mixed_tenants_trace(2, 200.0, 9);
        assert_eq!(tr.num_clients(), 4);
        let demand = |c: u32| -> f64 {
            tr.requests
                .iter()
                .filter(|r| r.client == ClientId(c))
                .map(|r| r.weighted_tokens())
                .sum::<f64>()
        };
        let short = demand(0);
        let long = demand(1);
        assert!((short / long - 1.0).abs() < 0.35, "short={short} long={long}");
    }

    #[test]
    fn multi_turn_prefixes_grow_within_sessions() {
        let tr = multi_turn_trace(3, 120.0, 4);
        assert_eq!(tr.num_clients(), 3);
        // Within one client's stream, later turns of a session carry the
        // conversation prefix: a strictly larger input than the first
        // turn of the run must appear many times.
        for c in 0..3u32 {
            let inputs: Vec<u32> = tr
                .requests
                .iter()
                .filter(|r| r.client == ClientId(c))
                .map(|r| r.input_tokens)
                .collect();
            assert!(inputs.len() > 10, "client {c} sent {} turns", inputs.len());
            let first = inputs[0];
            let grown = inputs.iter().filter(|&&i| i > first).count();
            assert!(
                grown * 2 > inputs.len(),
                "client {c}: prefixes must grow (first={first}, grown {grown}/{})",
                inputs.len()
            );
        }
        // The growth is bounded by the context cap.
        assert!(tr.requests.iter().all(|r| r.input_tokens <= 3072));
    }

    #[test]
    fn trace_mix_combines_both_length_regimes() {
        let tr = trace_mix(3, 1.0, 120.0, 5);
        assert_eq!(tr.num_clients(), 6);
        // ShareGPT-like tenants (odd ids) have clearly longer median
        // prompts than LMSYS-like tenants (even ids): 180 vs 55.
        let median_in = |parity: u32| -> f64 {
            let mut xs: Vec<f64> = tr
                .requests
                .iter()
                .filter(|r| r.client.0 % 2 == parity)
                .map(|r| r.input_tokens as f64)
                .collect();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            xs[xs.len() / 2]
        };
        assert!(
            median_in(1) > 1.5 * median_in(0),
            "sharegpt median {} vs lmsys {}",
            median_in(1),
            median_in(0)
        );
    }

    #[test]
    fn lengths_always_positive_and_bounded() {
        let gen = ShareGptLike::default();
        let mut rng = Rng::new(11);
        for _ in 0..10_000 {
            let (i, o) = gen.lengths(&mut rng);
            assert!(i >= 1 && i <= 4096);
            assert!(o >= 1 && o <= 1024);
        }
    }
}
