//! Scheduling policies: FCFS, RPM quotas, VTC (Sheng et al.), and the
//! paper's contribution — the Equinox holistic-fairness scheduler.
//!
//! The `Scheduler` trait is iteration-oriented to match continuous
//! batching: each engine iteration the batcher repeatedly asks the policy
//! to `pick` its next candidate subject to a feasibility closure
//! (`can_schedule` in Algorithm 1), and feeds back per-batch actuals via
//! `on_complete` so counter-based policies close the loop.

pub mod counters;
pub mod equinox;
pub mod fcfs;
pub mod guard;
pub mod index;
pub mod reference;
pub mod rpm;
pub mod vtc;

pub use counters::{hf_score, AdmitReceipt, HolisticCounters, HfParams};
pub use equinox::EquinoxSched;
pub use guard::{CalibrationTracker, GuardHealth, GuardMode, GuardPolicy};
pub use fcfs::Fcfs;
pub use index::{OrderedScore, ScoreIndex};
pub use reference::{LinearEquinox, LinearVtc, MapEquinox, MapRpm, MapVtc};
pub use rpm::Rpm;
pub use vtc::Vtc;

use crate::core::{ClientId, ClientMap, ClientMapFamily, Request, SlabFamily};

/// Actual metrics of a completed request/batch (Algorithm 1 line 19–21).
#[derive(Debug, Clone, Copy)]
pub struct Actuals {
    pub latency: f64,
    pub gpu_util: f64,
    pub tps: f64,
    pub output_tokens: u32,
}

/// A scheduling policy over per-client queues.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// A request (with predictions attached) arrives at the server queue.
    fn enqueue(&mut self, req: Request, now: f64);

    /// Select the next request to admit, subject to the batcher's
    /// feasibility check. Implementations must be *work conserving*: if
    /// the preferred client's head request is infeasible they should try
    /// other clients before giving up. Returns `None` when nothing
    /// feasible is queued. On success the policy has already applied its
    /// admission-time counter update (Algorithm 1 line 15).
    fn pick(&mut self, now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request>;

    /// Return a request to the head of its queue (preemption path).
    fn requeue(&mut self, req: Request);

    /// Incremental service feedback: `weighted_delta` weighted tokens
    /// were just rendered to `client`. The per-token engine calls this
    /// once per decode token; the macro-stepping engine aggregates a
    /// whole event-horizon window into one call (`4·k` for `k` tokens) —
    /// implementations must treat the delta as an amount, never as "one
    /// token happened". The OSDI VTC implementation charges its counter
    /// exactly this way; predictive schedulers already charged at
    /// admission and ignore it.
    fn on_progress(&mut self, _client: ClientId, _weighted_delta: f64) {}

    /// Next wall-clock time at which this policy's own admissibility can
    /// change with no engine-side event (quota/window refresh). `None`
    /// when admissibility is time-independent — every policy here except
    /// RPM. The engine uses the hint to advance idle periods and to bound
    /// decode macro-steps in O(1) instead of spinning per token. The hint
    /// may be conservative (earlier than the true change — the engine
    /// just probes again) but must never be later than it.
    fn next_refresh_at(&self, _now: f64) -> Option<f64> {
        None
    }

    /// Feedback with actual metrics after a request completes.
    fn on_complete(&mut self, req: &Request, actual: &Actuals, now: f64);

    /// Queued requests (all clients).
    fn queue_len(&self) -> usize;

    /// Visit the clients that currently have queued (backlogged) work, in
    /// ascending client-id order — the VTC-paper fairness bound is stated
    /// over co-backlogged intervals, and the engine samples this every
    /// window. A visitor instead of a returned `Vec` keeps the sampling
    /// path allocation-free (the engine reuses one scratch buffer).
    fn for_each_queued_client(&self, f: &mut dyn FnMut(ClientId));

    /// Collected form of `for_each_queued_client` — convenience for tests
    /// and cold paths; allocates.
    fn queued_clients(&self) -> Vec<ClientId> {
        let mut out = Vec::new();
        self.for_each_queued_client(&mut |c| out.push(c));
        out
    }

    /// Number of clients with queued work. Implementations that already
    /// hold the active set as a map override this to O(1); the default
    /// counts via the visitor.
    fn queued_client_count(&self) -> usize {
        let mut n = 0usize;
        self.for_each_queued_client(&mut |_| n += 1);
        n
    }

    fn is_empty(&self) -> bool {
        self.queue_len() == 0
    }

    /// Whether this policy consumes predictions (drives the ablation and
    /// lets the engine reserve KV by predicted length — the paper's
    /// stall-free scheduling optimisation).
    fn uses_predictions(&self) -> bool {
        false
    }

    /// Discrepancy introspection: the policy's internal service-accounting
    /// score for `client` — VTC's virtual token counter, Equinox's HF
    /// score. `None` for policies without a fairness counter (FCFS, RPM).
    /// The conformance harness records the active-set score spread per
    /// cell; the bounded-discrepancy property says HF/counter equalisation
    /// keeps delivered service close, so a diverging spread between
    /// co-backlogged clients is the first symptom of a broken policy.
    fn fairness_score(&self, _client: ClientId) -> Option<f64> {
        None
    }

    /// What quantity [`fairness_score`](Scheduler::fairness_score)
    /// returns, for trace annotation: the flight recorder stamps pick
    /// decisions with the chosen and best losing score, and this label
    /// tells the reader whether those are HF scores, virtual token
    /// counters, quota deficits, or plain arrival order.
    fn score_label(&self) -> &'static str {
        "score"
    }

    /// Export the policy's cumulative per-client fairness counters as
    /// (client, ufc-like, rfc-like) triples — the pull path the cluster's
    /// global dual-counter plane drains on its sync period. Policies
    /// without counters (FCFS, RPM) export nothing; VTC exports its
    /// virtual token counter in the UFC slot with RFC 0. Exports are
    /// cumulative, not deltas: the plane differences successive pulls
    /// itself, so a pull is idempotent and sync-period independent.
    fn export_counters(&self, _f: &mut dyn FnMut(ClientId, f64, f64)) {}

    /// Number of admission receipts currently held against in-flight
    /// requests (`None` when the policy keeps none). Receipts are created
    /// at `pick` and destroyed at `on_complete`/`requeue`; after a fully
    /// drained run this must be 0 — a leak means preemption refunds can
    /// double-bill (the conformance harness asserts it every cell).
    fn outstanding_receipts(&self) -> Option<usize> {
        None
    }

    /// The calibration guard's current degradation-ladder rung, `None`
    /// for schedulers without a guard attached. The engine polls this
    /// after completions and records a `GuardTransition` trace event on
    /// every change.
    fn guard_mode(&self) -> Option<GuardMode> {
        None
    }

    /// Exported guard state (Prometheus gauges, harness verdicts);
    /// `None` without a guard.
    fn guard_health(&self) -> Option<GuardHealth> {
        None
    }

    /// Whether this scheduler ships the Equinox *system* optimisations
    /// (§4/§7: adaptive batching + chunked-prefill coordination). The
    /// baselines run the stock host behaviour; Equinox piggybacks prefill
    /// chunks onto decode iterations even on hosts that stall decode for
    /// prefill (S-LoRA) — the source of its TTFT/throughput edge.
    fn system_optimizations(&self) -> bool {
        false
    }

    /// Remove and return EVERY queued request, applying NO admission-time
    /// counter charges, quota stamps, or receipt creation — the requests
    /// are not being scheduled, they are leaving this scheduler (replica
    /// failure: the cluster driver extracts a dead replica's queue for
    /// migration). The extraction order is deterministic (a pure function
    /// of queue state). The default routes through `pick` with an always-true
    /// feasibility check, which is only correct for policies whose `pick`
    /// is charge-free (FCFS and friends); every counter/quota/receipt
    /// policy overrides this with a plain queue drain.
    fn drain_queued(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while let Some(r) = self.pick(0.0, &mut |_| true) {
            out.push(r);
        }
        out
    }
}

/// Per-client FIFO queues with deterministic iteration order — the shared
/// substrate under every policy.
///
/// Storage-family generic (default: dense `ClientSlab`, which also keeps
/// a drained client's deque buffer around so reactivation after churn is
/// allocation-free); `BTreeFamily` instantiates the identical code over
/// `BTreeMap` for the retained slab-vs-BTreeMap reference.
#[derive(Debug, Default)]
pub struct ClientQueues<F: ClientMapFamily = SlabFamily> {
    queues: F::Map<std::collections::VecDeque<Request>>,
    len: usize,
}

impl<F: ClientMapFamily> ClientQueues<F> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_back(&mut self, req: Request) {
        self.queues.or_default(req.client).push_back(req);
        self.len += 1;
    }

    pub fn push_front(&mut self, req: Request) {
        self.queues.or_default(req.client).push_front(req);
        self.len += 1;
    }

    pub fn head(&self, client: ClientId) -> Option<&Request> {
        self.queues.get(client).and_then(|q| q.front())
    }

    pub fn pop(&mut self, client: ClientId) -> Option<Request> {
        let q = self.queues.get_mut(client)?;
        let r = q.pop_front();
        if r.is_some() {
            self.len -= 1;
        }
        if q.is_empty() {
            // Retire (not take): the emptied deque is Default-equivalent,
            // and the slab keeps its buffer for the client's next burst.
            self.queues.retire(client);
        }
        r
    }

    /// Clients that currently have queued work, in id order. Allocates —
    /// retained for the linear-scan reference schedulers and tests; hot
    /// paths use `for_each_active`.
    pub fn active_clients(&self) -> Vec<ClientId> {
        let mut out = Vec::with_capacity(self.queues.len());
        self.queues.for_each(&mut |c, _| out.push(c));
        out
    }

    /// Allocation-free visitor over active clients, in id order.
    pub fn for_each_active(&self, f: &mut dyn FnMut(ClientId)) {
        self.queues.for_each(&mut |c, _| f(c));
    }

    /// Number of clients with queued work. O(1).
    pub fn active_count(&self) -> usize {
        self.queues.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn client_len(&self, client: ClientId) -> usize {
        self.queues.get(client).map(|q| q.len()).unwrap_or(0)
    }

    /// Remove and return everything, in (client-id, FIFO) order — the
    /// charge-free substrate under `Scheduler::drain_queued`.
    pub fn drain_all(&mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.len);
        self.queues.for_each_mut(&mut |_, q| out.extend(q.drain(..)));
        self.queues.clear();
        self.len = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestId;

    fn req(id: u64, client: u32) -> Request {
        Request::new(RequestId(id), ClientId(client), 10, 10, 0.0)
    }

    #[test]
    fn queues_fifo_per_client() {
        let mut q: ClientQueues = ClientQueues::new();
        q.push_back(req(1, 0));
        q.push_back(req(2, 0));
        q.push_back(req(3, 1));
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(ClientId(0)).unwrap().id, RequestId(1));
        assert_eq!(q.pop(ClientId(0)).unwrap().id, RequestId(2));
        assert!(q.pop(ClientId(0)).is_none());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn push_front_preempts_order() {
        let mut q: ClientQueues = ClientQueues::new();
        q.push_back(req(1, 0));
        q.push_front(req(2, 0));
        assert_eq!(q.pop(ClientId(0)).unwrap().id, RequestId(2));
    }

    #[test]
    fn active_clients_drops_empty() {
        let mut q: ClientQueues = ClientQueues::new();
        q.push_back(req(1, 3));
        q.push_back(req(2, 1));
        assert_eq!(q.active_clients(), vec![ClientId(1), ClientId(3)]);
        q.pop(ClientId(1));
        assert_eq!(q.active_clients(), vec![ClientId(3)]);
    }

    #[test]
    fn drain_all_empties_in_client_fifo_order() {
        let mut q: ClientQueues = ClientQueues::new();
        q.push_back(req(1, 3));
        q.push_back(req(2, 1));
        q.push_back(req(3, 1));
        let out = q.drain_all();
        assert_eq!(
            out.iter().map(|r| r.id.0).collect::<Vec<_>>(),
            vec![2, 3, 1],
            "client-id order, FIFO within client"
        );
        assert!(q.is_empty());
        assert_eq!(q.active_count(), 0);
        assert_eq!(q.client_len(ClientId(1)), 0);
    }
}
