//! Retained linear-scan reference schedulers — the seed's O(C) pick
//! paths, kept as the *executable specification* of the indexed cores.
//!
//! Two consumers:
//! - `tests/properties.rs` drives randomized operation sequences through
//!   an indexed scheduler and its reference twin and asserts identical
//!   pick order (the index is a pure performance structure — it must
//!   never change a scheduling decision).
//! - `benches/scheduler.rs` runs both in the same process so the
//!   asymptotic win is measured against the real baseline, not a guess
//!   (EXPERIMENTS.md §Perf records the tenant-scaling table).
//!
//! Semantics match the indexed implementations exactly — including the
//! lift-on-reactivation fix and the receipt-based preemption refund — only the
//! data structures differ: selection is a full scan over a freshly
//! collected candidate `Vec`, and lifts rescan all active clients.

use super::counters::{AdmitReceipt, HfParams, HolisticCounters};
use super::{Actuals, ClientQueues, Scheduler};
use crate::core::{BTreeFamily, ClientId, Request, RequestId};
use std::collections::{BTreeMap, HashMap};

/// `BTreeMap`-backed twin of the production (slab-backed) `Vtc` — the
/// IDENTICAL indexed algorithm instantiated over pointer-chasing
/// storage. `tests/scale.rs` replays the adversarial registry on both
/// and asserts bit-identical fingerprints; `benches/scale.rs` measures
/// the storage-layer speedup against it.
pub type MapVtc = super::Vtc<BTreeFamily>;
/// `BTreeMap`-backed twin of the production `EquinoxSched`.
pub type MapEquinox = super::EquinoxSched<BTreeFamily>;
/// `BTreeMap`-backed twin of the production `Rpm` quota scheduler.
pub type MapRpm = super::Rpm<BTreeFamily>;

/// Linear-scan VTC: min-counter selection via O(C) scan per pick.
#[derive(Debug, Default)]
pub struct LinearVtc {
    queues: ClientQueues,
    counters: BTreeMap<ClientId, f64>,
    /// ω_f adopted from `Request::weight` — identical entitlement
    /// arithmetic to the indexed `Vtc` (charges divide by ω).
    weights: BTreeMap<ClientId, f64>,
    pub w_in: f64,
    pub w_out: f64,
    pub use_predictions: bool,
}

impl LinearVtc {
    pub fn new() -> Self {
        LinearVtc {
            queues: ClientQueues::new(),
            counters: BTreeMap::new(),
            weights: BTreeMap::new(),
            w_in: 1.0,
            w_out: 4.0,
            use_predictions: false,
        }
    }

    pub fn with_predictions() -> Self {
        LinearVtc { use_predictions: true, ..Self::new() }
    }

    pub fn counter(&self, client: ClientId) -> f64 {
        self.counters.get(&client).cloned().unwrap_or(0.0)
    }

    fn admission_charge(&self, req: &Request) -> f64 {
        let tokens = if self.use_predictions {
            self.w_in * req.input_tokens as f64 + self.w_out * req.predicted_output_tokens as f64
        } else {
            self.w_in * req.input_tokens as f64
        };
        tokens / if req.weight > 0.0 { req.weight } else { 1.0 }
    }

    fn weight_of(&self, client: ClientId) -> f64 {
        self.weights.get(&client).copied().unwrap_or(1.0)
    }
}

impl Scheduler for LinearVtc {
    fn name(&self) -> &'static str {
        if self.use_predictions {
            "vtc+pred-linear"
        } else {
            "vtc-linear"
        }
    }

    fn enqueue(&mut self, req: Request, _now: f64) {
        if req.weight > 0.0 {
            self.weights.insert(req.client, req.weight);
        }
        let was_active = self.queues.client_len(req.client) > 0;
        if !was_active {
            // Lift on every inactive→active transition: O(C) scan over
            // the clients with queued work (the lifted client has none).
            let mut min_active = f64::INFINITY;
            self.queues.for_each_active(&mut |c| {
                if c != req.client {
                    min_active = min_active.min(self.counter(c));
                }
            });
            let cur = self.counter(req.client);
            let lifted = if min_active.is_finite() { cur.max(min_active) } else { cur };
            self.counters.insert(req.client, lifted);
        }
        self.queues.push_back(req);
    }

    fn pick(&mut self, _now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request> {
        // The seed's linear min-scan with an exclusion list; comparison
        // via total_cmp so ordering matches the indexed BTreeSet exactly.
        let mut excluded: Vec<ClientId> = Vec::new();
        loop {
            let mut best: Option<(f64, ClientId)> = None;
            self.queues.for_each_active(&mut |client| {
                if excluded.contains(&client) {
                    return;
                }
                let c = self.counter(client);
                let better = match best {
                    Some((bc, bid)) => c.total_cmp(&bc).then(client.cmp(&bid)).is_lt(),
                    None => true,
                };
                if better {
                    best = Some((c, client));
                }
            });
            let Some((_, client)) = best else { return None };
            let ok = {
                let head = self.queues.head(client).unwrap();
                feasible(head)
            };
            if ok {
                let req = self.queues.pop(client).unwrap();
                let charge = self.admission_charge(&req);
                *self.counters.entry(client).or_insert(0.0) += charge;
                return Some(req);
            }
            excluded.push(client);
        }
    }

    fn requeue(&mut self, req: Request) {
        let charge = self.admission_charge(&req);
        if let Some(c) = self.counters.get_mut(&req.client) {
            *c = (*c - charge).max(0.0);
        }
        self.queues.push_front(req);
    }

    fn on_progress(&mut self, client: ClientId, weighted_delta: f64) {
        // Amount-based like the indexed twin: one aggregated macro-window
        // delta must land exactly where per-token deltas would.
        if !self.use_predictions {
            let w = self.weight_of(client);
            *self.counters.entry(client).or_insert(0.0) += weighted_delta / w;
        }
    }

    fn on_complete(&mut self, req: &Request, actual: &Actuals, _now: f64) {
        if self.use_predictions {
            let w = if req.weight > 0.0 { req.weight } else { 1.0 };
            let c = self.counters.entry(req.client).or_insert(0.0);
            *c += self.w_out * (actual.output_tokens as f64 - req.predicted_output_tokens as f64)
                / w;
            *c = c.max(0.0);
        }
    }

    fn queue_len(&self) -> usize {
        self.queues.len()
    }

    fn for_each_queued_client(&self, f: &mut dyn FnMut(ClientId)) {
        self.queues.for_each_active(f);
    }

    fn queued_client_count(&self) -> usize {
        self.queues.active_count()
    }

    fn uses_predictions(&self) -> bool {
        self.use_predictions
    }

    fn fairness_score(&self, client: ClientId) -> Option<f64> {
        Some(self.counter(client))
    }

    fn drain_queued(&mut self) -> Vec<Request> {
        // Charge-free extraction — the linear twin has no side index to
        // clear, so the plain queue drain is the whole story.
        self.queues.drain_all()
    }
}

/// Linear-scan Equinox: argmin-HF via O(C) scan over a collected
/// candidate `Vec` per pick attempt (the seed's Algorithm 1 loop).
#[derive(Debug)]
pub struct LinearEquinox {
    queues: ClientQueues,
    counters: HolisticCounters,
    peak_tps: f64,
    default_weight: f64,
    in_flight: HashMap<RequestId, AdmitReceipt>,
}

impl LinearEquinox {
    pub fn new(params: HfParams, peak_tps: f64) -> Self {
        LinearEquinox {
            queues: ClientQueues::new(),
            counters: HolisticCounters::new(params),
            peak_tps,
            default_weight: 1.0,
            in_flight: HashMap::new(),
        }
    }

    pub fn default_params(peak_tps: f64) -> Self {
        Self::new(HfParams::default(), peak_tps)
    }

    pub fn hf(&self, client: ClientId) -> f64 {
        self.counters.hf(client)
    }

    pub fn raw(&self, client: ClientId) -> (f64, f64) {
        self.counters.raw(client)
    }
}

impl Scheduler for LinearEquinox {
    fn name(&self) -> &'static str {
        "equinox-linear"
    }

    fn enqueue(&mut self, req: Request, _now: f64) {
        let was_active = self.queues.client_len(req.client) > 0;
        self.counters.touch(req.client, self.default_weight);
        if !was_active {
            let active = self.queues.active_clients();
            self.counters.lift_to_active_min(req.client, &active);
        }
        self.queues.push_back(req);
    }

    fn pick(&mut self, now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request> {
        let mut cands = self.queues.active_clients();
        while !cands.is_empty() {
            let c = self.counters.argmin_hf(&cands)?;
            let ok = {
                let head = self.queues.head(c).unwrap();
                feasible(head)
            };
            if ok {
                let req = self.queues.pop(c).unwrap();
                let receipt = self.counters.charge_admission(&req, now, self.peak_tps);
                self.in_flight.insert(req.id, receipt);
                return Some(req);
            }
            cands.retain(|&x| x != c);
        }
        None
    }

    fn requeue(&mut self, req: Request) {
        let client = req.client;
        let receipt = self.in_flight.remove(&req.id);
        self.queues.push_front(req);
        if let Some(receipt) = receipt {
            self.counters.refund_admission(client, receipt);
        }
    }

    fn on_complete(&mut self, req: &Request, actual: &Actuals, now: f64) {
        self.in_flight.remove(&req.id);
        self.counters.correct_on_complete(
            req,
            actual.output_tokens,
            actual.latency,
            actual.tps,
            actual.gpu_util,
            self.peak_tps,
            now,
        );
    }

    fn queue_len(&self) -> usize {
        self.queues.len()
    }

    fn for_each_queued_client(&self, f: &mut dyn FnMut(ClientId)) {
        self.queues.for_each_active(f);
    }

    fn queued_client_count(&self) -> usize {
        self.queues.active_count()
    }

    fn uses_predictions(&self) -> bool {
        true
    }

    fn system_optimizations(&self) -> bool {
        true
    }

    fn fairness_score(&self, client: ClientId) -> Option<f64> {
        Some(self.hf(client))
    }

    fn outstanding_receipts(&self) -> Option<usize> {
        Some(self.in_flight.len())
    }

    fn drain_queued(&mut self) -> Vec<Request> {
        // Charge-free extraction; queued work holds no receipts and the
        // linear twin keeps no active index, so the drain is plain.
        self.queues.drain_all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, client: u32, input: u32, out: u32) -> Request {
        let mut r = Request::new(RequestId(id), ClientId(client), input, out, 0.0);
        r.predicted_output_tokens = out;
        r.predicted_latency = 1.0;
        r.predicted_tps = 1000.0;
        r.predicted_gpu_util = 0.8;
        r
    }

    #[test]
    fn linear_vtc_min_counter_first() {
        let mut s = LinearVtc::new();
        s.enqueue(req(1, 0, 100, 10), 0.0);
        s.enqueue(req(2, 1, 10, 10), 0.0);
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(0));
        s.enqueue(req(3, 0, 10, 10), 0.0);
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(1));
    }

    #[test]
    fn linear_equinox_serves_underserved_first() {
        let mut s = LinearEquinox::default_params(2600.0);
        s.enqueue(req(0, 0, 1000, 1000), 0.0);
        s.enqueue(req(1, 1, 10, 10), 0.0);
        s.enqueue(req(10, 0, 100, 100), 0.0);
        s.enqueue(req(11, 1, 100, 100), 0.0);
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(0));
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(1));
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(1));
    }
}
