//! Virtual Token Counter (Sheng et al., OSDI'24) — the paper's primary
//! baseline. Tracks cumulative weighted tokens per client; admits the
//! client with the smallest counter; lifts reactivating clients to the
//! minimum active counter for work conservation.
//!
//! Selection is served by a [`ScoreIndex`] over the active set: the
//! min-counter client is an O(log C) `first()` and every counter change
//! re-keys in O(log C), versus the seed's O(C) scan per pick (retained as
//! [`super::reference::LinearVtc`] — the differential property tests
//! prove identical pick order). See EXPERIMENTS.md §Perf.

use super::guard::{CalibrationTracker, GuardHealth, GuardMode, GuardPolicy};
use super::index::ScoreIndex;
use super::{Actuals, ClientQueues, Scheduler};
use crate::core::{ClientId, ClientMap, ClientMapFamily, Request, RequestId, SlabFamily};
use std::collections::HashMap;

/// Storage-family generic (default: dense `ClientSlab` hot path; the
/// `BTreeFamily` instantiation is the retained like-for-like reference,
/// exported as [`super::reference::MapVtc`]).
#[derive(Debug, Default)]
pub struct Vtc<F: ClientMapFamily = SlabFamily> {
    queues: ClientQueues<F>,
    counters: F::Map<f64>,
    /// Per-client priority weight ω_f, adopted from `Request::weight` at
    /// enqueue. Entitlement semantics (weighted-VTC): every charge is
    /// divided by ω, so counter equalisation delivers service ∝ ω.
    weights: F::Map<f64>,
    /// Active (queued-work) clients keyed by counter value; membership is
    /// maintained on queue empty/non-empty transitions, keys on every
    /// counter mutation of an active client.
    active: ScoreIndex<F>,
    /// Input vs output token weights (paper/VTC pricing: 1 and 4).
    pub w_in: f64,
    pub w_out: f64,
    /// If true, charge by predicted output at admission and correct at
    /// completion (the "VTC + predictor" ablation rows). If false
    /// (baseline VTC) charge input at admission and outputs as they are
    /// observed at completion.
    pub use_predictions: bool,
    /// Optional calibration guard (predictive mode only): rescales or
    /// zeroes the predicted-token part of the admission charge per its
    /// ladder rung. `None` (default) is the exact pre-guard code path.
    guard: Option<CalibrationTracker<F>>,
    /// Output tokens actually charged per in-flight request — populated
    /// ONLY when a guard is attached (guard charges are state-dependent,
    /// so refund/correction must replay the admitted amount, not
    /// recompute it). Stays empty — and allocation-free — unguarded.
    in_flight_charged: HashMap<RequestId, f64>,
}

impl Vtc {
    /// Production (slab-backed) VTC.
    pub fn new() -> Self {
        Self::for_family()
    }

    /// VTC with a predictor attached (Table 1's "VTC + Single/MoPE/Oracle").
    pub fn with_predictions() -> Self {
        Self::for_family_with_predictions()
    }

    /// Predictive VTC with a calibration guard attached.
    pub fn with_predictions_guarded(policy: GuardPolicy) -> Self {
        Self::for_family_with_predictions_guarded(policy)
    }
}

impl<F: ClientMapFamily> Vtc<F> {
    /// Constructor for an explicit storage family (`Vtc::new` pins the
    /// slab; `MapVtc` in `sched/reference.rs` pins the `BTreeMap` twin).
    pub fn for_family() -> Self {
        Vtc {
            queues: ClientQueues::new(),
            counters: Default::default(),
            weights: Default::default(),
            active: ScoreIndex::new(),
            w_in: 1.0,
            w_out: 4.0,
            use_predictions: false,
            guard: None,
            in_flight_charged: HashMap::new(),
        }
    }

    /// Predictive variant of [`Vtc::for_family`].
    pub fn for_family_with_predictions() -> Self {
        Vtc { use_predictions: true, ..Self::for_family() }
    }

    /// Guarded predictive variant of [`Vtc::for_family`].
    pub fn for_family_with_predictions_guarded(policy: GuardPolicy) -> Self {
        Vtc {
            guard: Some(CalibrationTracker::for_family(policy)),
            ..Self::for_family_with_predictions()
        }
    }

    pub fn counter(&self, client: ClientId) -> f64 {
        self.counters.get(client).cloned().unwrap_or(0.0)
    }

    /// Admission charge in virtual-time units: token price divided by the
    /// request's ω_f — a pure function of the request, so a preemption
    /// refund reverses it exactly.
    fn admission_charge(&self, req: &Request) -> f64 {
        self.charge_with_out(req, req.predicted_output_tokens as f64)
    }

    /// Admission charge pricing an explicit output-token amount (the
    /// guard's debiased/zeroed charges). `admission_charge` delegates
    /// here with the raw prediction, so the unguarded path is
    /// bit-identical to the pre-guard code. Guard charges are
    /// state-dependent, NOT a pure function of the request — guarded
    /// refunds/corrections replay the admitted amount from
    /// `in_flight_charged` instead of recomputing.
    fn charge_with_out(&self, req: &Request, out_tokens: f64) -> f64 {
        let tokens = if self.use_predictions {
            self.w_in * req.input_tokens as f64 + self.w_out * out_tokens
        } else {
            self.w_in * req.input_tokens as f64
        };
        tokens / if req.weight > 0.0 { req.weight } else { 1.0 }
    }

    /// The output tokens admission charged for an in-flight request:
    /// the recorded guarded amount, or the raw prediction unguarded.
    fn take_charged_out(&mut self, req: &Request) -> f64 {
        if self.guard.is_some() {
            self.in_flight_charged
                .remove(&req.id)
                .unwrap_or(req.predicted_output_tokens as f64)
        } else {
            req.predicted_output_tokens as f64
        }
    }

    fn weight_of(&self, client: ClientId) -> f64 {
        self.weights.get(client).copied().unwrap_or(1.0)
    }

    /// Re-key an active client after a counter change. O(log C).
    fn refresh(&mut self, client: ClientId) {
        if self.active.contains(client) {
            let c = self.counter(client);
            self.active.insert(client, c);
        }
    }
}

impl<F: ClientMapFamily> Scheduler for Vtc<F> {
    fn name(&self) -> &'static str {
        match (self.use_predictions, self.guard.as_ref().map(|g| g.policy())) {
            (false, _) => "vtc",
            (true, None) => "vtc+pred",
            (true, Some(GuardPolicy::Debias)) => "vtc+pred+debias",
            (true, Some(GuardPolicy::Ladder)) => "vtc+pred+ladder",
        }
    }

    fn score_label(&self) -> &'static str {
        "vtc_counter"
    }

    fn enqueue(&mut self, req: Request, _now: f64) {
        if req.weight > 0.0 {
            self.weights.insert(req.client, req.weight);
        }
        let was_active = self.queues.client_len(req.client) > 0;
        if !was_active {
            // Lift on EVERY inactive→active transition (OSDI VTC §4), not
            // only first sight: a tenant that drains and later returns is
            // raised to the active minimum, so it cannot bank idle time.
            // (The seed early-returned for known clients — a returning
            // tenant kept its stale low counter and monopolised service.)
            let min_active = self.active.min_score();
            let cur = self.counter(req.client);
            let lifted = match min_active {
                Some(m) => cur.max(m),
                None => cur,
            };
            self.counters.insert(req.client, lifted);
            self.active.insert(req.client, lifted);
        }
        self.queues.push_back(req);
    }

    fn pick(&mut self, _now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request> {
        // Min-counter-first, work conserving across infeasible heads:
        // walk the active index in ascending (counter, id) order and take
        // the first feasible head — O(log C) in the common case, and no
        // exclusion list or candidate Vec (EXPERIMENTS.md §Perf; the seed
        // linear min-scan cost ~170 µs per full sort at 256 tenants).
        let mut chosen: Option<ClientId> = None;
        for (_counter, client) in self.active.iter_by_score() {
            let Some(head) = self.queues.head(client) else { continue };
            if feasible(head) {
                chosen = Some(client);
                break;
            }
        }
        let client = chosen?;
        let req = self.queues.pop(client).expect("active client has queued work");
        if self.queues.client_len(client) == 0 {
            self.active.remove(client);
        }
        let out_tokens = match &self.guard {
            None => req.predicted_output_tokens as f64,
            Some(g) => g.charged_tokens(req.predicted_output_tokens),
        };
        if self.guard.is_some() {
            self.in_flight_charged.insert(req.id, out_tokens);
        }
        let charge = self.charge_with_out(&req, out_tokens);
        *self.counters.or_default(client) += charge;
        self.refresh(client);
        Some(req)
    }

    fn requeue(&mut self, req: Request) {
        // Refund the admission charge — exact: unguarded it is a pure
        // function of the request; guarded it replays the recorded
        // admitted amount.
        let client = req.client;
        let out_tokens = self.take_charged_out(&req);
        let charge = self.charge_with_out(&req, out_tokens);
        if let Some(c) = self.counters.get_mut(client) {
            *c = (*c - charge).max(0.0);
        }
        self.queues.push_front(req);
        // Reactivation without lift — the preempted tenant was running,
        // not idle. `insert` both activates and re-keys.
        let cur = self.counter(client);
        self.active.insert(client, cur);
    }

    fn on_progress(&mut self, client: ClientId, weighted_delta: f64) {
        // Faithful OSDI VTC: the counter tracks service as it is
        // rendered. The delta is an amount, not an event — the macro-
        // stepping engine delivers a whole decode window (4·k) in one
        // call, which lands the counter exactly where k per-token calls
        // would. The stored ω_f divides the charge (entitlement).
        // Predictive variants charged at admission.
        if !self.use_predictions {
            let w = self.weight_of(client);
            *self.counters.or_default(client) += weighted_delta / w;
            self.refresh(client);
        }
    }

    fn on_complete(&mut self, req: &Request, actual: &Actuals, _now: f64) {
        if self.use_predictions {
            // Feed the calibration tracker first — the updated factor
            // and ladder apply from the next admission on.
            if let Some(g) = &mut self.guard {
                g.observe(req.client, req.predicted_output_tokens, actual.output_tokens);
            }
            // Correct prediction error: replace what admission CHARGED
            // (raw, debiased, or zero) with the actual. Unguarded, the
            // charged amount is the raw prediction — bit-identical to
            // the pre-guard correction.
            let charged_out = self.take_charged_out(req);
            {
                let w = if req.weight > 0.0 { req.weight } else { 1.0 };
                let w_out = self.w_out;
                let c = self.counters.or_default(req.client);
                *c += w_out * (actual.output_tokens as f64 - charged_out) / w;
                *c = c.max(0.0);
            }
            self.refresh(req.client);
        }
        // Baseline VTC already charged everything via on_progress
        // (input at admission + per-token output).
    }

    fn queue_len(&self) -> usize {
        self.queues.len()
    }

    fn for_each_queued_client(&self, f: &mut dyn FnMut(ClientId)) {
        self.queues.for_each_active(f);
    }

    fn queued_client_count(&self) -> usize {
        self.queues.active_count()
    }

    fn uses_predictions(&self) -> bool {
        self.use_predictions
    }

    fn fairness_score(&self, client: ClientId) -> Option<f64> {
        Some(self.counter(client))
    }

    fn guard_mode(&self) -> Option<GuardMode> {
        self.guard.as_ref().map(|g| g.mode())
    }

    fn guard_health(&self) -> Option<GuardHealth> {
        self.guard.as_ref().map(|g| g.health())
    }

    fn outstanding_receipts(&self) -> Option<usize> {
        // Guarded runs record per-request charged amounts — receipt-like
        // state that must fully drain (the harness asserts 0 after every
        // cell). Unguarded VTC keeps none.
        self.guard.as_ref().map(|_| self.in_flight_charged.len())
    }

    fn export_counters(&self, f: &mut dyn FnMut(ClientId, f64, f64)) {
        // The virtual token counter maps onto the UFC slot of the global
        // dual-counter plane; VTC has no resource-fairness signal.
        // Ascending id order on every storage family.
        self.counters.for_each(&mut |c, &v| f(c, v, 0.0));
    }

    fn drain_queued(&mut self) -> Vec<Request> {
        // Charge-free extraction (replica failover): the requests leave
        // without being scheduled, so no admission charge and no counter
        // mutation — only the active index empties with the queues.
        // Counters persist: if the client routes back here later it pays
        // from where it left off, and the reactivation lift still applies.
        for c in self.queues.active_clients() {
            self.active.remove(c);
        }
        self.queues.drain_all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestId;

    fn req(id: u64, client: u32, input: u32, out: u32) -> Request {
        Request::new(RequestId(id), ClientId(client), input, out, 0.0)
    }

    fn actuals(out: u32) -> Actuals {
        Actuals { latency: 1.0, gpu_util: 0.8, tps: 1000.0, output_tokens: out }
    }

    #[test]
    fn min_counter_first() {
        let mut s = Vtc::new();
        s.enqueue(req(1, 0, 100, 10), 0.0);
        s.enqueue(req(2, 1, 10, 10), 0.0);
        // Pick 1: both counters 0 → client 0 (tie-break by id), charged 100.
        let a = s.pick(0.0, &mut |_| true).unwrap();
        assert_eq!(a.client, ClientId(0));
        // Pick 2: client 1 now has the smaller counter.
        s.enqueue(req(3, 0, 10, 10), 0.0);
        let b = s.pick(0.0, &mut |_| true).unwrap();
        assert_eq!(b.client, ClientId(1));
    }

    #[test]
    fn per_token_progress_charges_output() {
        let mut s = Vtc::new();
        s.enqueue(req(1, 0, 100, 50), 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        assert_eq!(s.counter(ClientId(0)), 100.0);
        // OSDI-faithful: output charged as tokens are generated.
        for _ in 0..50 {
            s.on_progress(ClientId(0), 4.0);
        }
        s.on_complete(&r, &actuals(50), 1.0);
        assert_eq!(s.counter(ClientId(0)), 100.0 + 4.0 * 50.0);
    }

    #[test]
    fn prediction_mode_ignores_progress() {
        let mut s = Vtc::with_predictions();
        let mut r = req(1, 0, 100, 50);
        r.predicted_output_tokens = 50;
        s.enqueue(r, 0.0);
        let _ = s.pick(0.0, &mut |_| true).unwrap();
        let before = s.counter(ClientId(0));
        s.on_progress(ClientId(0), 4.0);
        assert_eq!(s.counter(ClientId(0)), before);
    }

    #[test]
    fn prediction_mode_charges_upfront_and_corrects() {
        let mut s = Vtc::with_predictions();
        let mut r = req(1, 0, 100, 50);
        r.predicted_output_tokens = 80;
        s.enqueue(r, 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        assert_eq!(s.counter(ClientId(0)), 100.0 + 4.0 * 80.0);
        s.on_complete(&r, &actuals(50), 1.0);
        assert_eq!(s.counter(ClientId(0)), 100.0 + 4.0 * 50.0);
    }

    #[test]
    fn work_conserving_skips_infeasible_head() {
        let mut s = Vtc::new();
        let mut big = req(1, 0, 10_000, 10);
        big.input_tokens = 10_000;
        s.enqueue(big, 0.0);
        s.enqueue(req(2, 1, 10, 10), 0.0);
        // Client 0 has min counter but infeasible head → client 1 runs.
        let r = s.pick(0.0, &mut |r| r.input_tokens < 100).unwrap();
        assert_eq!(r.client, ClientId(1));
    }

    #[test]
    fn lift_prevents_idle_banking() {
        let mut s = Vtc::new();
        s.enqueue(req(1, 0, 1000, 10), 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        s.on_complete(&r, &actuals(10), 1.0);
        let c0 = s.counter(ClientId(0));
        assert!(c0 > 0.0);
        // Client 1 arrives later: lifted to client 0's level? Only if
        // client 0 still has queued work; enqueue one more for client 0.
        s.enqueue(req(3, 0, 10, 10), 0.0);
        s.enqueue(req(2, 1, 10, 10), 0.0);
        assert_eq!(s.counter(ClientId(1)), c0);
    }

    /// Regression (indexed-core PR): a tenant that drains and RETURNS is
    /// lifted to the active minimum — the seed's lift early-returned for
    /// any known client, letting returning tenants bank idle time.
    #[test]
    fn lift_applies_on_reactivation_after_drain() {
        let mut s = Vtc::new();
        // Client 0 served a little, then drains (inactive).
        s.enqueue(req(1, 0, 100, 10), 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        s.on_complete(&r, &actuals(10), 1.0);
        assert_eq!(s.counter(ClientId(0)), 100.0);
        // Client 1 meanwhile accumulates a much larger counter and stays
        // backlogged.
        s.enqueue(req(2, 1, 5000, 10), 1.0);
        s.enqueue(req(3, 1, 10, 10), 1.0);
        let r = s.pick(1.0, &mut |_| true).unwrap();
        assert_eq!(r.client, ClientId(1));
        assert_eq!(s.counter(ClientId(1)), 5000.0);
        // Client 0 returns while client 1 is still active: lifted to the
        // active minimum (5000), not left at its stale 100.
        s.enqueue(req(4, 0, 10, 10), 2.0);
        assert_eq!(s.counter(ClientId(0)), 5000.0);
    }

    #[test]
    fn weighted_client_charged_at_half_rate() {
        // Entitlement: ω=2 pays half per token in both the admission
        // charge and the per-token progress charge.
        let mut s = Vtc::new();
        let mut r = req(1, 0, 100, 50);
        r.weight = 2.0;
        s.enqueue(r, 0.0);
        let _ = s.pick(0.0, &mut |_| true).unwrap();
        assert_eq!(s.counter(ClientId(0)), 50.0, "admission: 100 input / ω=2");
        s.on_progress(ClientId(0), 4.0);
        assert_eq!(s.counter(ClientId(0)), 52.0, "progress: 4.0 / ω=2");
    }

    #[test]
    fn exports_counters_for_global_plane() {
        let mut s = Vtc::new();
        s.enqueue(req(1, 0, 100, 10), 0.0);
        let _ = s.pick(0.0, &mut |_| true).unwrap();
        let mut seen = Vec::new();
        s.export_counters(&mut |c, ufc, rfc| seen.push((c, ufc, rfc)));
        assert_eq!(seen, vec![(ClientId(0), 100.0, 0.0)]);
    }

    #[test]
    fn drain_queued_is_charge_free_and_leaves_scheduler_usable() {
        let mut s = Vtc::new();
        s.enqueue(req(1, 0, 100, 10), 0.0);
        s.enqueue(req(2, 1, 10, 10), 0.0);
        let out = s.drain_queued();
        assert_eq!(out.len(), 2);
        assert!(s.is_empty());
        assert_eq!(s.counter(ClientId(0)), 0.0, "drain must not charge admission");
        assert_eq!(s.counter(ClientId(1)), 0.0);
        // Active index emptied with the queues: later traffic still works.
        s.enqueue(req(3, 0, 10, 10), 1.0);
        assert_eq!(s.pick(1.0, &mut |_| true).unwrap().id, RequestId(3));
    }

    /// Guard no-op identity at the VTC level: perfect predictions keep
    /// the guarded counters BIT-identical to the unguarded ones.
    #[test]
    fn guarded_oracle_is_bitwise_noop() {
        for policy in [GuardPolicy::Debias, GuardPolicy::Ladder] {
            let mut plain = Vtc::with_predictions();
            let mut guarded = Vtc::with_predictions_guarded(policy);
            for i in 0..200u64 {
                let out = 1 + ((i * 31) % 800) as u32;
                for s in [&mut plain, &mut guarded] {
                    let mut r = req(i, (i % 4) as u32, 50, out);
                    r.predicted_output_tokens = out;
                    s.enqueue(r, 0.0);
                    let p = s.pick(0.0, &mut |_| true).unwrap();
                    s.on_complete(&p, &actuals(out), 1.0);
                }
            }
            for c in 0..4u32 {
                assert_eq!(
                    plain.counter(ClientId(c)).to_bits(),
                    guarded.counter(ClientId(c)).to_bits(),
                    "{policy:?}, client {c}"
                );
            }
            assert_eq!(guarded.guard_health().unwrap().transitions, 0);
            assert_eq!(guarded.outstanding_receipts(), Some(0));
        }
    }

    /// A guarded refund must replay the ADMITTED amount: the debias
    /// factor keeps moving with observations, so recomputing the charge
    /// at refund time would leave a residue.
    #[test]
    fn guarded_requeue_refund_replays_admitted_amount() {
        let mut s = Vtc::with_predictions_guarded(GuardPolicy::Debias);
        // Warm the guard into a non-unit factor: 2× over-prediction.
        for i in 0..40u64 {
            let mut r = req(i, 0, 10, 100);
            r.predicted_output_tokens = 200;
            s.enqueue(r, 0.0);
            let p = s.pick(0.0, &mut |_| true).unwrap();
            s.on_complete(&p, &actuals(100), 1.0);
        }
        let before = s.counter(ClientId(0));
        let mut r = req(100, 0, 10, 100);
        r.predicted_output_tokens = 200;
        s.enqueue(r, 0.0);
        let p = s.pick(0.0, &mut |_| true).unwrap();
        assert!(s.counter(ClientId(0)) < before + 10.0 + 4.0 * 200.0, "charge was debiased");
        s.requeue(p);
        let after = s.counter(ClientId(0));
        assert!((before - after).abs() < 1e-9, "refund {after} vs pre-admission {before}");
        assert_eq!(s.outstanding_receipts(), Some(0));
    }

    #[test]
    fn requeue_refunds() {
        let mut s = Vtc::new();
        s.enqueue(req(1, 0, 100, 10), 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        assert_eq!(s.counter(ClientId(0)), 100.0);
        s.requeue(r);
        assert_eq!(s.counter(ClientId(0)), 0.0);
        assert_eq!(s.queue_len(), 1);
    }
}
