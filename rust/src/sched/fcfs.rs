//! First-Come-First-Served — the production default the paper critiques:
//! no client isolation, compute-heavy requests monopolise the GPU.

use super::{Actuals, Scheduler};
use crate::core::{ClientId, Request};
use std::collections::{BTreeMap, VecDeque};

#[derive(Debug, Default)]
pub struct Fcfs {
    queue: VecDeque<Request>,
    /// Queued-request count per client, so the engine's backlog sampling
    /// visits clients without sorting/deduping the whole queue.
    per_client: BTreeMap<ClientId, usize>,
}

impl Fcfs {
    pub fn new() -> Self {
        Self::default()
    }

    fn inc(&mut self, client: ClientId) {
        *self.per_client.entry(client).or_insert(0) += 1;
    }

    fn dec(&mut self, client: ClientId) {
        if let Some(n) = self.per_client.get_mut(&client) {
            *n -= 1;
            if *n == 0 {
                self.per_client.remove(&client);
            }
        }
    }
}

impl Scheduler for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn score_label(&self) -> &'static str {
        "arrival_order"
    }

    fn enqueue(&mut self, req: Request, _now: f64) {
        self.inc(req.client);
        self.queue.push_back(req);
    }

    fn pick(&mut self, _now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request> {
        // Strict arrival order: FCFS does NOT skip the head (that is what
        // causes its head-of-line blocking — §7.3.1).
        if let Some(head) = self.queue.front() {
            if feasible(head) {
                let r = self.queue.pop_front().unwrap();
                self.dec(r.client);
                return Some(r);
            }
        }
        None
    }

    fn requeue(&mut self, req: Request) {
        self.inc(req.client);
        self.queue.push_front(req);
    }

    fn on_complete(&mut self, _req: &Request, _actual: &Actuals, _now: f64) {}

    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn for_each_queued_client(&self, f: &mut dyn FnMut(ClientId)) {
        for &c in self.per_client.keys() {
            f(c);
        }
    }

    fn queued_client_count(&self) -> usize {
        self.per_client.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, RequestId};

    fn req(id: u64, client: u32, arrival: f64) -> Request {
        Request::new(RequestId(id), ClientId(client), 10, 10, arrival)
    }

    #[test]
    fn strict_arrival_order() {
        let mut s = Fcfs::new();
        s.enqueue(req(1, 1, 0.0), 0.0);
        s.enqueue(req(2, 0, 1.0), 1.0);
        let a = s.pick(2.0, &mut |_| true).unwrap();
        let b = s.pick(2.0, &mut |_| true).unwrap();
        assert_eq!(a.id, RequestId(1));
        assert_eq!(b.id, RequestId(2));
    }

    #[test]
    fn head_of_line_blocks() {
        let mut s = Fcfs::new();
        let mut big = req(1, 0, 0.0);
        big.input_tokens = 10_000;
        s.enqueue(big, 0.0);
        s.enqueue(req(2, 1, 1.0), 1.0);
        // Head infeasible → nothing is scheduled even though r2 would fit.
        let picked = s.pick(2.0, &mut |r| r.input_tokens < 100);
        assert!(picked.is_none());
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn requeue_restores_head() {
        let mut s = Fcfs::new();
        s.enqueue(req(1, 0, 0.0), 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        s.requeue(r);
        assert_eq!(s.queue_len(), 1);
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().id, RequestId(1));
    }
}
