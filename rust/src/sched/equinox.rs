//! The Equinox holistic-fair scheduler (Algorithm 1): max-min selection on
//! the composite HF score computed from the dual counters, driven by MoPE
//! predictions, with post-batch correction from actual metrics.

use super::counters::{HfParams, HolisticCounters};
use super::{Actuals, ClientQueues, Scheduler};
use crate::core::{ClientId, Request};

#[derive(Debug)]
pub struct EquinoxSched {
    queues: ClientQueues,
    counters: HolisticCounters,
    /// Platform peak TPS for RFC normalisation (§3.3 "normalized").
    peak_tps: f64,
    /// Per-client priority weights ω_f (default 1.0).
    default_weight: f64,
}

impl EquinoxSched {
    pub fn new(params: HfParams, peak_tps: f64) -> Self {
        EquinoxSched {
            queues: ClientQueues::new(),
            counters: HolisticCounters::new(params),
            peak_tps,
            default_weight: 1.0,
        }
    }

    /// Paper-default α=0.7, β=0.3, δ=0.1.
    pub fn default_params(peak_tps: f64) -> Self {
        Self::new(HfParams::default(), peak_tps)
    }

    pub fn hf(&self, client: ClientId) -> f64 {
        self.counters.hf(client)
    }

    pub fn all_hf(&self) -> Vec<(ClientId, f64)> {
        self.counters.all_hf()
    }

    pub fn params(&self) -> HfParams {
        self.counters.params()
    }

    /// Raw (UFC, RFC) for a client — metrics export and tests.
    pub fn raw(&self, client: ClientId) -> (f64, f64) {
        self.counters.raw(client)
    }
}

impl Scheduler for EquinoxSched {
    fn name(&self) -> &'static str {
        "equinox"
    }

    fn enqueue(&mut self, req: Request, _now: f64) {
        // Register and (re)activation-lift against clients with queued
        // work, mirroring VTC's work-conservation lift (§5).
        let was_active = self.queues.client_len(req.client) > 0;
        self.counters.touch(req.client, self.default_weight);
        if !was_active {
            let active = self.queues.active_clients();
            self.counters.lift_to_active_min(req.client, &active);
        }
        self.queues.push_back(req);
    }

    fn pick(&mut self, now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request> {
        // Algorithm 1 lines 10–16: repeatedly take the min-HF client among
        // those with queued work; work conserving across infeasible heads.
        let mut cands = self.queues.active_clients();
        while !cands.is_empty() {
            let c = self.counters.argmin_hf(&cands)?;
            let ok = {
                let head = self.queues.head(c).unwrap();
                feasible(head)
            };
            if ok {
                let req = self.queues.pop(c).unwrap();
                // updateCounter(req, c*): both counters at admission.
                self.counters.update_ufc_on_admit(&req, now);
                self.counters.update_rfc_on_admit(&req, self.peak_tps);
                return Some(req);
            }
            cands.retain(|&x| x != c);
        }
        None
    }

    fn requeue(&mut self, req: Request) {
        // Reverse the admission update (preemption refund) by applying the
        // correction with zero actual service, then re-admitting later
        // recharges. Simpler and safe: subtract the same quantities.
        // We model the refund as a completion with actual == 0 output and
        // predicted == admission values inverted; to keep the counter
        // non-negative semantics, use correct_on_complete with actuals
        // equal to zero-service.
        self.counters.correct_on_complete(
            &req,
            0,
            0.0,
            0.0,
            0.0,
            self.peak_tps,
            req.arrival,
        );
        // The above replaces the predicted charge with a zero-service
        // charge of (input)/(denom) — remove the residual input charge by
        // noting a requeued request will be recharged fully on next pick;
        // the residual slightly overcharges, which is conservative
        // (prevents preemption gaming).
        self.queues.push_front(req);
    }

    fn on_complete(&mut self, req: &Request, actual: &Actuals, now: f64) {
        self.counters.correct_on_complete(
            req,
            actual.output_tokens,
            actual.latency,
            actual.tps,
            actual.gpu_util,
            self.peak_tps,
            now,
        );
    }

    fn queue_len(&self) -> usize {
        self.queues.len()
    }

    fn queued_clients(&self) -> Vec<ClientId> {
        self.queues.active_clients()
    }

    fn uses_predictions(&self) -> bool {
        true
    }

    fn system_optimizations(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestId;

    fn req(id: u64, client: u32, input: u32, out_pred: u32, arrival: f64) -> Request {
        let mut r = Request::new(RequestId(id), ClientId(client), input, out_pred, arrival);
        r.predicted_output_tokens = out_pred;
        r.predicted_latency = 1.0;
        r.predicted_tps = 1000.0;
        r.predicted_gpu_util = 0.8;
        r
    }

    #[test]
    fn serves_underserved_client_first() {
        let mut s = EquinoxSched::default_params(2600.0);
        // Both clients keep work queued (so no reactivation lift applies);
        // client 0 receives a much larger request, so its UFC grows more.
        s.enqueue(req(0, 0, 1000, 1000, 0.0), 0.0);
        s.enqueue(req(1, 1, 10, 10, 0.0), 0.0);
        s.enqueue(req(10, 0, 100, 100, 0.0), 0.0);
        s.enqueue(req(11, 1, 100, 100, 0.0), 0.0);
        let a = s.pick(0.0, &mut |_| true).unwrap(); // tie-break → c0, big charge
        assert_eq!(a.client, ClientId(0));
        let b = s.pick(0.0, &mut |_| true).unwrap(); // c1 now far below
        assert_eq!(b.client, ClientId(1));
        // Client 1 stays underserved → picked again before client 0.
        let c = s.pick(0.0, &mut |_| true).unwrap();
        assert_eq!(c.client, ClientId(1));
    }

    /// The paper's Fig 5 worked example: VTC would pick user0 (fewer
    /// tokens), but user0 already enjoys low latency; with α > β Equinox
    /// identifies user1 as more underserved.
    #[test]
    fn fig5_worked_example() {
        let mut s = EquinoxSched::default_params(2600.0);
        // user0: fewer tokens but served promptly (short waits → full
        // UFC charges). user1: more tokens but badly delayed service
        // (long waits → heavily discounted UFC charges).
        s.enqueue(req(0, 0, 50, 100, 0.0), 0.0);
        s.enqueue(req(1, 1, 80, 150, 0.0), 0.0);
        let a = s.pick(0.0, &mut |_| true).unwrap(); // c0, wait 0 → denom 1.1
        assert_eq!(a.client, ClientId(0));
        let b = s.pick(60.0, &mut |_| true).unwrap(); // c1, wait 60 → denom 7.1
        assert_eq!(b.client, ClientId(1));
        let hf0 = s.hf(ClientId(0));
        let hf1 = s.hf(ClientId(1));
        assert!(hf1 < hf0, "hf0={hf0} hf1={hf1} — user1 should be more underserved");
        // Next round (user1 enqueues while queues are warm): user1 first.
        s.enqueue(req(3, 1, 80, 150, 61.0), 61.0);
        s.enqueue(req(2, 0, 50, 100, 61.0), 61.0);
        assert_eq!(s.pick(61.0, &mut |_| true).unwrap().client, ClientId(1));
    }

    #[test]
    fn work_conserving() {
        let mut s = EquinoxSched::default_params(2600.0);
        let mut big = req(1, 0, 10_000, 10, 0.0);
        big.input_tokens = 10_000;
        s.enqueue(big, 0.0);
        s.enqueue(req(2, 1, 10, 10, 0.0), 0.0);
        let r = s.pick(0.0, &mut |r| r.input_tokens < 100).unwrap();
        assert_eq!(r.client, ClientId(1));
    }

    #[test]
    fn completion_correction_restores_oracle_counters() {
        let mut s = EquinoxSched::default_params(2600.0);
        let mut r = req(1, 0, 100, 50, 0.0); // predicted 50
        r.true_output_tokens = 200;
        s.enqueue(r, 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        let (before, _) = s.raw(ClientId(0));
        s.on_complete(
            &r,
            &Actuals { latency: 1.0, gpu_util: 0.8, tps: 1000.0, output_tokens: 200 },
            1.0,
        );
        let (after, _) = s.raw(ClientId(0));
        assert!(after > before, "underprediction must raise the counter on completion");
    }

    #[test]
    fn declares_prediction_use() {
        assert!(EquinoxSched::default_params(1000.0).uses_predictions());
    }
}
