//! The Equinox holistic-fair scheduler (Algorithm 1): max-min selection on
//! the composite HF score computed from the dual counters, driven by MoPE
//! predictions, with post-batch correction from actual metrics.
//!
//! The max-min pick is served by the incremental score index inside
//! [`HolisticCounters`]: O(log C) for the common feasible-head case and
//! allocation-free, versus the seed's O(C) scan over a fresh candidate
//! `Vec` (retained as [`super::reference::LinearEquinox`] — the
//! differential property tests prove identical pick order).

use super::counters::{AdmitReceipt, HfParams, HolisticCounters};
use super::guard::{CalibrationTracker, GuardHealth, GuardMode, GuardPolicy};
use super::{Actuals, ClientQueues, Scheduler};
use crate::core::{ClientId, ClientMapFamily, Request, RequestId, SlabFamily};
use std::collections::HashMap;

/// Storage-family generic (default: dense `ClientSlab` hot path; the
/// `BTreeFamily` instantiation is the retained like-for-like reference,
/// exported as [`super::reference::MapEquinox`]).
#[derive(Debug)]
pub struct EquinoxSched<F: ClientMapFamily = SlabFamily> {
    queues: ClientQueues<F>,
    counters: HolisticCounters<F>,
    /// Platform peak TPS for RFC normalisation (§3.3 "normalized").
    peak_tps: f64,
    /// Per-client priority weights ω_f (default 1.0).
    default_weight: f64,
    /// Admission receipts of in-flight requests, so a preemption refund
    /// reverses the admission charge exactly (cleared on requeue and on
    /// completion — bounded by the running batch size). Keyed by request,
    /// not client — stays a `HashMap`.
    in_flight: HashMap<RequestId, AdmitReceipt>,
    /// Optional calibration guard (misprediction resilience): rescales
    /// or zeroes the predicted-token admission charge per its
    /// degradation ladder. `None` (the default) is the exact pre-guard
    /// code path.
    guard: Option<CalibrationTracker<F>>,
}

impl EquinoxSched {
    /// Production (slab-backed) Equinox scheduler.
    pub fn new(params: HfParams, peak_tps: f64) -> Self {
        Self::for_family(params, peak_tps)
    }

    /// Paper-default α=0.7, β=0.3, δ=0.1.
    pub fn default_params(peak_tps: f64) -> Self {
        Self::new(HfParams::default(), peak_tps)
    }

    /// Slab-backed Equinox with a calibration guard attached.
    pub fn with_guard(params: HfParams, peak_tps: f64, policy: GuardPolicy) -> Self {
        Self::for_family_with_guard(params, peak_tps, policy)
    }
}

impl<F: ClientMapFamily> EquinoxSched<F> {
    /// Constructor for an explicit storage family (`EquinoxSched::new`
    /// pins the slab; `MapEquinox` in `sched/reference.rs` pins the
    /// `BTreeMap` twin).
    pub fn for_family(params: HfParams, peak_tps: f64) -> Self {
        EquinoxSched {
            queues: ClientQueues::new(),
            counters: HolisticCounters::new(params),
            peak_tps,
            default_weight: 1.0,
            in_flight: HashMap::new(),
            guard: None,
        }
    }

    /// Guarded variant of [`EquinoxSched::for_family`].
    pub fn for_family_with_guard(params: HfParams, peak_tps: f64, policy: GuardPolicy) -> Self {
        EquinoxSched {
            guard: Some(CalibrationTracker::for_family(policy)),
            ..Self::for_family(params, peak_tps)
        }
    }

    pub fn hf(&self, client: ClientId) -> f64 {
        self.counters.hf(client)
    }

    pub fn all_hf(&self) -> Vec<(ClientId, f64)> {
        self.counters.all_hf()
    }

    pub fn params(&self) -> HfParams {
        self.counters.params()
    }

    /// Raw (UFC, RFC) for a client — metrics export and tests.
    pub fn raw(&self, client: ClientId) -> (f64, f64) {
        self.counters.raw(client)
    }
}

impl<F: ClientMapFamily> Scheduler for EquinoxSched<F> {
    fn name(&self) -> &'static str {
        match self.guard.as_ref().map(|g| g.policy()) {
            None => "equinox",
            Some(GuardPolicy::Debias) => "equinox+debias",
            Some(GuardPolicy::Ladder) => "equinox+ladder",
        }
    }

    fn score_label(&self) -> &'static str {
        "hf"
    }

    fn enqueue(&mut self, req: Request, _now: f64) {
        // Register and (re)activation-lift against clients with queued
        // work, mirroring VTC's work-conservation lift (§5). The lift
        // reads the incrementally-tracked active-set minima — O(log C),
        // no scan over all clients.
        let was_active = self.queues.client_len(req.client) > 0;
        self.counters.touch(req.client, self.default_weight);
        if !was_active {
            self.counters.lift_to_active_min_indexed(req.client);
            self.counters.set_active(req.client);
        }
        self.queues.push_back(req);
    }

    fn pick(&mut self, now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request> {
        // Algorithm 1 lines 10–16: walk active clients in ascending
        // (HF, id) order and take the first feasible head — O(log C) in
        // the common case, work conserving across infeasible heads
        // without removing/restoring index entries.
        let mut chosen: Option<ClientId> = None;
        for (_hf, c) in self.counters.active_by_hf() {
            let Some(head) = self.queues.head(c) else { continue };
            if feasible(head) {
                chosen = Some(c);
                break;
            }
        }
        let c = chosen?;
        let req = self.queues.pop(c).expect("active client has queued work");
        if self.queues.client_len(c) == 0 {
            self.counters.set_inactive(c);
        }
        // updateCounter(req, c*): both counters at admission; keep the
        // receipt so a preemption can reverse the charge exactly. With a
        // guard attached the token price follows its ladder rung (raw /
        // debiased / zero); `charged_tokens` for the unguarded path is
        // the raw prediction, making `charge_admission_tokens` here
        // bit-identical to the plain `charge_admission`.
        let out_tokens = match &self.guard {
            None => req.predicted_output_tokens as f64,
            Some(g) => g.charged_tokens(req.predicted_output_tokens),
        };
        let receipt = self.counters.charge_admission_tokens(&req, now, self.peak_tps, out_tokens);
        self.in_flight.insert(req.id, receipt);
        Some(req)
    }

    fn requeue(&mut self, req: Request) {
        // Preemption refund: reverse the admission-time UFC/RFC update
        // (UFC exactly; RFC exactly unless same-client updates interleaved
        // — see HolisticCounters::refund_admission), so the recharge at
        // re-admission leaves the counters as if the request had been
        // admitted once (no double-billing).
        let client = req.client;
        let was_active = self.queues.client_len(client) > 0;
        let receipt = self.in_flight.remove(&req.id);
        self.queues.push_front(req);
        if !was_active {
            // Reactivation without lift: the preempted tenant was just
            // running, it has banked no idle time.
            self.counters.set_active(client);
        }
        if let Some(receipt) = receipt {
            self.counters.refund_admission(client, receipt);
        }
    }

    fn on_complete(&mut self, req: &Request, actual: &Actuals, now: f64) {
        let receipt = self.in_flight.remove(&req.id);
        // Feed the calibration tracker BEFORE the correction: the actual
        // is known here and the updated factor/ladder applies from the
        // next admission on.
        if let Some(g) = &mut self.guard {
            g.observe(req.client, req.predicted_output_tokens, actual.output_tokens);
        }
        // Correct against what admission actually priced (the receipt's
        // charged tokens), not the raw prediction — exact under debiased
        // and actual-only charges and across mid-flight mode changes.
        // No receipt (a migrated-in request completing without a local
        // admission) falls back to the raw prediction, the pre-guard
        // behaviour.
        let charged_out =
            receipt.map_or(req.predicted_output_tokens as f64, |r| r.charged_tokens);
        self.counters.correct_on_complete_charged(
            req,
            charged_out,
            actual.output_tokens,
            actual.latency,
            actual.tps,
            actual.gpu_util,
            self.peak_tps,
            now,
        );
    }

    fn queue_len(&self) -> usize {
        self.queues.len()
    }

    fn for_each_queued_client(&self, f: &mut dyn FnMut(ClientId)) {
        self.queues.for_each_active(f);
    }

    fn queued_client_count(&self) -> usize {
        self.queues.active_count()
    }

    fn uses_predictions(&self) -> bool {
        true
    }

    fn system_optimizations(&self) -> bool {
        true
    }

    fn fairness_score(&self, client: ClientId) -> Option<f64> {
        Some(self.hf(client))
    }

    fn outstanding_receipts(&self) -> Option<usize> {
        Some(self.in_flight.len())
    }

    fn guard_mode(&self) -> Option<GuardMode> {
        self.guard.as_ref().map(|g| g.mode())
    }

    fn guard_health(&self) -> Option<GuardHealth> {
        self.guard.as_ref().map(|g| g.health())
    }

    fn export_counters(&self, f: &mut dyn FnMut(ClientId, f64, f64)) {
        self.counters.for_each_counter(f);
    }

    fn drain_queued(&mut self) -> Vec<Request> {
        // Charge-free extraction (replica failover): deactivate every
        // queued client in the HF index, then hand the queues over whole.
        // No admission charges, no receipts — queued work holds none —
        // and the dual counters persist for the plane's final pull.
        for c in self.queues.active_clients() {
            self.counters.set_inactive(c);
        }
        self.queues.drain_all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestId;

    fn req(id: u64, client: u32, input: u32, out_pred: u32, arrival: f64) -> Request {
        let mut r = Request::new(RequestId(id), ClientId(client), input, out_pred, arrival);
        r.predicted_output_tokens = out_pred;
        r.predicted_latency = 1.0;
        r.predicted_tps = 1000.0;
        r.predicted_gpu_util = 0.8;
        r
    }

    #[test]
    fn serves_underserved_client_first() {
        let mut s = EquinoxSched::default_params(2600.0);
        // Both clients keep work queued (so no reactivation lift applies);
        // client 0 receives a much larger request, so its UFC grows more.
        s.enqueue(req(0, 0, 1000, 1000, 0.0), 0.0);
        s.enqueue(req(1, 1, 10, 10, 0.0), 0.0);
        s.enqueue(req(10, 0, 100, 100, 0.0), 0.0);
        s.enqueue(req(11, 1, 100, 100, 0.0), 0.0);
        let a = s.pick(0.0, &mut |_| true).unwrap(); // tie-break → c0, big charge
        assert_eq!(a.client, ClientId(0));
        let b = s.pick(0.0, &mut |_| true).unwrap(); // c1 now far below
        assert_eq!(b.client, ClientId(1));
        // Client 1 stays underserved → picked again before client 0.
        let c = s.pick(0.0, &mut |_| true).unwrap();
        assert_eq!(c.client, ClientId(1));
    }

    /// The paper's Fig 5 worked example: VTC would pick user0 (fewer
    /// tokens), but user0 already enjoys low latency; with α > β Equinox
    /// identifies user1 as more underserved.
    #[test]
    fn fig5_worked_example() {
        let mut s = EquinoxSched::default_params(2600.0);
        // user0: fewer tokens but served promptly (short waits → full
        // UFC charges). user1: more tokens but badly delayed service
        // (long waits → heavily discounted UFC charges).
        s.enqueue(req(0, 0, 50, 100, 0.0), 0.0);
        s.enqueue(req(1, 1, 80, 150, 0.0), 0.0);
        let a = s.pick(0.0, &mut |_| true).unwrap(); // c0, wait 0 → denom 1.1
        assert_eq!(a.client, ClientId(0));
        let b = s.pick(60.0, &mut |_| true).unwrap(); // c1, wait 60 → denom 7.1
        assert_eq!(b.client, ClientId(1));
        let hf0 = s.hf(ClientId(0));
        let hf1 = s.hf(ClientId(1));
        assert!(hf1 < hf0, "hf0={hf0} hf1={hf1} — user1 should be more underserved");
        // Next round (user1 enqueues while queues are warm): user1 first.
        s.enqueue(req(3, 1, 80, 150, 61.0), 61.0);
        s.enqueue(req(2, 0, 50, 100, 61.0), 61.0);
        assert_eq!(s.pick(61.0, &mut |_| true).unwrap().client, ClientId(1));
    }

    #[test]
    fn work_conserving() {
        let mut s = EquinoxSched::default_params(2600.0);
        let mut big = req(1, 0, 10_000, 10, 0.0);
        big.input_tokens = 10_000;
        s.enqueue(big, 0.0);
        s.enqueue(req(2, 1, 10, 10, 0.0), 0.0);
        let r = s.pick(0.0, &mut |r| r.input_tokens < 100).unwrap();
        assert_eq!(r.client, ClientId(1));
    }

    #[test]
    fn completion_correction_restores_oracle_counters() {
        let mut s = EquinoxSched::default_params(2600.0);
        let mut r = req(1, 0, 100, 50, 0.0); // predicted 50
        r.true_output_tokens = 200;
        s.enqueue(r, 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        let (before, _) = s.raw(ClientId(0));
        s.on_complete(
            &r,
            &Actuals { latency: 1.0, gpu_util: 0.8, tps: 1000.0, output_tokens: 200 },
            1.0,
        );
        let (after, _) = s.raw(ClientId(0));
        assert!(after > before, "underprediction must raise the counter on completion");
    }

    #[test]
    fn declares_prediction_use() {
        assert!(EquinoxSched::default_params(1000.0).uses_predictions());
    }

    /// Regression (indexed-core PR): admit → requeue → re-admit must leave
    /// the counters exactly where a single admission would — the seed's
    /// zero-service correction left a residual input charge that
    /// double-billed preempted requests on re-admission.
    #[test]
    fn requeue_refund_is_exact() {
        let mut s = EquinoxSched::default_params(2600.0);
        let mut oracle = EquinoxSched::default_params(2600.0);
        // Prior traffic so counters start non-zero on both sides.
        for sched in [&mut s, &mut oracle] {
            sched.enqueue(req(0, 0, 80, 120, 0.0), 0.0);
            sched.pick(1.0, &mut |_| true).unwrap();
        }
        s.enqueue(req(1, 0, 100, 400, 2.0), 2.0);
        oracle.enqueue(req(1, 0, 100, 400, 2.0), 2.0);
        // s: admit, preempt, re-admit at the same instant.
        let r = s.pick(5.0, &mut |_| true).unwrap();
        s.requeue(r);
        let r = s.pick(5.0, &mut |_| true).unwrap();
        assert_eq!(r.id, RequestId(1));
        // oracle: a single admission at that instant.
        oracle.pick(5.0, &mut |_| true).unwrap();
        let (ufc, rfc) = s.raw(ClientId(0));
        let (ufc_o, rfc_o) = oracle.raw(ClientId(0));
        assert!((ufc - ufc_o).abs() < 1e-9, "ufc {ufc} vs single-admission {ufc_o}");
        assert!((rfc - rfc_o).abs() < 1e-12, "rfc {rfc} vs single-admission {rfc_o}");
    }

    /// The guard's hard invariant in miniature: with perfect predictions
    /// the guarded scheduler's counters are BIT-identical to the plain
    /// one, under both guard policies.
    #[test]
    fn oracle_fed_guard_is_bitwise_noop() {
        for policy in [GuardPolicy::Debias, GuardPolicy::Ladder] {
            let mut plain = EquinoxSched::default_params(2600.0);
            let mut guarded = EquinoxSched::with_guard(HfParams::default(), 2600.0, policy);
            for i in 0..300u64 {
                let client = (i % 6) as u32;
                let out = 1 + ((i * 53) % 900) as u32;
                let now = i as f64 * 0.1;
                for s in [&mut plain, &mut guarded] {
                    // predicted == actual: the oracle information regime.
                    s.enqueue(req(i, client, 60, out, now), now);
                    let picked = s.pick(now, &mut |_| true).unwrap();
                    s.on_complete(
                        &picked,
                        &Actuals { latency: 1.0, gpu_util: 0.8, tps: 1000.0, output_tokens: out },
                        now + 1.0,
                    );
                }
            }
            assert_eq!(guarded.guard_mode().unwrap().code(), policy_start_code(policy));
            assert_eq!(guarded.guard_health().unwrap().transitions, 0);
            for c in 0..6u32 {
                let a = plain.raw(ClientId(c));
                let b = guarded.raw(ClientId(c));
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "{policy:?} ufc, client {c}");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "{policy:?} rfc, client {c}");
            }
        }
    }

    fn policy_start_code(policy: GuardPolicy) -> u32 {
        match policy {
            GuardPolicy::Debias => 1,
            GuardPolicy::Ladder => 0,
        }
    }

    /// Under systematic 2× over-prediction the debiasing guard converges
    /// to charging ≈ the true cost, where the raw scheduler keeps
    /// over-billing — the mechanism behind the harness's
    /// debiased-beats-raw acceptance bar.
    #[test]
    fn debias_guard_corrects_systematic_overprediction() {
        let mut s = EquinoxSched::with_guard(HfParams::default(), 2600.0, GuardPolicy::Debias);
        let mut last_receipt_charge = f64::NAN;
        for i in 0..120u64 {
            // predicted 200, actual 100 — 2× bias in regime 1.
            let mut r = req(i, 0, 50, 200, i as f64);
            r.true_output_tokens = 100;
            s.enqueue(r, i as f64);
            let picked = s.pick(i as f64, &mut |_| true).unwrap();
            last_receipt_charge = s.in_flight[&picked.id].charged_tokens;
            s.on_complete(
                &picked,
                &Actuals { latency: 1.0, gpu_util: 0.8, tps: 1000.0, output_tokens: 100 },
                i as f64 + 0.5,
            );
        }
        assert!(
            (last_receipt_charge - 100.0).abs() < 15.0,
            "debiased charge {last_receipt_charge}, want ≈100 (true cost)"
        );
        let h = s.guard_health().unwrap();
        assert!(h.signed_err_ewma > 0.3, "tracked bias {h:?}");
    }

    #[test]
    fn drain_queued_is_charge_free_and_resets_active_index() {
        let mut s = EquinoxSched::default_params(2600.0);
        s.enqueue(req(1, 0, 100, 100, 0.0), 0.0);
        s.enqueue(req(2, 1, 50, 50, 0.0), 0.0);
        let before0 = s.raw(ClientId(0));
        let before1 = s.raw(ClientId(1));
        let out = s.drain_queued();
        assert_eq!(out.len(), 2);
        assert!(s.is_empty());
        assert_eq!(s.raw(ClientId(0)), before0, "drain must not charge counters");
        assert_eq!(s.raw(ClientId(1)), before1);
        assert_eq!(s.outstanding_receipts(), Some(0));
        // Index emptied with the queues: later traffic still picks.
        s.enqueue(req(3, 1, 10, 10, 1.0), 1.0);
        assert_eq!(s.pick(1.0, &mut |_| true).unwrap().client, ClientId(1));
    }

    /// A drained client must leave the active index; a fresh enqueue
    /// re-activates (and lifts) it.
    #[test]
    fn drain_and_reactivate_keeps_index_consistent() {
        let mut s = EquinoxSched::default_params(2600.0);
        s.enqueue(req(1, 0, 100, 100, 0.0), 0.0);
        s.enqueue(req(2, 1, 100, 100, 0.0), 0.0);
        // Drain client 0 fully.
        let a = s.pick(0.0, &mut |r| r.client == ClientId(0)).unwrap();
        assert_eq!(a.client, ClientId(0));
        assert_eq!(s.queued_clients(), vec![ClientId(1)]);
        // Client 0 returns: lifted against client 1 (still backlogged).
        s.enqueue(req(3, 0, 10, 10, 1.0), 1.0);
        assert_eq!(s.queued_clients(), vec![ClientId(0), ClientId(1)]);
        let (ufc0, _) = s.raw(ClientId(0));
        let (ufc1, _) = s.raw(ClientId(1));
        assert!(ufc0 >= ufc1, "reactivated client must not undercut the active min");
    }
}
