//! Online calibration guard: misprediction detection, debiasing, and the
//! graceful-degradation ladder.
//!
//! Equinox's proactive fairness rides on predictions (§6): every HF
//! admission charge prices a request by its *predicted* output tokens.
//! `counters::correct_on_complete` already admits predictions are wrong
//! post-hoc; this module closes the loop *online*. A
//! [`CalibrationTracker`] sits inside a predictive scheduler and watches
//! the existing `on_complete` actuals path: per-regime EWMAs of the
//! signed and absolute log-error (regimes keyed by the paper's 3-expert
//! boundaries over the *predicted* length — the only quantity known at
//! charge time) yield
//!
//! 1. a **debias factor** `exp(−signed_ewma)` that rescales
//!    predicted-token admission charges, cancelling systematic bias, and
//! 2. a hysteresis **degradation ladder**
//!    `Predictive → Debiased → ActualOnly`: when tracked error crosses
//!    the engage thresholds the scheduler steps down to debiased and
//!    ultimately to actual-progress charging (admission prices the input
//!    only; the completion correction settles the full actuals — exactly
//!    VTC's information-free behaviour), stepping back up one rung at a
//!    time once calibration returns.
//!
//! Hard invariant (machine-checked by `tests/properties.rs` and
//! `harness/mispredict.rs`): under `Oracle` predictions the whole layer
//! is a **bitwise no-op**. Zero log-error keeps every EWMA at exactly
//! `0.0`, the debias factor at exactly `1.0`, and the ladder on
//! `Predictive` — so the charged tokens are bit-identical to the
//! unguarded path and fingerprints/trace digests are unchanged.
//!
//! Per-client calibration cells live in dense [`ClientSlab`] storage
//! (same `ClientMapFamily` discipline as every hot per-client structure
//! since the §Scale PR), so the observe path is allocation-free in
//! steady state.
//!
//! [`ClientSlab`]: crate::core::ClientSlab

use crate::core::{ClientId, ClientMap, ClientMapFamily, SlabFamily};
use crate::predictor::MopeConfig;

/// EWMA factor for the calibration error signals. Matches the RFC EMA
/// tempo: ~10 completions to react, ~20 to recover.
const CAL_EMA: f64 = 0.1;
/// Minimum observations in a regime before its cell influences the
/// debias factor or the ladder (a single early miss must not flap the
/// mode).
const MIN_SAMPLES: u64 = 5;
/// Minimum completions between ladder transitions (hysteresis dwell).
const MIN_DWELL: u64 = 8;
/// A regime cell with no observation in this many completions is
/// *stale* and excluded from the ladder signal: a regime nobody routes
/// through any more (say, one polluted only during a blackout window)
/// must not hold the scheduler in fallback forever. Its EWMA state is
/// kept — the cell re-enters the signal on its next observation.
const STALE_WINDOW: u64 = 64;
/// Debias factor clamp: never scale a charge by more than 4× either way.
const DEBIAS_CLAMP: f64 = 4.0;

/// Engage threshold: |signed log-error| above this means systematic
/// bias — step down to `Debiased`. (2× bias ⇒ signed ≈ ln 2 ≈ 0.69.)
const SIGNED_ENGAGE: f64 = 0.30;
/// Engage threshold on absolute log-error for `Debiased`.
const ABS_ENGAGE: f64 = 0.60;
/// Engage threshold on absolute log-error for `ActualOnly`: error this
/// large (≈2.5× typical miss) means predictions carry no usable signal.
const ABS_BLACKOUT: f64 = 0.90;
/// Release threshold for `ActualOnly → Debiased`.
const ABS_RELEASE_BLACKOUT: f64 = 0.70;
/// Release thresholds for `Debiased → Predictive` (clear margin below
/// the engage levels — classic hysteresis band).
const ABS_RELEASE: f64 = 0.45;
const SIGNED_RELEASE: f64 = 0.15;

/// The degradation ladder rung a guarded scheduler is charging on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum GuardMode {
    /// Full trust: charge predicted tokens at admission (the unguarded
    /// Equinox/VTC+pred behaviour, bit-for-bit).
    #[default]
    Predictive,
    /// Charge `predicted × debias_factor`: systematic bias cancelled,
    /// prediction signal retained.
    Debiased,
    /// Predictions carry no signal: admission charges the input only and
    /// the completion correction settles the full actuals — VTC-style
    /// actual-progress charging.
    ActualOnly,
}

impl GuardMode {
    /// Stable wire code (trace events, Prometheus gauge).
    pub fn code(&self) -> u32 {
        match self {
            GuardMode::Predictive => 0,
            GuardMode::Debiased => 1,
            GuardMode::ActualOnly => 2,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            GuardMode::Predictive => "predictive",
            GuardMode::Debiased => "debiased",
            GuardMode::ActualOnly => "actual_only",
        }
    }

    pub fn from_code(code: u32) -> GuardMode {
        match code {
            1 => GuardMode::Debiased,
            2 => GuardMode::ActualOnly,
            _ => GuardMode::Predictive,
        }
    }
}

/// What the guard is allowed to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GuardPolicy {
    /// Always-on debiasing, no ladder: the mode is pinned to
    /// [`GuardMode::Debiased`] and only the factor adapts (it starts —
    /// and under perfect predictions stays — at exactly 1.0).
    Debias,
    /// The full hysteresis ladder.
    Ladder,
}

impl GuardPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            GuardPolicy::Debias => "debias",
            GuardPolicy::Ladder => "ladder",
        }
    }
}

/// One calibration cell: EWMAs of signed and absolute log-error.
#[derive(Debug, Clone, Copy, Default)]
struct CalCell {
    n: u64,
    signed: f64,
    abs: f64,
    /// Global observation index of the last update (staleness check).
    last: u64,
}

impl CalCell {
    fn update(&mut self, log_err: f64, now: u64) {
        self.n += 1;
        self.last = now;
        self.signed += CAL_EMA * (log_err - self.signed);
        self.abs += CAL_EMA * (log_err.abs() - self.abs);
    }

    fn seasoned(&self) -> bool {
        self.n >= MIN_SAMPLES
    }

    fn fresh(&self, now: u64) -> bool {
        now.saturating_sub(self.last) <= STALE_WINDOW
    }
}

/// Exported guard state (Prometheus gauges, harness verdicts).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GuardHealth {
    pub mode: GuardMode,
    /// Worst per-regime absolute log-error EWMA (seasoned cells only).
    pub abs_err_ewma: f64,
    /// Signed log-error EWMA of the worst-|signed| seasoned regime.
    pub signed_err_ewma: f64,
    /// Debias factor of that regime (1.0 when nothing is seasoned).
    pub debias_factor: f64,
    /// Ladder transitions so far.
    pub transitions: u64,
    /// Completions observed.
    pub observed: u64,
}

/// Online calibration tracker + degradation ladder. Storage-family
/// generic like its host schedulers: per-client cells live in the same
/// dense slab (or `BTreeMap` reference) family.
#[derive(Debug)]
pub struct CalibrationTracker<F: ClientMapFamily = SlabFamily> {
    policy: GuardPolicy,
    /// Regime boundaries over *predicted* tokens — the paper's 3-expert
    /// split, the only classification available at charge time.
    boundaries: Vec<u32>,
    /// Global per-regime calibration cells (drive the factor + ladder).
    regimes: Vec<CalCell>,
    /// Per-client cells (slab storage): introspection and per-tenant
    /// calibration audit; not on the charge path.
    clients: F::Map<CalCell>,
    mode: GuardMode,
    /// Completions since the last transition (hysteresis dwell).
    dwell: u64,
    transitions: u64,
    observed: u64,
}

impl CalibrationTracker {
    /// Production (slab-backed) tracker.
    pub fn new(policy: GuardPolicy) -> Self {
        Self::for_family(policy)
    }
}

impl<F: ClientMapFamily> CalibrationTracker<F> {
    pub fn for_family(policy: GuardPolicy) -> Self {
        let boundaries = MopeConfig::default().boundaries();
        let n_regimes = boundaries.len() + 1;
        CalibrationTracker {
            policy,
            boundaries,
            regimes: vec![CalCell::default(); n_regimes],
            clients: Default::default(),
            mode: match policy {
                GuardPolicy::Debias => GuardMode::Debiased,
                GuardPolicy::Ladder => GuardMode::Predictive,
            },
            dwell: 0,
            transitions: 0,
            observed: 0,
        }
    }

    pub fn policy(&self) -> GuardPolicy {
        self.policy
    }

    pub fn mode(&self) -> GuardMode {
        self.mode
    }

    fn regime_of(&self, tokens: u32) -> usize {
        self.boundaries.iter().position(|&b| tokens < b).unwrap_or(self.boundaries.len())
    }

    /// Debias factor for a prediction: `exp(−signed_ewma)` of its
    /// regime, clamped. Exactly `1.0` until the regime is seasoned —
    /// and forever, under zero log-error.
    pub fn debias_factor(&self, predicted: u32) -> f64 {
        let cell = &self.regimes[self.regime_of(predicted)];
        if !cell.seasoned() || cell.signed == 0.0 {
            return 1.0;
        }
        (-cell.signed).exp().clamp(1.0 / DEBIAS_CLAMP, DEBIAS_CLAMP)
    }

    /// Output tokens to charge at admission for a prediction, per the
    /// current ladder rung. The `Predictive` arm returns the exact
    /// unguarded value (`predicted as f64`) — the bitwise no-op path.
    pub fn charged_tokens(&self, predicted: u32) -> f64 {
        match self.mode {
            GuardMode::Predictive => predicted as f64,
            GuardMode::Debiased => predicted as f64 * self.debias_factor(predicted),
            GuardMode::ActualOnly => 0.0,
        }
    }

    /// Feed one completion (the existing `on_complete` actuals path).
    /// Updates the regime + client cells and steps the ladder at most
    /// one rung, respecting the hysteresis dwell.
    pub fn observe(&mut self, client: ClientId, predicted: u32, actual: u32) {
        let log_err = (predicted.max(1) as f64 / actual.max(1) as f64).ln();
        let regime = self.regime_of(predicted);
        self.observed += 1;
        let now = self.observed;
        self.regimes[regime].update(log_err, now);
        self.clients.or_default(client).update(log_err, now);
        self.dwell += 1;
        if self.policy == GuardPolicy::Ladder {
            self.step_ladder();
        }
    }

    /// Worst seasoned *fresh* (abs, |signed|) across regimes; zeros when
    /// nothing qualifies. Stale cells (no observation within
    /// [`STALE_WINDOW`] completions) are excluded: they carry no current
    /// signal, and keeping them in would let a dead regime pin the
    /// ladder in fallback.
    fn worst(&self) -> (f64, f64) {
        let mut abs = 0.0f64;
        let mut signed = 0.0f64;
        for cell in &self.regimes {
            if cell.seasoned() && cell.fresh(self.observed) {
                abs = abs.max(cell.abs);
                signed = signed.max(cell.signed.abs());
            }
        }
        (abs, signed)
    }

    fn step_ladder(&mut self) {
        if self.dwell < MIN_DWELL {
            return;
        }
        let (abs, signed) = self.worst();
        let next = match self.mode {
            GuardMode::Predictive if signed > SIGNED_ENGAGE || abs > ABS_ENGAGE => {
                Some(GuardMode::Debiased)
            }
            GuardMode::Debiased if abs > ABS_BLACKOUT => Some(GuardMode::ActualOnly),
            GuardMode::Debiased if abs < ABS_RELEASE && signed < SIGNED_RELEASE => {
                Some(GuardMode::Predictive)
            }
            GuardMode::ActualOnly if abs < ABS_RELEASE_BLACKOUT => Some(GuardMode::Debiased),
            _ => None,
        };
        if let Some(next) = next {
            self.mode = next;
            self.dwell = 0;
            self.transitions += 1;
        }
    }

    /// Per-client calibration cell: `(observations, signed_ewma,
    /// abs_ewma)`. `None` for clients never observed.
    pub fn client_cal(&self, client: ClientId) -> Option<(u64, f64, f64)> {
        self.clients.get(client).map(|c| (c.n, c.signed, c.abs))
    }

    pub fn health(&self) -> GuardHealth {
        let (abs, _) = self.worst();
        let worst_signed_cell = self
            .regimes
            .iter()
            .filter(|c| c.seasoned() && c.fresh(self.observed))
            .max_by(|a, b| a.signed.abs().total_cmp(&b.signed.abs()));
        let signed = worst_signed_cell.map_or(0.0, |c| c.signed);
        let factor = worst_signed_cell.map_or(1.0, |c| {
            if c.signed == 0.0 {
                1.0
            } else {
                (-c.signed).exp().clamp(1.0 / DEBIAS_CLAMP, DEBIAS_CLAMP)
            }
        });
        GuardHealth {
            mode: self.mode,
            abs_err_ewma: abs,
            signed_err_ewma: signed,
            debias_factor: factor,
            transitions: self.transitions,
            observed: self.observed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(t: &mut CalibrationTracker, n: usize, pred: u32, actual: u32) {
        for i in 0..n {
            t.observe(ClientId(i as u32 % 4), pred, actual);
        }
    }

    #[test]
    fn perfect_predictions_keep_everything_at_identity() {
        for policy in [GuardPolicy::Debias, GuardPolicy::Ladder] {
            let mut t = CalibrationTracker::new(policy);
            let start = t.mode();
            for i in 0..500u32 {
                let tokens = 1 + (i * 97) % 1000;
                t.observe(ClientId(i % 8), tokens, tokens);
                assert_eq!(t.charged_tokens(tokens), tokens as f64, "bitwise identity");
                assert_eq!(t.debias_factor(tokens), 1.0);
            }
            assert_eq!(t.mode(), start, "no transitions under zero error");
            let h = t.health();
            assert_eq!(h.abs_err_ewma, 0.0);
            assert_eq!(h.debias_factor, 1.0);
            assert_eq!(h.transitions, 0);
        }
    }

    #[test]
    fn debias_factor_cancels_systematic_bias() {
        let mut t = CalibrationTracker::new(GuardPolicy::Debias);
        // 2× over-prediction, all regime 1 (pred 100).
        feed(&mut t, 200, 100, 50);
        let f = t.debias_factor(100);
        assert!((f - 0.5).abs() < 0.05, "factor {f}, want ≈0.5");
        let charged = t.charged_tokens(100);
        assert!((charged - 50.0).abs() < 5.0, "charged {charged}, want ≈50");
        // Other regimes untouched → factor 1.
        assert_eq!(t.debias_factor(20), 1.0);
        assert_eq!(t.mode(), GuardMode::Debiased, "debias policy pins the mode");
    }

    #[test]
    fn ladder_engages_on_bias_and_recovers() {
        let mut t = CalibrationTracker::new(GuardPolicy::Ladder);
        assert_eq!(t.mode(), GuardMode::Predictive);
        feed(&mut t, 60, 200, 100); // 2× bias, regime 1
        assert_eq!(t.mode(), GuardMode::Debiased, "bias must engage the ladder");
        // Calibration returns: clean completions decay the EWMAs.
        feed(&mut t, 120, 100, 100);
        assert_eq!(t.mode(), GuardMode::Predictive, "must recover after calibration returns");
        assert!(t.health().transitions >= 2);
    }

    #[test]
    fn ladder_reaches_actual_only_under_garbage_and_charges_zero() {
        let mut t = CalibrationTracker::new(GuardPolicy::Ladder);
        // Blackout-grade garbage: predictions off by ~16×.
        feed(&mut t, 100, 32, 500);
        assert_eq!(t.mode(), GuardMode::ActualOnly);
        assert_eq!(t.charged_tokens(400), 0.0, "actual-only charges no predicted tokens");
        // Recovery is rung by rung: garbage clears → Debiased → Predictive.
        // Clean traffic must flow through the polluted regime (pred < 53
        // = regime 0, where the garbage predictions landed) to decay it.
        feed(&mut t, 400, 40, 40);
        assert_eq!(t.mode(), GuardMode::Predictive);
        assert!(t.health().transitions >= 4);
    }

    #[test]
    fn stale_regime_does_not_pin_the_ladder() {
        let mut t = CalibrationTracker::new(GuardPolicy::Ladder);
        // Garbage confined to regime 0 drives the ladder down…
        feed(&mut t, 100, 32, 500);
        assert_eq!(t.mode(), GuardMode::ActualOnly);
        // …but afterwards regime 0 never sees traffic again. Clean
        // completions through regime 1 only: once regime 0 goes stale
        // (STALE_WINDOW completions without an observation) it drops out
        // of the ladder signal and the mode recovers anyway.
        feed(&mut t, 2 * STALE_WINDOW as usize, 100, 100);
        assert_eq!(t.mode(), GuardMode::Predictive, "stale regime pinned the ladder");
    }

    #[test]
    fn hysteresis_dwell_limits_transition_rate() {
        let mut t = CalibrationTracker::new(GuardPolicy::Ladder);
        // Alternate extreme over/under-shoot every completion; without a
        // dwell the ladder could flap each observation.
        for i in 0..200u32 {
            if i % 2 == 0 {
                t.observe(ClientId(0), 500, 50);
            } else {
                t.observe(ClientId(0), 50, 500);
            }
        }
        let h = t.health();
        assert!(
            h.transitions <= 200 / MIN_DWELL,
            "transitions {} exceed the dwell bound",
            h.transitions
        );
    }

    #[test]
    fn per_client_cells_track_separately() {
        let mut t = CalibrationTracker::new(GuardPolicy::Debias);
        for _ in 0..20 {
            t.observe(ClientId(1), 100, 50); // biased tenant
            t.observe(ClientId(2), 80, 80); // clean tenant
        }
        let (n1, s1, a1) = t.client_cal(ClientId(1)).unwrap();
        let (n2, s2, a2) = t.client_cal(ClientId(2)).unwrap();
        assert_eq!((n1, n2), (20, 20));
        assert!(s1 > 0.3 && a1 > 0.3, "biased tenant cell: signed={s1} abs={a1}");
        assert_eq!((s2, a2), (0.0, 0.0), "clean tenant cell stays at zero");
        assert!(t.client_cal(ClientId(9)).is_none());
    }

    #[test]
    fn debias_factor_is_clamped() {
        let mut t = CalibrationTracker::new(GuardPolicy::Debias);
        // Absurd 1000× over-prediction — factor must stop at the clamp.
        feed(&mut t, 300, 1000, 1);
        assert_eq!(t.debias_factor(1000), 1.0 / DEBIAS_CLAMP);
    }

    #[test]
    fn mode_codes_roundtrip() {
        for m in [GuardMode::Predictive, GuardMode::Debiased, GuardMode::ActualOnly] {
            assert_eq!(GuardMode::from_code(m.code()), m);
        }
        assert_eq!(GuardMode::from_code(77), GuardMode::Predictive);
    }
}
