//! The dual-counter framework (§3): User-Fairness Counter, Resource-
//! Fairness Counter, and the composite Holistic Fairness score.
//!
//! Selection (`argmin_hf`) and work-conservation lifts are served from
//! incremental [`ScoreIndex`]es over the *active* set (clients with
//! queued work), so the Algorithm 1 max-min pick is O(log C) instead of
//! the seed's O(C) scan — see EXPERIMENTS.md §Perf. The owning policy
//! drives membership via [`HolisticCounters::set_active`] /
//! [`HolisticCounters::set_inactive`] on queue empty/non-empty
//! transitions; every counter mutator re-keys the touched client, so the
//! indexes never go stale.

use super::index::ScoreIndex;
use crate::core::{ClientId, ClientMap, ClientMapFamily, Request, SlabFamily};

/// Tunable weights of the holistic-fairness equation (§3.3, §7.6).
#[derive(Debug, Clone, Copy)]
pub struct HfParams {
    /// UFC weight α (paper default 0.7; α > β to favour user experience).
    pub alpha: f64,
    /// RFC weight β = 1 - α (paper default 0.3).
    pub beta: f64,
    /// Latency-compensation factor δ on *waiting time* (paper: 0.1).
    pub delta: f64,
    /// Compensation factor on the *predicted inference duration*. The
    /// paper applies one δ to (wait + predict); its testbed's mean
    /// inference duration is ~2.4 s (Fig 7d) so the predict term is a
    /// small correction there. Our per-request GPU durations reach tens
    /// of seconds for long outputs, where δ·predict would hand heavy
    /// requests a persistent ~3× price discount and starve light tenants
    /// — so the predict term gets its own, smaller factor (deviation,
    /// see DESIGN.md).
    pub delta_predict: f64,
    /// Cap on the compensation denominator `1 + δ·(wait + predict)`.
    /// The paper states the formula uncapped, but with δ=0.1 and the
    /// multi-minute waits of saturated runs an uncapped denominator lets
    /// deeply-backlogged clients consume service almost for free, which
    /// would break the bounded-discrepancy behaviour Table 1 reports.
    /// Capping keeps the compensation a bounded priority boost
    /// (documented as a deviation in DESIGN.md).
    pub comp_cap: f64,
}

impl Default for HfParams {
    fn default() -> Self {
        HfParams { alpha: 0.7, beta: 0.3, delta: 0.1, delta_predict: 0.02, comp_cap: 2.0 }
    }
}

impl HfParams {
    pub fn with_alpha(alpha: f64) -> Self {
        HfParams { alpha, beta: 1.0 - alpha, ..Default::default() }
    }

    /// Compensation denominator, capped.
    pub fn comp(&self, wait: f64, predict: f64) -> f64 {
        (1.0 + self.delta * wait + self.delta_predict * predict).min(self.comp_cap)
    }
}

/// EMA factor of the RFC recent-efficiency signal.
const RFC_EMA: f64 = 0.1;

/// Fixed scale converting the RFC efficiency signal (≈0..1.5) into
/// UFC weighted-token units — roughly one typical request's weight.
const RFC_SCALE: f64 = 1000.0;

/// The holistic-fairness composition `α·UFC + β·K·RFC` over raw counter
/// values — shared by [`HolisticCounters::hf`] and the cluster's global
/// dual-counter plane (`crate::cluster::global`), which merges raw
/// per-replica counters and must score them identically.
pub fn hf_score(params: &HfParams, ufc: f64, rfc: f64) -> f64 {
    params.alpha * ufc + params.beta * RFC_SCALE * rfc
}

/// Per-client counter state.
#[derive(Debug, Clone, Copy, Default)]
struct ClientCounters {
    ufc: f64,
    rfc: f64,
    /// Priority weight ω_f.
    weight: f64,
}

/// Exact record of one admission-time counter update, so a preemption
/// refund can reverse it precisely (no residual double-billing when the
/// request is re-admitted).
#[derive(Debug, Clone, Copy)]
pub struct AdmitReceipt {
    /// UFC increment applied at admission.
    pub ufc_delta: f64,
    /// The efficiency sample fed into the RFC EMA at admission.
    pub rfc_eff: f64,
    /// Output tokens the admission actually priced: the raw prediction,
    /// a guard-debiased value, or 0 under actual-only charging. The
    /// completion correction replaces exactly this amount, so charges
    /// stay exact across mid-flight guard-mode transitions.
    pub charged_tokens: f64,
}

/// The dual-counter store for all clients, with the max-min selection
/// primitive (min-HF client first) answered from incremental indexes.
///
/// Storage-family generic: the production path (`SlabFamily`, the
/// default) keeps per-client counters in a dense [`ClientSlab`] so each
/// admission/credit is an array index; `BTreeFamily` instantiates the
/// SAME code over `BTreeMap` as the retained reference the scale bench
/// and zero-drift tests compare against.
///
/// [`ClientSlab`]: crate::core::ClientSlab
#[derive(Debug, Default)]
pub struct HolisticCounters<F: ClientMapFamily = SlabFamily> {
    params: HfParams,
    clients: F::Map<ClientCounters>,
    /// Active (queued-work) clients keyed by HF score — Algorithm 1's
    /// argmin is this index's `first()`.
    active_hf: ScoreIndex<F>,
    /// Active clients keyed by raw UFC / RFC, for O(log C) lifts.
    active_ufc: ScoreIndex<F>,
    active_rfc: ScoreIndex<F>,
}

impl<F: ClientMapFamily> HolisticCounters<F> {
    pub fn new(params: HfParams) -> Self {
        HolisticCounters { params, ..Default::default() }
    }

    pub fn params(&self) -> HfParams {
        self.params
    }

    /// Register a client (idempotent), starting at zero counters. The
    /// weight given here is a default only: admission-time updates adopt
    /// the per-request ω_f (`Request::weight`, stamped by the workload
    /// generator), which is the end-to-end delivery path for tier
    /// weights.
    pub fn touch(&mut self, client: ClientId, weight: f64) {
        self.clients.or_insert_with(client, || ClientCounters { ufc: 0.0, rfc: 0.0, weight });
    }

    /// Visit every known client's raw (UFC, RFC) — the export path the
    /// cluster's global dual-counter plane pulls on its sync period
    /// (`Scheduler::export_counters`). Ascending id order on every
    /// storage family.
    pub fn for_each_counter(&self, f: &mut dyn FnMut(ClientId, f64, f64)) {
        self.clients.for_each(&mut |c, cc| f(c, cc.ufc, cc.rfc));
    }

    /// Re-key an active client after a counter mutation. No-op for
    /// inactive clients (e.g. the engine's scheduler-independent auditor,
    /// which never activates anyone and pays nothing for the indexes).
    fn refresh(&mut self, client: ClientId) {
        if self.active_hf.contains(client) {
            self.set_active(client);
        }
    }

    /// Mark a client active (it now has queued work). O(log C).
    pub fn set_active(&mut self, client: ClientId) {
        let hf = self.hf(client);
        let (ufc, rfc) = self.raw(client);
        self.active_hf.insert(client, hf);
        self.active_ufc.insert(client, ufc);
        self.active_rfc.insert(client, rfc);
    }

    /// Mark a client inactive (its queue drained). O(log C).
    pub fn set_inactive(&mut self, client: ClientId) {
        self.active_hf.remove(client);
        self.active_ufc.remove(client);
        self.active_rfc.remove(client);
    }

    pub fn is_active(&self, client: ClientId) -> bool {
        self.active_hf.contains(client)
    }

    /// The min-HF active client — O(log C) replacement for scanning
    /// `argmin_hf` over a collected candidate Vec.
    pub fn argmin_hf_active(&self) -> Option<ClientId> {
        self.active_hf.min_client()
    }

    /// Active clients in ascending (HF, id) order — the work-conserving
    /// pick walks this and takes the first feasible head, touching only
    /// the front in the common case.
    pub fn active_by_hf(&self) -> impl Iterator<Item = (f64, ClientId)> + '_ {
        self.active_hf.iter_by_score()
    }

    /// VTC-style *lift* on (re)activation: raise the client's counters to
    /// the minimum among the currently-active set, so a tenant cannot bank
    /// idle time into future monopolisation. `active` is the set of
    /// clients with queued work, excluding the lifted client.
    ///
    /// This is the O(C) linear form retained for the reference scheduler
    /// and tests; the indexed hot path is [`lift_to_active_min_indexed`].
    ///
    /// [`lift_to_active_min_indexed`]: HolisticCounters::lift_to_active_min_indexed
    pub fn lift_to_active_min(&mut self, client: ClientId, active: &[ClientId]) {
        let min_ufc = active
            .iter()
            .filter(|&&c| c != client)
            .filter_map(|&c| self.clients.get(c))
            .map(|c| c.ufc)
            .fold(f64::INFINITY, f64::min);
        let min_rfc = active
            .iter()
            .filter(|&&c| c != client)
            .filter_map(|&c| self.clients.get(c))
            .map(|c| c.rfc)
            .fold(f64::INFINITY, f64::min);
        if let Some(c) = self.clients.get_mut(client) {
            if min_ufc.is_finite() {
                c.ufc = c.ufc.max(min_ufc);
            }
            if min_rfc.is_finite() {
                c.rfc = c.rfc.max(min_rfc);
            }
        }
        self.refresh(client);
    }

    /// O(log C) lift against the incrementally-tracked active-set minima.
    /// The client must not be in the active set yet: activate *after*
    /// lifting, so the minima naturally exclude it.
    pub fn lift_to_active_min_indexed(&mut self, client: ClientId) {
        debug_assert!(!self.active_hf.contains(client), "lift before set_active");
        let min_ufc = self.active_ufc.min_score();
        let min_rfc = self.active_rfc.min_score();
        if let Some(c) = self.clients.get_mut(client) {
            if let Some(m) = min_ufc {
                c.ufc = c.ufc.max(m);
            }
            if let Some(m) = min_rfc {
                c.rfc = c.rfc.max(m);
            }
        }
    }

    /// UFC admission update (§3.1):
    /// `UFC += (in + 4·out_pred) / (ω_f · (1 + δ·(wait + predict_time)))`.
    /// Returns the applied increment (for exact preemption refunds).
    ///
    /// ω_f enters as an *entitlement* divisor (weighted fair queuing /
    /// weighted-VTC convention): an ω=2 client's counter grows at half
    /// rate per token, so min-HF equalisation delivers it ~2× the service
    /// of an ω=1 peer under contention. (Deviation noted: the paper
    /// states ω_f as a multiplier, but runs every experiment at ω≡1 where
    /// the direction is unobservable; a multiplier would *throttle* paid
    /// tiers, inverting the tier semantics the weights exist for.)
    pub fn update_ufc_on_admit(&mut self, req: &Request, now: f64) -> f64 {
        let delta = self.apply_ufc_on_admit(req, now);
        self.refresh(req.client);
        delta
    }

    /// Adopt the per-request ω_f (the end-to-end weight delivery path)
    /// and return the effective client weight.
    fn adopt_weight(c: &mut ClientCounters, req: &Request) -> f64 {
        if req.weight > 0.0 {
            c.weight = req.weight;
        }
        if c.weight == 0.0 {
            c.weight = 1.0;
        }
        c.weight
    }

    /// Counter mutation without the index re-key — callers that batch
    /// several updates refresh once at the end.
    fn apply_ufc_on_admit(&mut self, req: &Request, now: f64) -> f64 {
        self.apply_ufc_on_admit_tokens(req, now, req.predicted_output_tokens as f64)
    }

    /// UFC admission update pricing an explicit output-token amount —
    /// the calibration guard's entry point (debiased or zeroed charges).
    /// `apply_ufc_on_admit` delegates here with the raw prediction, so
    /// the unguarded path is bit-identical to the pre-guard code.
    fn apply_ufc_on_admit_tokens(&mut self, req: &Request, now: f64, out_tokens: f64) -> f64 {
        let params = self.params;
        let c = self.clients.or_default(req.client);
        let weight = Self::adopt_weight(c, req);
        let wait = (now - req.arrival).max(0.0);
        let tokens = req.input_tokens as f64 + 4.0 * out_tokens;
        let delta = tokens / (weight * params.comp(wait, req.predicted_latency));
        c.ufc += delta;
        delta
    }

    /// RFC update (§3.2): `RFC ← RFC + ω_f · TPS · Util`, with TPS
    /// normalised against the platform's peak so UFC and RFC live on
    /// comparable scales (the paper's "normalized UFC and RFC").
    ///
    /// Deviation (documented in DESIGN.md): the counter is an
    /// exponential moving average of the per-request efficiency rather
    /// than an unbounded cumulative sum. Taken literally, a cumulative
    /// RFC (i) scales with request *count*, starving many-small-request
    /// tenants, and (ii) lets a constant efficiency gap between tenants
    /// push their service apart linearly without bound — both contradict
    /// the bounded-discrepancy behaviour the paper's Table 1 reports for
    /// Equinox. The EMA keeps RFC a bounded recent-efficiency signal:
    /// tenants whose service has been delivered inefficiently score lower
    /// and get nudged forward, while UFC dominates the long-run balance.
    /// Returns the efficiency sample fed into the EMA (for exact refunds).
    pub fn update_rfc_on_admit(&mut self, req: &Request, peak_tps: f64) -> f64 {
        let eff = self.apply_rfc_on_admit(req, peak_tps);
        self.refresh(req.client);
        eff
    }

    /// Counter mutation without the index re-key (see `apply_ufc_on_admit`).
    /// ω_f divides here too, keeping both HF terms on the same
    /// entitlement convention.
    fn apply_rfc_on_admit(&mut self, req: &Request, peak_tps: f64) -> f64 {
        let c = self.clients.or_default(req.client);
        let weight = Self::adopt_weight(c, req);
        let tps_norm = (req.predicted_tps / peak_tps).clamp(0.0, 1.5);
        let eff = tps_norm * req.predicted_gpu_util / weight;
        c.rfc += RFC_EMA * (eff - c.rfc);
        eff
    }

    /// Both admission-time updates (Algorithm 1 line 15), returning the
    /// receipt a preemption refund needs to reverse them (see
    /// [`refund_admission`](HolisticCounters::refund_admission) for the
    /// exactness conditions). Re-keys the indexes once, after both
    /// updates — this sits on the hot pick path.
    pub fn charge_admission(&mut self, req: &Request, now: f64, peak_tps: f64) -> AdmitReceipt {
        self.charge_admission_tokens(req, now, peak_tps, req.predicted_output_tokens as f64)
    }

    /// [`charge_admission`](HolisticCounters::charge_admission) pricing
    /// an explicit output-token amount (the calibration guard's
    /// debiased/zeroed charges). The RFC efficiency sample is unchanged
    /// — it prices *how* service is delivered, not how much; the token
    /// quantity only enters UFC.
    pub fn charge_admission_tokens(
        &mut self,
        req: &Request,
        now: f64,
        peak_tps: f64,
        out_tokens: f64,
    ) -> AdmitReceipt {
        let ufc_delta = self.apply_ufc_on_admit_tokens(req, now, out_tokens);
        let rfc_eff = self.apply_rfc_on_admit(req, peak_tps);
        self.refresh(req.client);
        AdmitReceipt { ufc_delta, rfc_eff, charged_tokens: out_tokens }
    }

    /// Reverse an admission-time update (preemption path). The UFC
    /// increment is subtracted — exact regardless of interleaved updates,
    /// since UFC is additive. The RFC EMA step `rfc' = (1-e)·rfc + e·eff`
    /// is inverted as `rfc = (rfc' - e·eff)/(1-e)`, which is exact when
    /// the refunded admission was the client's most recent RFC update
    /// (the common preempt-and-requeue path); if other same-client RFC
    /// updates landed in between, the inversion is approximate, with
    /// error bounded by the EMA factor times the efficiency-sample gap —
    /// RFC is a bounded recent-efficiency signal and self-corrects on the
    /// next update. Net effect: a refunded-then-re-admitted request lands
    /// on the same counters as a single admission (no preemption
    /// double-billing of the dominant UFC term).
    pub fn refund_admission(&mut self, client: ClientId, receipt: AdmitReceipt) {
        if let Some(c) = self.clients.get_mut(client) {
            c.ufc = (c.ufc - receipt.ufc_delta).max(0.0);
            c.rfc = ((c.rfc - RFC_EMA * receipt.rfc_eff) / (1.0 - RFC_EMA)).max(0.0);
        }
        self.refresh(client);
    }

    /// Post-completion correction with actual metrics (Algorithm 1 line
    /// 20): replace the predicted token/latency contribution by the
    /// observed one. We apply the *difference* so the counter stays
    /// monotone and bounded-discrepancy arguments carry over.
    pub fn correct_on_complete(
        &mut self,
        req: &Request,
        actual_output: u32,
        actual_latency: f64,
        actual_tps: f64,
        actual_util: f64,
        peak_tps: f64,
        now: f64,
    ) {
        self.correct_on_complete_charged(
            req,
            req.predicted_output_tokens as f64,
            actual_output,
            actual_latency,
            actual_tps,
            actual_util,
            peak_tps,
            now,
        )
    }

    /// [`correct_on_complete`](HolisticCounters::correct_on_complete)
    /// against an explicit admission-time token amount (the receipt's
    /// `charged_tokens`): the correction removes exactly what admission
    /// priced and settles the actuals. With `charged_out = 0`
    /// (actual-only charging) the net effect is pure actual-progress
    /// pricing settled at completion — VTC's information-free behaviour.
    #[allow(clippy::too_many_arguments)]
    pub fn correct_on_complete_charged(
        &mut self,
        req: &Request,
        charged_out: f64,
        actual_output: u32,
        actual_latency: f64,
        actual_tps: f64,
        actual_util: f64,
        peak_tps: f64,
        now: f64,
    ) {
        let params = self.params;
        {
            let c = self.clients.or_default(req.client);
            let weight = Self::adopt_weight(c, req);
            let wait = (now - req.arrival).max(0.0);
            let predicted = req.input_tokens as f64 + 4.0 * charged_out;
            let actual = req.input_tokens as f64 + 4.0 * actual_output as f64;
            let denom_pred = params.comp(wait, req.predicted_latency);
            let denom_act = params.comp(wait, actual_latency);
            c.ufc += (actual / denom_act - predicted / denom_pred) / weight;
            let tps_pred = (req.predicted_tps / peak_tps).clamp(0.0, 1.5);
            let tps_act = (actual_tps / peak_tps).clamp(0.0, 1.5);
            // EMA correction: move the efficiency signal by the observed
            // prediction error.
            c.rfc +=
                RFC_EMA * (tps_act * actual_util - tps_pred * req.predicted_gpu_util) / weight;
            // Counters must not go negative after correction.
            c.ufc = c.ufc.max(0.0);
            c.rfc = c.rfc.max(0.0);
        }
        self.refresh(req.client);
    }

    /// Holistic fairness score of one client: `α·UFC + β·RFC·K` (§3.3).
    ///
    /// "Normalized" is implemented as a FIXED rescaling of the bounded
    /// RFC efficiency signal into UFC (weighted-token) units, not as
    /// division by the population mean: mean-normalisation would let a
    /// constant RFC offset between tenants demand an ever-growing UFC
    /// offset (the mean grows with time), i.e. an unbounded service gap —
    /// incompatible with the paper's bounded-discrepancy claim. With a
    /// fixed scale, HF equalisation bounds the UFC gap by
    /// `(β/α)·K·|ΔRFC| ≤ (β/α)·K·1.5` weighted tokens.
    pub fn hf(&self, client: ClientId) -> f64 {
        let c = self.clients.get(client).copied().unwrap_or_default();
        hf_score(&self.params, c.ufc, c.rfc)
    }

    /// Raw counters (for metrics export / Jain over HF).
    pub fn raw(&self, client: ClientId) -> (f64, f64) {
        let c = self.clients.get(client).copied().unwrap_or_default();
        (c.ufc, c.rfc)
    }

    /// All clients' HF scores (for Jain's index over HF, §7.1),
    /// ascending by id on every storage family.
    pub fn all_hf(&self) -> Vec<(ClientId, f64)> {
        let mut out = Vec::with_capacity(self.clients.len());
        let params = self.params;
        self.clients.for_each(&mut |id, cc| out.push((id, hf_score(&params, cc.ufc, cc.rfc))));
        out
    }

    /// The client with the minimum HF among `candidates` — the max-min
    /// selection of Algorithm 1 line 11. Ties break on client id for
    /// determinism. O(C) linear form, retained as the executable spec for
    /// the indexed `argmin_hf_active` (compared via `total_cmp` so the
    /// two agree bit-for-bit, including on signed zeros).
    pub fn argmin_hf(&self, candidates: &[ClientId]) -> Option<ClientId> {
        candidates
            .iter()
            .map(|&c| (c, self.hf(c)))
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
            .map(|(c, _)| c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Request, RequestId};

    fn req(client: u32, input: u32, out_pred: u32, arrival: f64) -> Request {
        let mut r = Request::new(RequestId(0), ClientId(client), input, out_pred, arrival);
        r.predicted_output_tokens = out_pred;
        r.predicted_latency = 1.0;
        r.predicted_tps = 1000.0;
        r.predicted_gpu_util = 0.8;
        r
    }

    #[test]
    fn ufc_formula_matches_paper() {
        let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
        hc.touch(ClientId(0), 1.0);
        // wait = 2s, predict = 1s → denom = 1 + 0.1·2 + 0.02·1 = 1.22
        // (split δ for wait vs predicted duration; see HfParams docs).
        let r = req(0, 100, 400, 0.0);
        hc.update_ufc_on_admit(&r, 2.0);
        let (ufc, _) = hc.raw(ClientId(0));
        let expect = (100.0 + 4.0 * 400.0) / 1.22;
        assert!((ufc - expect).abs() < 1e-9, "ufc={ufc} expect={expect}");
    }

    #[test]
    fn compensation_is_capped() {
        let p = HfParams::default();
        assert!((p.comp(1000.0, 1000.0) - p.comp_cap).abs() < 1e-12);
        assert!(p.comp(0.0, 0.0) == 1.0);
    }

    #[test]
    fn latency_compensation_discounts_backlogged_users() {
        // Same request, longer wait → SMALLER UFC increment → that client
        // keeps priority (the paper's backlog prioritisation).
        let mut a: HolisticCounters = HolisticCounters::new(HfParams::default());
        a.touch(ClientId(0), 1.0);
        let r = req(0, 100, 100, 0.0);
        a.update_ufc_on_admit(&r, 0.0);
        let (short_wait, _) = a.raw(ClientId(0));

        let mut b: HolisticCounters = HolisticCounters::new(HfParams::default());
        b.touch(ClientId(0), 1.0);
        b.update_ufc_on_admit(&r, 50.0);
        let (long_wait, _) = b.raw(ClientId(0));
        assert!(long_wait < short_wait);
    }

    #[test]
    fn min_hf_selects_underserved() {
        let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
        hc.touch(ClientId(0), 1.0);
        hc.touch(ClientId(1), 1.0);
        let r = req(0, 100, 400, 0.0);
        hc.update_ufc_on_admit(&r, 0.0);
        hc.update_rfc_on_admit(&r, 2600.0);
        assert_eq!(hc.argmin_hf(&[ClientId(0), ClientId(1)]), Some(ClientId(1)));
    }

    #[test]
    fn lift_on_reactivation() {
        let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
        hc.touch(ClientId(0), 1.0);
        for _ in 0..10 {
            let r = req(0, 100, 400, 0.0);
            hc.update_ufc_on_admit(&r, 0.0);
        }
        // A client joining while client 0 is active is lifted to client
        // 0's counters, not zero.
        hc.touch(ClientId(1), 1.0);
        hc.lift_to_active_min(ClientId(1), &[ClientId(0)]);
        let (ufc0, _) = hc.raw(ClientId(0));
        let (ufc1, _) = hc.raw(ClientId(1));
        assert!((ufc0 - ufc1).abs() < 1e-9);
    }

    #[test]
    fn no_lift_when_no_active_peers() {
        let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
        hc.touch(ClientId(0), 1.0);
        let r = req(0, 100, 400, 0.0);
        hc.update_ufc_on_admit(&r, 0.0);
        // Client 1 joins while client 0 has NO queued work → no lift.
        hc.touch(ClientId(1), 1.0);
        hc.lift_to_active_min(ClientId(1), &[]);
        let (ufc1, _) = hc.raw(ClientId(1));
        assert_eq!(ufc1, 0.0);
    }

    #[test]
    fn correction_moves_counter_toward_actuals() {
        let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
        hc.touch(ClientId(0), 1.0);
        let r = req(0, 100, 100, 0.0); // predicted 100 out
        hc.update_ufc_on_admit(&r, 0.0);
        let (before, _) = hc.raw(ClientId(0));
        // Actual output was 400 — counter must rise.
        hc.correct_on_complete(&r, 400, 1.0, 1000.0, 0.8, 2600.0, 0.0);
        let (after, _) = hc.raw(ClientId(0));
        assert!(after > before);
        // And match the oracle-admission value.
        let mut oracle: HolisticCounters = HolisticCounters::new(HfParams::default());
        oracle.touch(ClientId(0), 1.0);
        let r2 = req(0, 100, 400, 0.0);
        oracle.update_ufc_on_admit(&r2, 0.0);
        let (oracle_v, _) = oracle.raw(ClientId(0));
        assert!((after - oracle_v).abs() < 1e-6, "after={after} oracle={oracle_v}");
    }

    #[test]
    fn alpha_beta_tradeoff_changes_ranking() {
        // Client 0: high UFC, low RFC. Client 1: low UFC, high RFC.
        let build = |alpha: f64| {
            let mut hc: HolisticCounters = HolisticCounters::new(HfParams::with_alpha(alpha));
            hc.touch(ClientId(0), 1.0);
            hc.touch(ClientId(1), 1.0);
            let mut r0 = req(0, 1000, 1000, 0.0);
            r0.predicted_tps = 100.0;
            r0.predicted_gpu_util = 0.1;
            hc.update_ufc_on_admit(&r0, 0.0);
            hc.update_rfc_on_admit(&r0, 2600.0);
            let mut r1 = req(1, 10, 10, 0.0);
            r1.predicted_tps = 2600.0;
            r1.predicted_gpu_util = 1.0;
            hc.update_ufc_on_admit(&r1, 0.0);
            hc.update_rfc_on_admit(&r1, 2600.0);
            hc
        };
        // α→1: user view dominates → client 1 (fewer weighted tokens) wins.
        let hc = build(0.99);
        assert_eq!(hc.argmin_hf(&[ClientId(0), ClientId(1)]), Some(ClientId(1)));
        // α→0: resource view dominates → client 0 (less efficient service
        // so far) wins.
        let hc = build(0.01);
        assert_eq!(hc.argmin_hf(&[ClientId(0), ClientId(1)]), Some(ClientId(0)));
    }

    #[test]
    fn indexed_argmin_matches_linear() {
        let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
        let ids: Vec<ClientId> = (0..8).map(ClientId).collect();
        for &c in &ids {
            hc.touch(c, 1.0);
            hc.set_active(c);
        }
        for i in 0..40u32 {
            let r = req(i % 8, 50 + 13 * i, 20 + 7 * i, 0.0);
            hc.update_ufc_on_admit(&r, i as f64 * 0.1);
            hc.update_rfc_on_admit(&r, 2600.0);
            assert_eq!(
                hc.argmin_hf_active(),
                hc.argmin_hf(&ids),
                "index diverged from linear scan at step {i}"
            );
        }
        // Deactivation narrows the index, not the counters.
        hc.set_inactive(hc.argmin_hf(&ids).unwrap());
        let rest: Vec<ClientId> = ids.iter().cloned().filter(|&c| hc.is_active(c)).collect();
        assert_eq!(hc.argmin_hf_active(), hc.argmin_hf(&rest));
    }

    #[test]
    fn indexed_lift_matches_linear() {
        let mut a: HolisticCounters = HolisticCounters::new(HfParams::default());
        let mut b: HolisticCounters = HolisticCounters::new(HfParams::default());
        for hc in [&mut a, &mut b] {
            for c in 0..3 {
                hc.touch(ClientId(c), 1.0);
            }
            for i in 0..5u32 {
                let r = req(i % 3, 100 + i, 50, 0.0);
                hc.update_ufc_on_admit(&r, 0.0);
                hc.update_rfc_on_admit(&r, 2600.0);
            }
        }
        let active = vec![ClientId(0), ClientId(1), ClientId(2)];
        a.touch(ClientId(9), 1.0);
        a.lift_to_active_min(ClientId(9), &active);
        for &c in &active {
            b.set_active(c);
        }
        b.touch(ClientId(9), 1.0);
        b.lift_to_active_min_indexed(ClientId(9));
        assert_eq!(a.raw(ClientId(9)), b.raw(ClientId(9)));
    }

    #[test]
    fn refund_reverses_admission_exactly() {
        let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
        hc.touch(ClientId(0), 1.0);
        let r = req(0, 100, 400, 0.0);
        // Pre-existing state so the refund is not the trivial zero case.
        hc.update_ufc_on_admit(&r, 0.0);
        hc.update_rfc_on_admit(&r, 2600.0);
        let before = hc.raw(ClientId(0));
        let receipt = hc.charge_admission(&r, 3.0, 2600.0);
        hc.refund_admission(ClientId(0), receipt);
        let after = hc.raw(ClientId(0));
        assert!((before.0 - after.0).abs() < 1e-9, "ufc {} vs {}", before.0, after.0);
        assert!((before.1 - after.1).abs() < 1e-12, "rfc {} vs {}", before.1, after.1);
    }

    #[test]
    fn weights_grant_proportional_entitlement() {
        // Entitlement semantics: the ω=2 client is charged HALF per token,
        // so under min-HF selection it receives ~2× the service before
        // counters equalise. The weight arrives on the request (the
        // end-to-end delivery path), not via `touch`.
        let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
        hc.touch(ClientId(0), 1.0);
        hc.touch(ClientId(1), 1.0);
        let mut r0 = req(0, 100, 100, 0.0);
        r0.weight = 2.0;
        let r1 = req(1, 100, 100, 0.0);
        hc.update_ufc_on_admit(&r0, 0.0);
        hc.update_ufc_on_admit(&r1, 0.0);
        let (u0, _) = hc.raw(ClientId(0));
        let (u1, _) = hc.raw(ClientId(1));
        assert!((2.0 * u0 - u1).abs() < 1e-9, "u0={u0} u1={u1}");
        // RFC uses the same convention.
        hc.update_rfc_on_admit(&r0, 2600.0);
        hc.update_rfc_on_admit(&r1, 2600.0);
        let (_, f0) = hc.raw(ClientId(0));
        let (_, f1) = hc.raw(ClientId(1));
        assert!(f0 < f1, "rfc0={f0} rfc1={f1}");
    }

    #[test]
    fn counter_export_visits_all_clients() {
        let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
        for c in 0..3u32 {
            hc.touch(ClientId(c), 1.0);
            hc.update_ufc_on_admit(&req(c, 100, 100, 0.0), 0.0);
        }
        let mut seen = Vec::new();
        hc.for_each_counter(&mut |c, ufc, rfc| seen.push((c, ufc, rfc)));
        assert_eq!(seen.len(), 3);
        for (c, ufc, _) in &seen {
            assert_eq!((*ufc, 0.0), (hc.raw(*c).0, 0.0));
            assert!(*ufc > 0.0);
        }
    }
}
