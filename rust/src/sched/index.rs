//! Incremental score index for the scheduling core (EXPERIMENTS.md §Perf).
//!
//! Every counter-based policy (VTC, Equinox) repeatedly answers the same
//! query on its hot path: "which *active* client has the minimum score?"
//! The seed answered it with an O(C) linear scan (plus a fresh
//! `Vec<ClientId>` per call); at 10k+ tenants that dominates the pick
//! path. `ScoreIndex` keeps active clients in a `BTreeSet` ordered by
//! `(score, client)` so the min is an O(log C) `first()`, an arbitrary
//! client's key is replaced in O(log C), and work-conserving
//! skip-over-infeasible-heads is an in-order walk that never removes or
//! restores entries.
//!
//! Invariants (exercised by the differential property tests in
//! `tests/properties.rs`):
//! - `set` and `keys` agree: `(s, c) ∈ set ⟺ keys[c] = s`.
//! - Membership equals the policy's *active* set (clients with queued
//!   work); the owning policy calls `insert`/`remove` on queue
//!   empty/non-empty transitions and `insert` (upsert) after every
//!   counter mutation of an active client.
//! - Ordering uses `f64::total_cmp`, so ties and signed zeros order
//!   deterministically and identically to the retained linear-scan
//!   reference (`sched/reference.rs`).

use crate::core::{ClientId, ClientMap, ClientMapFamily, SlabFamily};
use std::collections::BTreeSet;

/// Totally-ordered f64 key (via `total_cmp`), so scores can live in a
/// `BTreeSet` without NaN footguns.
#[derive(Debug, Clone, Copy, Default)]
pub struct OrderedScore(pub f64);

// Bit equality, NOT f64 `==`: equality must agree with the `total_cmp`
// ordering (under which -0.0 < 0.0 and NaN payloads are distinct), or
// `ScoreIndex::insert`'s same-key fast path could strand a stale entry
// in the set.
impl PartialEq for OrderedScore {
    fn eq(&self, other: &Self) -> bool {
        self.0.to_bits() == other.0.to_bits()
    }
}

impl Eq for OrderedScore {}

impl PartialOrd for OrderedScore {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrderedScore {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Keyed ordered multimap client → score with O(log C) min and update.
///
/// The ordered side stays a `BTreeSet` (it IS the order structure); the
/// `keys` side — one lookup per re-key, the second log-structure the
/// seed paid on every counter mutation — is storage-family generic, so
/// the production path does a dense slab probe instead.
#[derive(Debug, Default)]
pub struct ScoreIndex<F: ClientMapFamily = SlabFamily> {
    set: BTreeSet<(OrderedScore, ClientId)>,
    keys: F::Map<OrderedScore>,
}

impl<F: ClientMapFamily> ScoreIndex<F> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert or re-key a client. O(log C).
    pub fn insert(&mut self, client: ClientId, score: f64) {
        let key = OrderedScore(score);
        if let Some(old) = self.keys.insert(client, key) {
            if old == key {
                return;
            }
            self.set.remove(&(old, client));
        }
        self.set.insert((key, client));
    }

    /// Remove a client (queue drained). Returns whether it was present.
    pub fn remove(&mut self, client: ClientId) -> bool {
        match self.keys.take(client) {
            Some(old) => {
                self.set.remove(&(old, client));
                true
            }
            None => false,
        }
    }

    pub fn contains(&self, client: ClientId) -> bool {
        self.keys.contains(client)
    }

    /// The min-score client, ties broken by client id. O(log C).
    pub fn min_client(&self) -> Option<ClientId> {
        self.set.iter().next().map(|&(_, c)| c)
    }

    /// The minimum score among members. O(log C).
    pub fn min_score(&self) -> Option<f64> {
        self.set.iter().next().map(|&(s, _)| s.0)
    }

    /// Walk members in ascending `(score, client)` order — the
    /// work-conserving scan: the caller takes the first feasible head and
    /// stops, so the common case touches only the front.
    pub fn iter_by_score(&self) -> impl Iterator<Item = (f64, ClientId)> + '_ {
        self.set.iter().map(|&(s, c)| (s.0, c))
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_and_rekey() {
        let mut ix: ScoreIndex = ScoreIndex::new();
        ix.insert(ClientId(3), 5.0);
        ix.insert(ClientId(1), 2.0);
        ix.insert(ClientId(2), 9.0);
        assert_eq!(ix.min_client(), Some(ClientId(1)));
        assert_eq!(ix.min_score(), Some(2.0));
        // Re-key the min upward: next-best surfaces.
        ix.insert(ClientId(1), 7.0);
        assert_eq!(ix.min_client(), Some(ClientId(3)));
        assert_eq!(ix.len(), 3);
    }

    #[test]
    fn ties_break_on_client_id() {
        let mut ix: ScoreIndex = ScoreIndex::new();
        ix.insert(ClientId(9), 1.0);
        ix.insert(ClientId(4), 1.0);
        assert_eq!(ix.min_client(), Some(ClientId(4)));
        let order: Vec<ClientId> = ix.iter_by_score().map(|(_, c)| c).collect();
        assert_eq!(order, vec![ClientId(4), ClientId(9)]);
    }

    #[test]
    fn remove_is_exact() {
        let mut ix: ScoreIndex = ScoreIndex::new();
        ix.insert(ClientId(0), 1.0);
        ix.insert(ClientId(1), 1.0);
        assert!(ix.remove(ClientId(0)));
        assert!(!ix.remove(ClientId(0)));
        assert_eq!(ix.min_client(), Some(ClientId(1)));
        assert!(ix.remove(ClientId(1)));
        assert!(ix.is_empty());
        assert_eq!(ix.min_client(), None);
    }

    #[test]
    fn idempotent_rekey_same_score() {
        let mut ix: ScoreIndex = ScoreIndex::new();
        ix.insert(ClientId(0), 3.0);
        ix.insert(ClientId(0), 3.0);
        assert_eq!(ix.len(), 1);
        assert_eq!(ix.iter_by_score().count(), 1);
    }

    #[test]
    fn total_order_handles_zero_signs() {
        let mut ix: ScoreIndex = ScoreIndex::new();
        ix.insert(ClientId(0), 0.0);
        ix.insert(ClientId(1), -0.0);
        // total_cmp: -0.0 < 0.0 — deterministic, no unwrap panics.
        assert_eq!(ix.min_client(), Some(ClientId(1)));
    }

    #[test]
    fn rekey_across_zero_signs_stays_consistent() {
        // 0.0 and -0.0 are == under f64 but distinct under total_cmp; a
        // naive same-key fast path would strand the old set entry.
        let mut ix: ScoreIndex = ScoreIndex::new();
        ix.insert(ClientId(0), 0.0);
        ix.insert(ClientId(0), -0.0);
        assert_eq!(ix.len(), 1);
        assert_eq!(ix.iter_by_score().count(), 1);
        assert!(ix.remove(ClientId(0)));
        assert!(ix.is_empty());
        assert_eq!(ix.min_client(), None);
        assert_eq!(ix.iter_by_score().count(), 0);
    }
}
