//! Requests-Per-Minute quota scheduling — the static rate-limit mitigation
//! the paper's §1 critiques: prevents monopolisation but wastes capacity
//! off-peak because unused allowance doesn't transfer.

use super::{Actuals, Scheduler};
use crate::core::{ClientId, ClientMap, ClientMapFamily, Request, SlabFamily};
use std::collections::VecDeque;

/// Storage-family generic like the fair schedulers (default: dense
/// `ClientSlab`; `MapRpm` in `sched/reference.rs` pins the `BTreeMap`
/// twin for the slab-vs-BTreeMap differential).
#[derive(Debug)]
pub struct Rpm<F: ClientMapFamily = SlabFamily> {
    /// FCFS among quota-eligible requests.
    queue: VecDeque<Request>,
    /// Per-client admission timestamps within the trailing window. The
    /// slab backend retains a drained client's stamp buffer, so one-shot
    /// clients cost a slot but no repeated allocation.
    admitted: F::Map<VecDeque<f64>>,
    /// Queued-request count per client (allocation-free backlog visiting).
    per_client: F::Map<usize>,
    /// Quota: max admissions per client per window.
    pub quota: u32,
    /// Window length (60 s for literal RPM).
    pub window: f64,
}

impl Rpm {
    /// Production (slab-backed) RPM limiter.
    pub fn new(quota: u32, window: f64) -> Self {
        Self::for_family(quota, window)
    }
}

impl<F: ClientMapFamily> Rpm<F> {
    /// Constructor for an explicit storage family.
    pub fn for_family(quota: u32, window: f64) -> Self {
        Rpm {
            queue: VecDeque::new(),
            admitted: Default::default(),
            per_client: Default::default(),
            quota,
            window,
        }
    }

    fn inc(&mut self, client: ClientId) {
        *self.per_client.or_default(client) += 1;
    }

    fn dec(&mut self, client: ClientId) {
        if let Some(n) = self.per_client.get_mut(client) {
            *n -= 1;
            if *n == 0 {
                // Zero count is Default-equivalent, so the slab may
                // retire the slot (drops membership, keeps the slot).
                self.per_client.retire(client);
            }
        }
    }
}

impl<F: ClientMapFamily> Scheduler for Rpm<F> {
    fn name(&self) -> &'static str {
        "rpm"
    }

    fn score_label(&self) -> &'static str {
        "rpm_window_count"
    }

    fn enqueue(&mut self, req: Request, _now: f64) {
        self.inc(req.client);
        self.queue.push_back(req);
    }

    fn pick(&mut self, now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request> {
        // First request in arrival order whose client is under quota.
        // NOT work-conserving across the quota: over-quota requests wait
        // even if the GPU is idle — that is the waste the paper measures.
        // Quota expiry is checked in place while walking the queue (the
        // seed collected every queued client into a fresh Vec per call).
        let quota = self.quota;
        let window = self.window;
        let mut idx: Option<usize> = None;
        for (i, r) in self.queue.iter().enumerate() {
            let stamps = self.admitted.or_default(r.client);
            while stamps.front().map(|&t| now - t >= window).unwrap_or(false) {
                stamps.pop_front();
            }
            if (stamps.len() as u32) < quota {
                idx = Some(i);
                break;
            }
        }
        let r = self.queue.remove(idx?)?;
        if feasible(&r) {
            self.admitted.or_default(r.client).push_back(now);
            self.dec(r.client);
            Some(r)
        } else {
            self.queue.insert(idx.unwrap(), r);
            None
        }
    }

    fn requeue(&mut self, req: Request) {
        // Refund the quota slot consumed at pick time.
        if let Some(stamps) = self.admitted.get_mut(req.client) {
            stamps.pop_back();
        }
        self.inc(req.client);
        self.queue.push_front(req);
    }

    fn on_complete(&mut self, _req: &Request, _actual: &Actuals, _now: f64) {}

    fn next_refresh_at(&self, now: f64) -> Option<f64> {
        // Earliest stamp expiry among clients with queued work: when the
        // oldest admission falls out of the trailing window that client
        // regains a slot. Conservative — the client may still be over
        // quota on its remaining stamps, in which case the engine simply
        // probes again at the following expiry. Iterates `per_client`
        // (clients with queued work), not the historical `admitted` map,
        // which holds an entry for every client ever walked — this hint
        // sits on the engine's per-event path.
        let admitted = &self.admitted;
        let window = self.window;
        let mut next: Option<f64> = None;
        self.per_client.for_each(&mut |client, _| {
            let Some(stamps) = admitted.get(client) else { return };
            if let Some(&t0) = stamps.front() {
                let expiry = t0 + window;
                if expiry > now && next.map(|x| expiry < x).unwrap_or(true) {
                    next = Some(expiry);
                }
            }
        });
        next
    }

    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn for_each_queued_client(&self, f: &mut dyn FnMut(ClientId)) {
        self.per_client.for_each(&mut |c, _| f(c));
    }

    fn queued_client_count(&self) -> usize {
        self.per_client.len()
    }

    fn drain_queued(&mut self) -> Vec<Request> {
        // Charge-free extraction (replica failover): bypass the quota —
        // the requests are not being admitted — and consume no stamps.
        // Arrival order, exactly the queue's layout.
        self.per_client.clear();
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestId;

    fn req(id: u64, client: u32) -> Request {
        Request::new(RequestId(id), ClientId(client), 10, 10, 0.0)
    }

    #[test]
    fn quota_caps_within_window() {
        let mut s = Rpm::new(2, 60.0);
        for i in 0..3 {
            s.enqueue(req(i, 0), 0.0);
        }
        assert!(s.pick(0.0, &mut |_| true).is_some());
        assert!(s.pick(1.0, &mut |_| true).is_some());
        // Third admission blocked by quota even though GPU is free.
        assert!(s.pick(2.0, &mut |_| true).is_none());
        // Window expiry restores the allowance.
        assert!(s.pick(61.0, &mut |_| true).is_some());
    }

    #[test]
    fn quota_is_per_client() {
        let mut s = Rpm::new(1, 60.0);
        s.enqueue(req(1, 0), 0.0);
        s.enqueue(req(2, 0), 0.0);
        s.enqueue(req(3, 1), 0.0);
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(0));
        // Client 0 over quota → client 1's request is next despite order.
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(1));
        assert!(s.pick(0.0, &mut |_| true).is_none());
    }

    #[test]
    fn next_refresh_at_points_at_earliest_useful_expiry() {
        let mut s = Rpm::new(1, 60.0);
        // No queued work, no stamps: no refresh event.
        assert_eq!(s.next_refresh_at(0.0), None);
        s.enqueue(req(1, 0), 0.0);
        s.enqueue(req(2, 0), 0.0);
        assert!(s.pick(5.0, &mut |_| true).is_some());
        // Client 0 over quota with queued work: expiry at stamp + window.
        assert_eq!(s.next_refresh_at(10.0), Some(65.0));
        // At the hinted time the queued request becomes admissible.
        assert!(s.pick(65.0, &mut |_| true).is_some());
        // Drained queue: stamps remain but no queued work → no event.
        assert_eq!(s.next_refresh_at(70.0), None);
    }

    #[test]
    fn drain_queued_bypasses_quota_and_consumes_no_stamps() {
        let mut s = Rpm::new(1, 60.0);
        s.enqueue(req(1, 0), 0.0);
        s.enqueue(req(2, 0), 0.0);
        s.enqueue(req(3, 1), 0.0);
        let out = s.drain_queued();
        assert_eq!(out.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(s.is_empty());
        assert_eq!(s.queued_client_count(), 0);
        // No stamps were consumed: a fresh enqueue admits immediately.
        s.enqueue(req(4, 0), 0.0);
        assert!(s.pick(0.0, &mut |_| true).is_some());
    }

    #[test]
    fn requeue_refunds_quota() {
        let mut s = Rpm::new(1, 60.0);
        s.enqueue(req(1, 0), 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        s.requeue(r);
        // Slot refunded → pick succeeds again.
        assert!(s.pick(0.0, &mut |_| true).is_some());
    }
}
