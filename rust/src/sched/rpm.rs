//! Requests-Per-Minute quota scheduling — the static rate-limit mitigation
//! the paper's §1 critiques: prevents monopolisation but wastes capacity
//! off-peak because unused allowance doesn't transfer.

use super::{Actuals, Scheduler};
use crate::core::{ClientId, Request};
use std::collections::{BTreeMap, VecDeque};

#[derive(Debug)]
pub struct Rpm {
    /// FCFS among quota-eligible requests.
    queue: VecDeque<Request>,
    /// Per-client admission timestamps within the trailing window.
    admitted: BTreeMap<ClientId, VecDeque<f64>>,
    /// Quota: max admissions per client per window.
    pub quota: u32,
    /// Window length (60 s for literal RPM).
    pub window: f64,
}

impl Rpm {
    pub fn new(quota: u32, window: f64) -> Self {
        Rpm { queue: VecDeque::new(), admitted: BTreeMap::new(), quota, window }
    }

    fn under_quota(&mut self, client: ClientId, now: f64) -> bool {
        let stamps = self.admitted.entry(client).or_default();
        while stamps.front().map(|&t| now - t >= self.window).unwrap_or(false) {
            stamps.pop_front();
        }
        (stamps.len() as u32) < self.quota
    }
}

impl Scheduler for Rpm {
    fn name(&self) -> &'static str {
        "rpm"
    }

    fn enqueue(&mut self, req: Request, _now: f64) {
        self.queue.push_back(req);
    }

    fn pick(&mut self, now: f64, feasible: &mut dyn FnMut(&Request) -> bool) -> Option<Request> {
        // First request in arrival order whose client is under quota.
        // NOT work-conserving across the quota: over-quota requests wait
        // even if the GPU is idle — that is the waste the paper measures.
        let clients: Vec<ClientId> = self.queue.iter().map(|r| r.client).collect();
        let idx = {
            let mut found = None;
            for (i, client) in clients.into_iter().enumerate() {
                if self.under_quota(client, now) {
                    found = Some(i);
                    break;
                }
            }
            found?
        };
        let r = self.queue.remove(idx)?;
        if feasible(&r) {
            self.admitted.entry(r.client).or_default().push_back(now);
            Some(r)
        } else {
            self.queue.insert(idx, r);
            None
        }
    }

    fn requeue(&mut self, req: Request) {
        // Refund the quota slot consumed at pick time.
        if let Some(stamps) = self.admitted.get_mut(&req.client) {
            stamps.pop_back();
        }
        self.queue.push_front(req);
    }

    fn on_complete(&mut self, _req: &Request, _actual: &Actuals, _now: f64) {}

    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn queued_clients(&self) -> Vec<ClientId> {
        let mut ids: Vec<ClientId> = self.queue.iter().map(|r| r.client).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestId;

    fn req(id: u64, client: u32) -> Request {
        Request::new(RequestId(id), ClientId(client), 10, 10, 0.0)
    }

    #[test]
    fn quota_caps_within_window() {
        let mut s = Rpm::new(2, 60.0);
        for i in 0..3 {
            s.enqueue(req(i, 0), 0.0);
        }
        assert!(s.pick(0.0, &mut |_| true).is_some());
        assert!(s.pick(1.0, &mut |_| true).is_some());
        // Third admission blocked by quota even though GPU is free.
        assert!(s.pick(2.0, &mut |_| true).is_none());
        // Window expiry restores the allowance.
        assert!(s.pick(61.0, &mut |_| true).is_some());
    }

    #[test]
    fn quota_is_per_client() {
        let mut s = Rpm::new(1, 60.0);
        s.enqueue(req(1, 0), 0.0);
        s.enqueue(req(2, 0), 0.0);
        s.enqueue(req(3, 1), 0.0);
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(0));
        // Client 0 over quota → client 1's request is next despite order.
        assert_eq!(s.pick(0.0, &mut |_| true).unwrap().client, ClientId(1));
        assert!(s.pick(0.0, &mut |_| true).is_none());
    }

    #[test]
    fn requeue_refunds_quota() {
        let mut s = Rpm::new(1, 60.0);
        s.enqueue(req(1, 0), 0.0);
        let r = s.pick(0.0, &mut |_| true).unwrap();
        s.requeue(r);
        // Slot refunded → pick succeeds again.
        assert!(s.pick(0.0, &mut |_| true).is_some());
    }
}
