//! Iteration-level continuous-batching engine over the roofline GPU model.
//!
//! One loop iteration = one engine step (Orca-style): chunked prefill
//! tokens plus one decode token for every running sequence, costed by
//! `GpuModel::iteration`. Admission happens between steps via the
//! `Scheduler` under a feasibility check covering the batch cap and KV
//! memory — prediction-driven schedulers additionally *reserve* KV for
//! their predicted output (the paper's stall-free scheduling), which is
//! what saves them from mid-decode preemptions under pressure.

use super::gpu::{GpuModel, IterationMix};
use super::host::HostProfile;
use crate::core::{ClientId, Request, RequestState};
use crate::kv::{KvCache, KvConfig};
use crate::metrics::{LatencyStats, ServiceTracker};
use crate::predictor::{predict_request, PerfMap, Predictor};
use crate::sched::counters::{HfParams, HolisticCounters};
use crate::sched::{Actuals, Scheduler};
use crate::workload::Trace;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub gpu: GpuModel,
    pub host: HostProfile,
    /// Timeline sample period (s) for util/rate series.
    pub sample_dt: f64,
    /// Safety cap on engine iterations.
    pub max_iterations: u64,
    /// Keep running after the trace horizon until queues drain.
    pub drain: bool,
}

impl SimConfig {
    pub fn a100_7b_vllm() -> Self {
        SimConfig {
            gpu: GpuModel::a100_7b(),
            host: HostProfile::VLLM,
            sample_dt: 1.0,
            max_iterations: 20_000_000,
            drain: true,
        }
    }

    pub fn with_host(mut self, host: HostProfile) -> Self {
        self.host = host;
        self
    }

    pub fn with_gpu(mut self, gpu: GpuModel) -> Self {
        self.gpu = gpu;
        self
    }
}

/// A request resident in the running batch.
#[derive(Debug)]
struct Running {
    req: Request,
    prefill_done: u32,
    admitted_at: f64,
    util_acc: f64,
    util_samples: u64,
    /// KV tokens currently backed by pages.
    kv_tokens: u32,
}

/// Everything the experiment harness needs out of one run.
#[derive(Debug)]
pub struct SimResult {
    pub scheduler: String,
    pub latency: LatencyStats,
    pub per_client_latency: BTreeMap<ClientId, LatencyStats>,
    pub service: ServiceTracker,
    /// (time, utilization in [0,1]) samples.
    pub util_timeline: Vec<(f64, f64)>,
    /// Output tokens per second of wall time.
    pub output_tps: f64,
    /// Weighted-token service per second.
    pub weighted_tps: f64,
    /// Busy-time-weighted average GPU utilization.
    pub gpu_util: f64,
    pub finished: usize,
    pub total_requests: usize,
    pub preemptions: u64,
    pub iterations: u64,
    /// Final per-client HF score from the scheduler-independent auditor
    /// (Jain over HF, §7.3.3).
    pub final_hf: Vec<(ClientId, f64)>,
    /// Per-sample-window set of backlogged clients (queued work), for the
    /// VTC-style bounded-discrepancy evaluation. Consecutive identical
    /// sets share one `Arc` allocation, so long drain phases (which
    /// sample the same backlog thousands of times) stay O(distinct sets)
    /// in memory instead of O(windows × clients).
    pub backlog_timeline: Vec<(f64, Arc<[ClientId]>)>,
    /// End of simulated time.
    pub wall: f64,
}

impl SimResult {
    pub fn jain_over_hf(&self) -> f64 {
        let xs: Vec<f64> = self.final_hf.iter().map(|(_, v)| *v).collect();
        crate::metrics::jain_index(&xs)
    }

    pub fn jain_over_service(&self) -> f64 {
        let xs: Vec<f64> =
            self.service.clients().iter().map(|c| self.service.total(*c)).collect();
        crate::metrics::jain_index(&xs)
    }

    /// Mean of Jain's index over per-window service rates — the
    /// *stability* view of fairness (Fig 12a): statistically identical
    /// tenants all end with equal totals, but an unfair scheduler serves
    /// them in lopsided bursts that windowed Jain exposes.
    pub fn windowed_jain(&self, window: f64) -> f64 {
        self.windowed_jain_until(window, self.wall)
    }

    /// Windowed Jain restricted to `t_max` (typically the trace horizon:
    /// during post-arrival drain every scheduler serves equal backlogs
    /// round-robin-ish, which would wash out the differences).
    pub fn windowed_jain_until(&self, window: f64, t_max: f64) -> f64 {
        let clients = self.service.clients();
        let t_end = t_max.min(self.wall);
        if clients.len() < 2 || t_end <= window {
            return 1.0;
        }
        let mut sum = 0.0;
        let mut n = 0usize;
        let mut t = window;
        while t <= t_end {
            let xs: Vec<f64> = clients
                .iter()
                .map(|c| self.service.curve(*c).map(|cv| cv.rate(t, window)).unwrap_or(0.0))
                .collect();
            if xs.iter().any(|&x| x > 0.0) {
                sum += crate::metrics::jain_index(&xs);
                n += 1;
            }
            t += window;
        }
        if n == 0 {
            1.0
        } else {
            sum / n as f64
        }
    }

    /// The VTC-paper fairness quantity: |ΔS_a − ΔS_b| accumulated within
    /// maximal intervals where BOTH clients are backlogged (the bounded-
    /// discrepancy theorem is stated over such intervals — outside them a
    /// client may legitimately receive less because it demands less).
    /// Returns the sampled series across all co-backlogged windows.
    pub fn backlogged_diff_series(&self, a: ClientId, b: ClientId) -> Vec<f64> {
        let ca = self.service.curve(a);
        let cb = self.service.curve(b);
        let (Some(ca), Some(cb)) = (ca, cb) else { return Vec::new() };
        let mut series = Vec::new();
        let mut window_start: Option<(f64, f64, f64)> = None; // (t0, sa0, sb0)
        for (t, backlogged) in &self.backlog_timeline {
            let both = backlogged.contains(&a) && backlogged.contains(&b);
            match (both, window_start) {
                (true, None) => {
                    window_start = Some((*t, ca.at(*t), cb.at(*t)));
                }
                (true, Some((_, sa0, sb0))) => {
                    series.push(((ca.at(*t) - sa0) - (cb.at(*t) - sb0)).abs());
                }
                (false, Some(_)) => {
                    window_start = None;
                }
                (false, None) => {}
            }
        }
        series
    }
}

/// One simulation run binding scheduler + predictor + workload.
pub struct Simulation<'a> {
    pub cfg: SimConfig,
    pub scheduler: &'a mut dyn Scheduler,
    pub predictor: &'a mut dyn Predictor,
    pub perfmap: PerfMap,
}

impl<'a> Simulation<'a> {
    pub fn new(
        cfg: SimConfig,
        scheduler: &'a mut dyn Scheduler,
        predictor: &'a mut dyn Predictor,
    ) -> Self {
        Simulation { cfg, scheduler, predictor, perfmap: PerfMap::default_a100_7b() }
    }

    pub fn run(&mut self, trace: &Trace) -> SimResult {
        let cfg = self.cfg.clone();
        let kv_cfg = KvConfig {
            page_size: 16,
            total_pages: ((cfg.gpu.kv_token_capacity() as f64 * cfg.host.kv_fraction) as u64 / 16)
                .min(u32::MAX as u64) as u32,
        };
        let mut kv = KvCache::new(kv_cfg);
        let mut running: Vec<Running> = Vec::new();
        let pending = trace.requests.clone();
        let mut next_arrival = 0usize;
        let total_requests = pending.len();

        let mut t = 0.0f64;
        let mut iterations = 0u64;
        let mut preemptions = 0u64;
        let mut finished = 0usize;

        let mut latency = LatencyStats::new();
        let mut per_client_latency: BTreeMap<ClientId, LatencyStats> = BTreeMap::new();
        let mut service = ServiceTracker::new();
        let mut auditor = HolisticCounters::new(HfParams::default());
        let peak_tps = cfg.gpu.peak_decode_tps(64, 512);

        // Utilization accounting over sample windows.
        let mut util_timeline: Vec<(f64, f64)> = Vec::new();
        let mut backlog_timeline: Vec<(f64, Arc<[ClientId]>)> = Vec::new();
        // Reused scratch + interned last set: the per-window backlog
        // sample is allocation-free unless the set actually changed.
        let mut backlog_scratch: Vec<ClientId> = Vec::new();
        let mut last_backlog: Option<Arc<[ClientId]>> = None;
        let mut win_start = 0.0f64;
        let mut win_busy_util = 0.0f64; // ∫ util dt over busy time
        let mut busy_util_total = 0.0f64;
        let mut total_output_tokens = 0u64;
        let mut total_weighted = 0.0f64;
        let mut last_batch_sig: u64 = 0;
        // Decode progress watermark for preempted requests: recomputed
        // tokens are GPU work but NOT newly delivered service — counting
        // them would credit the preempted tenant with phantom service.
        let mut rework: std::collections::HashMap<crate::core::RequestId, u32> =
            std::collections::HashMap::new();

        loop {
            iterations += 1;
            if iterations > cfg.max_iterations {
                break;
            }

            // ---- arrivals ----
            while next_arrival < pending.len() && pending[next_arrival].arrival <= t {
                let mut req = pending[next_arrival].clone();
                next_arrival += 1;
                predict_request(self.predictor, &self.perfmap, &mut req);
                auditor.touch(req.client, 1.0);
                req.state = RequestState::Queued;
                self.scheduler.enqueue(req, t);
            }

            let mut admitted_this_iter = 0u32;
            // ---- admission (Algorithm 1 lines 10–16) ----
            // Stall-free scheduling (§4): prediction-driven schedulers
            // reserve prompt + predicted output, but only once the cache
            // is under pressure — below the threshold, reservations would
            // just throttle admission for no benefit.
            let uses_pred = self.scheduler.uses_predictions();
            let total_tokens = kv.config().total_tokens().max(1);
            loop {
                if running.len() >= cfg.host.max_batch {
                    break;
                }
                let free_tokens = kv.free_tokens();
                let pressure = 1.0 - free_tokens as f64 / total_tokens as f64;
                // Reservation fraction ramps with pressure: nothing below
                // 50% occupancy, the full predicted output as the pool
                // nears exhaustion. An all-or-nothing reserve would
                // throttle admission (and TTFT) long before preemption
                // was actually a risk.
                let reserve_frac =
                    if uses_pred { ((pressure - 0.5) / 0.4).clamp(0.0, 1.0) } else { 0.0 };
                // vLLM-style watermark: keep enough headroom for the
                // resident batch to decode a window of steps, so admission
                // itself cannot trigger immediate preemption.
                let headroom = 32 * running.len() as u64;
                let picked = self.scheduler.pick(t, &mut |r: &Request| {
                    let need = r.input_tokens as u64
                        + (reserve_frac * r.predicted_output_tokens as f64) as u64
                        + 16;
                    need + headroom <= free_tokens
                });
                match picked {
                    None => break,
                    Some(mut req) => {
                        let reserve = req.input_tokens
                            + (reserve_frac * req.predicted_output_tokens as f64) as u32;
                        kv.allocate(req.id, reserve).expect("feasibility checked");
                        req.state = RequestState::Prefilling;
                        admitted_this_iter += 1;
                        running.push(Running {
                            kv_tokens: reserve,
                            admitted_at: t,
                            prefill_done: 0,
                            util_acc: 0.0,
                            util_samples: 0,
                            req,
                        });
                    }
                }
            }

            // ---- idle fast-forward ----
            if running.is_empty() {
                if next_arrival < pending.len() {
                    t = t.max(pending[next_arrival].arrival);
                    continue;
                }
                if !self.scheduler.is_empty() {
                    // Queued but nothing admissible (e.g. RPM quota
                    // exhaustion): advance time so quotas/windows refresh.
                    t += 0.25;
                    continue;
                }
                break; // drained
            }

            let any_prefill = running.iter().any(|r| r.prefill_done < r.req.input_tokens);
            let decode_allowed = cfg.host.mixed_batches
                || self.scheduler.system_optimizations()
                || !any_prefill;

            // ---- memory assurance before decode (vLLM recompute-style
            // preemption): if the batch's growth this step cannot be
            // backed by free pages, preempt the most recently admitted
            // sequences until it can. Their progress is lost and they
            // requeue — the cost prediction-blind schedulers pay under
            // pressure, which stall-free reservations avoid.
            if decode_allowed {
                loop {
                    let mut needed_pages = 0u32;
                    for r in running.iter() {
                        if r.prefill_done >= r.req.input_tokens
                            && r.req.generated < r.req.true_output_tokens
                        {
                            let ctx_after = r.req.input_tokens + r.req.generated + 1;
                            if ctx_after > r.kv_tokens && r.kv_tokens % 16 == 0 {
                                needed_pages += 1;
                            }
                        }
                    }
                    if needed_pages <= kv.free_pages() || running.len() <= 1 {
                        break;
                    }
                    // Victim: the newest-admitted sequence of the client
                    // holding the largest resident KV footprint. Naive
                    // newest-first would systematically churn the tenant
                    // with the highest admission rate (usually the small-
                    // request one), wrecking fairness for every policy.
                    let mut footprint: BTreeMap<ClientId, u64> = BTreeMap::new();
                    for r in running.iter() {
                        *footprint.entry(r.req.client).or_insert(0) += r.kv_tokens as u64;
                    }
                    let hog = footprint
                        .iter()
                        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(a.0)))
                        .map(|(c, _)| *c)
                        .unwrap();
                    let victim = running
                        .iter()
                        .enumerate()
                        .filter(|(_, r)| r.req.client == hog)
                        .max_by(|a, b| {
                            a.1.admitted_at
                                .partial_cmp(&b.1.admitted_at)
                                .unwrap()
                                .then(a.0.cmp(&b.0))
                        })
                        .map(|(i, _)| i)
                        .unwrap();
                    preemptions += 1;
                    let slot = running.swap_remove(victim);
                    kv.release(slot.req.id).ok();
                    let mut req = slot.req;
                    let wm = rework.entry(req.id).or_insert(0);
                    *wm = (*wm).max(req.generated);
                    req.generated = 0;
                    req.first_token_at = None;
                    req.state = RequestState::Queued;
                    self.scheduler.requeue(req);
                }
            }

            // ---- build the iteration mix ----
            let mut mix = IterationMix::default();
            let mut chunks: Vec<(usize, u32)> = Vec::new();
            if any_prefill {
                // Equinox's chunked-prefill coordination caps the per-
                // iteration prefill work so decode latency stays smooth
                // (Sarathi-style); baselines use the stock host budget.
                let mut budget = if self.scheduler.system_optimizations() {
                    cfg.host.prefill_chunk.min(2048)
                } else {
                    cfg.host.prefill_chunk
                };
                for (i, r) in running.iter().enumerate() {
                    if budget == 0 {
                        break;
                    }
                    let remaining = r.req.input_tokens - r.prefill_done;
                    if remaining == 0 {
                        continue;
                    }
                    let chunk = remaining.min(budget);
                    budget -= chunk;
                    mix.prefill_tokens += chunk as u64;
                    mix.prefill_context += r.prefill_done as u64;
                    chunks.push((i, chunk));
                }
            }
            if decode_allowed {
                for r in running.iter() {
                    if r.prefill_done >= r.req.input_tokens && r.req.generated < r.req.true_output_tokens {
                        mix.decode_seqs += 1;
                        mix.decode_context +=
                            (r.req.input_tokens + r.req.generated) as u64;
                    }
                }
            }
            if mix.prefill_tokens == 0 && mix.decode_seqs == 0 {
                // Whole batch blocked on chunk budget exhaustion for
                // already-prefilled requests in unmixed hosts — force a
                // decode-only iteration.
                for r in running.iter() {
                    if r.req.generated < r.req.true_output_tokens {
                        mix.decode_seqs += 1;
                        mix.decode_context += (r.req.input_tokens + r.req.generated) as u64;
                    }
                }
                if mix.decode_seqs == 0 {
                    break; // degenerate (all zero-output requests)
                }
            }

            // ---- cost the iteration ----
            let mut cost = cfg.gpu.iteration(&mix);
            // Serving-stack efficiency (host loop, adapters): stretches
            // the busy period.
            cost.time /= cfg.host.efficiency;
            let sig = batch_signature(&running);
            let refresh = if sig != last_batch_sig { cfg.host.batch_refresh } else { 0.0 };
            last_batch_sig = sig;
            // Serialized host CPU per admitted request (GIL-bound frontends).
            let host_cpu = admitted_this_iter as f64 * cfg.host.request_overhead;
            let dt = cost.time + refresh + host_cpu;
            let t_end = t + dt;

            busy_util_total += cost.time * cost.util;
            win_busy_util += cost.time * cost.util;

            // ---- advance requests ----
            for (i, chunk) in chunks {
                running[i].prefill_done += chunk;
            }
            let mut completed: Vec<usize> = Vec::new();
            for i in 0..running.len() {
                let prefilled = running[i].prefill_done >= running[i].req.input_tokens;
                running[i].util_acc += cost.util;
                running[i].util_samples += 1;
                if !prefilled || !decode_allowed && any_prefill {
                    continue;
                }
                if running[i].req.generated >= running[i].req.true_output_tokens {
                    completed.push(i);
                    continue;
                }
                // One decode token.
                let ctx_after =
                    running[i].req.input_tokens + running[i].req.generated + 1;
                if ctx_after > running[i].kv_tokens {
                    if kv.grow(running[i].req.id, ctx_after - running[i].kv_tokens).is_ok() {
                        running[i].kv_tokens = ctx_after;
                    } else {
                        // Assured above except in single-request corner
                        // cases; skip this step (stall).
                        continue;
                    }
                }
                running[i].req.generated += 1;
                let fresh = rework
                    .get(&running[i].req.id)
                    .map(|wm| running[i].req.generated > *wm)
                    .unwrap_or(true);
                if running[i].req.first_token_at.is_none() {
                    running[i].req.first_token_at = Some(t_end);
                    running[i].req.state = RequestState::Decoding;
                    // Prefill service is rendered by first-token time:
                    // credit the prompt tokens (weight 1 each) — once,
                    // even across preemption re-runs.
                    let first_run =
                        rework.get(&running[i].req.id).map(|wm| *wm == 0).unwrap_or(true);
                    if first_run {
                        service.record(
                            running[i].req.client,
                            t_end,
                            running[i].req.input_tokens as f64,
                        );
                    }
                }
                // Token-granular service accounting (weight 4 per output
                // token) — continuous curves, no completion-lump aliasing.
                // Recomputed (post-preemption) tokens are not re-credited
                // as user-visible service, but they ARE charged to the
                // scheduler's counters: the GPU work was consumed, and
                // leaving it unpriced lets a repeatedly-preempted tenant
                // keep min-counter priority while burning capacity on
                // rework (a starvation spiral).
                if fresh {
                    service.record(running[i].req.client, t_end, 4.0);
                }
                self.scheduler.on_progress(running[i].req.client, 4.0);
                if running[i].req.generated >= running[i].req.true_output_tokens {
                    completed.push(i);
                }
            }

            t = t_end;

            completed.sort_unstable();
            for &i in completed.iter().rev() {
                let slot = running.swap_remove(i);
                // Completion.
                let mut req = slot.req;
                req.finished_at = Some(t);
                req.state = RequestState::Finished;
                finished += 1;
                let e2e = t - req.arrival;
                let exec = t - slot.admitted_at;
                let out = req.generated;
                total_output_tokens += out as u64;
                let weighted = req.input_tokens as f64 + 4.0 * out as f64;
                total_weighted += weighted;
                let avg_util = if slot.util_samples > 0 {
                    slot.util_acc / slot.util_samples as f64
                } else {
                    0.0
                };
                let actual_tps = (req.input_tokens + out) as f64 / exec.max(1e-9);
                let actuals = Actuals {
                    latency: exec,
                    gpu_util: avg_util,
                    tps: actual_tps,
                    output_tokens: out,
                };
                self.scheduler.on_complete(&req, &actuals, t);
                self.predictor.observe(&req, out);
                self.perfmap.observe(
                    req.input_tokens,
                    out,
                    crate::predictor::perfmap::MappedMetrics {
                        latency: exec,
                        gpu_util: avg_util,
                        tps: actual_tps,
                    },
                );
                // Scheduler-independent HF auditor (actual metrics).
                {
                    let mut audited = req.clone();
                    audited.predicted_output_tokens = out;
                    audited.predicted_latency = exec;
                    audited.predicted_tps = actual_tps;
                    audited.predicted_gpu_util = avg_util;
                    auditor.update_ufc_on_admit(&audited, t.min(e2e + audited.arrival));
                    auditor.update_rfc_on_admit(&audited, peak_tps);
                }
                latency.observe(&req);
                per_client_latency.entry(req.client).or_default().observe(&req);
                kv.release(req.id).ok();
            }

            // ---- timeline sampling ----
            while t - win_start >= cfg.sample_dt {
                let u = (win_busy_util / cfg.sample_dt).min(1.0);
                util_timeline.push((win_start + cfg.sample_dt, u));
                backlog_scratch.clear();
                self.scheduler.for_each_queued_client(&mut |c| backlog_scratch.push(c));
                let unchanged = last_backlog
                    .as_ref()
                    .map(|prev| prev[..] == backlog_scratch[..])
                    .unwrap_or(false);
                let set: Arc<[ClientId]> = if unchanged {
                    Arc::clone(last_backlog.as_ref().unwrap())
                } else {
                    let fresh: Arc<[ClientId]> = Arc::from(&backlog_scratch[..]);
                    last_backlog = Some(Arc::clone(&fresh));
                    fresh
                };
                backlog_timeline.push((win_start + cfg.sample_dt, set));
                win_busy_util = 0.0;
                win_start += cfg.sample_dt;
            }

            // ---- termination ----
            let drained = running.is_empty() && self.scheduler.is_empty();
            if next_arrival >= pending.len() && drained {
                break;
            }
            if !cfg.drain && t > trace.horizon && drained {
                break;
            }
        }

        let wall = t.max(1e-9);
        SimResult {
            scheduler: self.scheduler.name().to_string(),
            latency,
            per_client_latency,
            service,
            util_timeline,
            output_tps: total_output_tokens as f64 / wall,
            weighted_tps: total_weighted / wall,
            // SM-busy seconds over wall time — what nvidia-smi-style
            // monitoring (and the paper's Fig 9b/17b) reports.
            gpu_util: (busy_util_total / wall).min(1.0),
            finished,
            total_requests,
            preemptions,
            iterations,
            final_hf: auditor.all_hf(),
            backlog_timeline,
            wall,
        }
    }
}

/// Order-insensitive batch-composition signature for refresh detection.
/// XOR of per-id mixes: commutative, so no sort or allocation on the
/// per-iteration hot path (§Perf iteration 3).
fn batch_signature(running: &[Running]) -> u64 {
    running
        .iter()
        .map(|r| {
            let mut z = r.req.id.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        })
        .fold(0x6a09_e667_f3bc_c909u64, |acc, x| acc ^ x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::Oracle;
    use crate::sched::{EquinoxSched, Fcfs, Vtc};
    use crate::workload::{generate, Scenario};

    fn short_trace() -> Trace {
        generate(&Scenario::balanced_load(20.0), 42)
    }

    #[test]
    fn fcfs_completes_all_requests() {
        let trace = short_trace();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert_eq!(res.finished, trace.len(), "all requests must finish");
        assert!(res.wall > 0.0);
        assert!(res.output_tps > 0.0);
    }

    #[test]
    fn equinox_completes_all_requests() {
        let trace = short_trace();
        let mut sched = EquinoxSched::default_params(3000.0);
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert_eq!(res.finished, trace.len());
        assert_eq!(res.preemptions, 0, "oracle reservations must avoid preemption");
    }

    #[test]
    fn vtc_completes_all_requests() {
        let trace = short_trace();
        let mut sched = Vtc::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert_eq!(res.finished, trace.len());
    }

    #[test]
    fn latencies_are_positive_and_ordered() {
        let trace = short_trace();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert!(res.latency.ttft_mean() > 0.0);
        assert!(res.latency.e2e_mean() > res.latency.ttft_mean());
    }

    #[test]
    fn service_totals_match_token_accounting() {
        let trace = short_trace();
        let expected: f64 = trace.requests.iter().map(|r| r.weighted_tokens()).sum();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        let total = res.service.grand_total();
        assert!((total - expected).abs() / expected < 1e-9, "total={total} expected={expected}");
    }

    #[test]
    fn util_timeline_is_bounded() {
        let trace = short_trace();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert!(!res.util_timeline.is_empty());
        for (_, u) in &res.util_timeline {
            assert!((0.0..=1.0).contains(u));
        }
    }

    #[test]
    fn backlog_sets_are_interned() {
        let trace = short_trace();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert!(!res.backlog_timeline.is_empty());
        for w in res.backlog_timeline.windows(2) {
            if w[0].1[..] == w[1].1[..] {
                assert!(
                    Arc::ptr_eq(&w[0].1, &w[1].1),
                    "consecutive identical backlog sets must share one allocation"
                );
            }
        }
    }

    #[test]
    fn deterministic_given_seeded_inputs() {
        let trace = short_trace();
        let run = || {
            let mut sched = EquinoxSched::default_params(3000.0);
            let mut pred = Oracle::new();
            let mut sim =
                Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
            let r = sim.run(&trace);
            (r.finished, r.iterations, r.output_tps)
        };
        assert_eq!(run(), run());
    }
}
